"""Reproduce the paper's own evaluation: Table I and Fig. 5.

    PYTHONPATH=src python examples/photonic_sim.py

Prints the link-budget scalability table (15/15 cells exact vs the paper)
and the transaction-level FPS / FPS/W / FPS/W/mm2 comparison of SPOGA vs
HOLYLIGHT (MAW) and DEAPCNN (AMW) on MobileNet-V2, ShuffleNet-V2,
ResNet-50 and GoogLeNet, with the headline ratios vs the paper's Sec IV-C.
"""

from benchmarks import fig5_fps, table1_scalability

print("\n".join(table1_scalability.run()))
print("\n".join(fig5_fps.run()))
