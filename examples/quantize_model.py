"""PTQ flow: calibrate a trained model's activations, compare quant modes.

    PYTHONPATH=src python examples/quantize_model.py

Trains a tiny LM in bf16, then evaluates the SAME weights under the three
INT8 execution dataflows (paper Fig. 2) plus bf16, showing

* spoga / deas / direct produce IDENTICAL logits (same integer math),
* the quantization error vs bf16 is small,
* per-tensor absmax vs 99.9th-percentile calibration scales.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.train import train_loop
from repro.models import forward
from repro.quant.calibrate import absmax_calibrate, percentile_calibrate

ARCH = "llama3.2-1b"

cfg_bf16 = reduced(get_config(ARCH)).with_(n_layers=2, remat=False)
tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3, total_steps=30)
params, losses = train_loop(cfg_bf16, tcfg, steps=30, batch=4, seq=64, log_every=10)
print(f"[quantize] trained bf16: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

pipe = SyntheticTokenPipeline(cfg_bf16.vocab_size, 64, 4, seed=99)
batch = {"tokens": pipe.global_batch_at(0)}

ref = np.asarray(forward(params, cfg_bf16, batch), np.float32)
outs = {}
for mode in ("int8_spoga", "int8_deas", "int8_direct"):
    outs[mode] = np.asarray(
        forward(params, cfg_bf16.with_(quant_mode=mode), batch), np.float32)

assert (outs["int8_spoga"] == outs["int8_deas"]).all()
assert (outs["int8_spoga"] == outs["int8_direct"]).all()
print("[quantize] spoga == deas == direct: identical logits (exact int math)")

err = np.abs(outs["int8_spoga"] - ref).max() / (np.abs(ref).max() + 1e-9)
agree = (outs["int8_spoga"].argmax(-1) == ref.argmax(-1)).mean()
print(f"[quantize] int8 vs bf16: max rel err {err:.4f}, "
      f"argmax agreement {100 * agree:.1f}%")

# calibration: collect an activation sample and compare scale estimators
acts = [jax.random.normal(jax.random.PRNGKey(i), (1024,)) *
        (1.0 + 5.0 * (i == 2)) for i in range(4)]     # one outlier batch
print(f"[quantize] absmax scale      = {float(absmax_calibrate(acts)):.5f}")
print(f"[quantize] p99.9 scale       = {float(percentile_calibrate(acts)):.5f} "
      f"(robust to the outlier batch)")
