"""End-to-end driver: train a ~100M-param LM with the SPOGA INT8 dataflow.

Default invocation trains a ~100M-parameter xLSTM-family model for 300
steps on the synthetic pipeline with checkpointing every 50 steps:

    PYTHONPATH=src python examples/train_lm.py                # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --smoke        # tiny, 30 steps
    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-1b \\
        --quant-mode int8_spoga --steps 500 --ckpt-dir /tmp/spoga_ckpt

On a TPU pod the same driver pjit-shards over the production mesh; on CPU
it runs the identical program on one device.
"""

import argparse

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--quant-mode", default="int8_spoga",
                    choices=["bf16", "int8_spoga", "int8_deas", "int8_direct"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + 30 steps (CI-sized)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        args.steps, args.batch, args.seq = 30, 4, 64
    cfg = cfg.with_(quant_mode=args.quant_mode, remat=False)

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=max(args.steps // 20, 3),
                       total_steps=args.steps)
    _, losses = train_loop(cfg, tcfg, steps=args.steps, batch=args.batch,
                           seq=args.seq, ckpt_dir=args.ckpt_dir,
                           checkpoint_every=50, log_every=10)
    print(f"[train_lm] {args.arch} ({args.quant_mode}): "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
