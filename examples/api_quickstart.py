"""repro.api quickstart: the one-import serving surface.

    PYTHONPATH=src python examples/api_quickstart.py

1. Build an ``LLM`` from an arch name + a layered ``RuntimeConfig``.
2. ``generate`` a batch of prompts; check scheduling is output-invisible
   (every request's greedy tokens == its solo ``serve_batch`` decode).
3. ``stream`` tokens, then detokenized text fragments.
4. Serialize the RuntimeConfig to a dict and round-trip it.
5. The same facade on the paged KV cache with byte-size int8 pages.
6. Stacked (batched) prefill admission — fewer dispatches, same tokens.
"""

import json

import jax.numpy as jnp
import numpy as np

from repro.api import (
    LLM,
    KVConfig,
    RuntimeConfig,
    SamplingParams,
    SchedulerConfig,
    serve_batch,
)

rng = np.random.default_rng(0)

# 1 — one entrypoint: arch registry name + runtime config
runtime = RuntimeConfig(reduced=True, max_new_tokens=8)
llm = LLM(arch="llama3.2-1b", runtime=runtime)
print(f"1. LLM({llm.config.name}): quant={llm.config.quant_mode}, "
      f"kv={runtime.kv.mode}/{runtime.kv.dtype}")

# 2 — batch generate; greedy streams are bitwise a solo decode per prompt
prompts = [rng.integers(0, llm.config.vocab_size, n).tolist() for n in (5, 9, 3)]
outs = llm.generate(prompts, sampling=SamplingParams(greedy=True))
for out, prompt in zip(outs, prompts):
    solo, _ = serve_batch(llm.config, llm.params,
                          {"tokens": jnp.asarray([prompt], jnp.int32)},
                          cache_len=llm.engine.engine_cfg.cache_len,
                          gen_tokens=len(out.token_ids))
    assert out.token_ids == np.asarray(solo)[0].tolist()
print(f"2. generate: {len(outs)} requests, first tokens "
      f"{[o.token_ids[0] for o in outs]}, all == solo serve_batch exactly")

# 3 — streaming: token ids, then text fragments through the detokenizer
toks = list(llm.stream(prompts[0], max_new_tokens=4))
text = "".join(llm.stream(prompts[0], max_new_tokens=4, detokenize=True))
print(f"3. stream: tokens {toks} -> text {text!r}")

# 4 — the runtime config round-trips through plain JSON
blob = json.dumps(runtime.to_dict())
assert RuntimeConfig.from_dict(json.loads(blob)) == runtime
print(f"4. RuntimeConfig round-trip through {len(blob)}-byte JSON")

# 5 — paged pool with int8 byte-size pages; same facade, same outputs
paged = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(
    reduced=True,
    max_new_tokens=6,
    kv=KVConfig(mode="paged", dtype="int8", page_size=8),
))
outs = paged.generate(prompts)
m = paged.metrics
print(f"5. paged int8: {sum(len(o.token_ids) for o in outs)} tokens, "
      f"peak {m.peak_pages_used}/{m.pages_total} pages, "
      f"{m.defrag_count} defrags")

# 6 — stacked (batched) prefill admission: same-bucket prompts share ONE
# prefill dispatch (slot mode; outputs stay bitwise-identical)
stacked = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(
    reduced=True,
    max_new_tokens=6,
    scheduler=SchedulerConfig(n_slots=4, batched_admission=True,
                              prefill_buckets=(8, 16)),
))
outs2 = stacked.generate(prompts)
assert [o.token_ids[0] for o in outs2] == [o.token_ids[0] for o in outs]
m = stacked.metrics
assert m.prefill_dispatches < m.prefills
print(f"6. batched admission: {m.prefills} prefills in "
      f"{m.prefill_dispatches} dispatches ({m.stacked_prefills} stacked), "
      f"outputs unchanged")
