"""Serve a small model with batched requests: prefill + decode w/ KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-1b --smoke
    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b --smoke \\
        --batch 8 --gen 32          # bounded-state decode (RG-LRU + local attn)

Every architecture family serves through the same two entry points
(``prefill`` then repeated ``decode_step``); dense GQA, MLA, MoE,
xLSTM state, RG-LRU and enc-dec cross-attention caches all work.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serve import serve_batch
from repro.models import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant-mode", default="bf16")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    cfg = cfg.with_(quant_mode=args.quant_mode, remat=False)

    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    if cfg.is_encoder_decoder:
        batch = {
            "src_embeds": jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02,
            "tgt_tokens": jax.random.randint(
                key, (args.batch, 4), 0, cfg.vocab_size),
        }
    else:
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}

    t0 = time.time()
    out, timings = serve_batch(cfg, params, batch,
                               cache_len=args.prompt_len + args.gen, gen_tokens=args.gen)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"[serve_lm] {args.arch}: generated {out.shape} "
          f"({toks} tokens in {dt:.2f}s = {toks / dt:.1f} tok/s incl. compile)")
    print("[serve_lm] sample:", np.asarray(out[0, :12]))


if __name__ == "__main__":
    main()
