"""Quickstart: SPOGA's bit-sliced INT8 GEMM in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Slice INT8 operands into nibbles and verify exact reconstruction.
2. Run the three GEMM dataflows (prior-work DEAS, the paper's SPOGA,
   native direct) and verify they agree EXACTLY in int32.
3. Run the Pallas TPU kernel in interpret mode against the oracle.
4. Run one quantized W8A8 linear layer end to end.
5. Pick GEMM backends from the registry and run the parametric quant
   modes (w4a8: 4-bit weights in ONE slice plane — half the partials).
6. Serve staggered requests through the continuous-batching engine and
   check scheduling is output-invisible (== solo greedy decode).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import list_backends, quant_mode_summary, quantized_linear
from repro.core.slicing import reconstruct, slice_planes, slice_tc
from repro.core.spoga import deas_matmul, direct_matmul, quantized_matmul, spoga_matmul
from repro.kernels.spoga_gemm import spoga_gemm
from repro.models.layers import linear
from repro.quant.qtensor import quantize

rng = np.random.default_rng(0)

# 1 — nibble slicing is exact for the full int8 range
x = jnp.asarray(rng.integers(-128, 128, (4, 8), dtype=np.int8))
msn, lsn = slice_tc(x)
assert (reconstruct(msn, lsn) == x).all()
print("1. slicing: x == 16*MSN + LSN exactly, MSN in [-8,7], LSN in [0,15]")

# 2 — the three dataflows are the same integer arithmetic
a = jnp.asarray(rng.integers(-128, 128, (64, 128), dtype=np.int8))
b = jnp.asarray(rng.integers(-128, 128, (128, 32), dtype=np.int8))
o_deas, o_spoga, o_direct = deas_matmul(a, b), spoga_matmul(a, b), direct_matmul(a, b)
assert (o_deas == o_spoga).all() and (o_spoga == o_direct).all()
print("2. dataflows: deas == spoga == direct (int32-exact), out", o_spoga.shape)

# 3 — the Pallas TPU kernel (interpret mode on CPU)
o_kernel = spoga_gemm(a, b, block_m=32, block_n=32, block_k=64, interpret=True)
assert (o_kernel == o_spoga).all()
print("3. pallas kernel: fused radix accumulation matches the oracle")

# 4 — a W8A8 quantized linear layer
h = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32) * 0.1)
hq, wq = quantize(h, axis=-1), quantize(w, axis=0)
y = quantized_matmul(hq.data, wq.data, hq.scale, wq.scale.reshape(1, -1),
                     mode="int8_spoga")
err = float(jnp.max(jnp.abs(y - h @ w)) / jnp.max(jnp.abs(h @ w)))
print(f"4. W8A8 linear: relative error vs fp32 = {err:.4f} (quantization only)")

# 5 — the backend registry + parametric quant modes end to end
print(f"5. GEMM backend registry: {', '.join(list_backends())}")
hx = jnp.asarray(rng.normal(size=(2, 16, 96)).astype(np.float32))  # batched
wx = jnp.asarray(rng.normal(size=(96, 40)).astype(np.float32) * 0.1)
exact = jnp.einsum("...k,kn->...n", hx, wx)
for mode in ("int8_spoga", "w4a8", "w4a4"):
    # default backend (auto-selected) and the fused Pallas kernel body
    # (interpret mode on CPU) must agree on the same quantized integers
    y_auto = quantized_linear(hx, wx, mode, out_dtype=jnp.float32)
    y_pallas = quantized_linear(hx, wx, mode, backend="pallas_interpret",
                                out_dtype=jnp.float32)
    assert np.array_equal(np.asarray(y_auto), np.asarray(y_pallas)), mode
    rel = float(jnp.linalg.norm(y_auto - exact) / jnp.linalg.norm(exact))
    print(f"   {quant_mode_summary(mode):52s} rel err {rel:.4f}")

# w4a8 weights really do ride a single 4-bit plane:
w4 = quantize(wx, axis=0, bits=4)
(plane,) = slice_planes(w4.data, 1, 4)
assert (plane == w4.data).all()
# ... and the model-layer entry point takes the same modes:
y_layer = linear(hx.astype(jnp.bfloat16), wx.astype(jnp.bfloat16), "w4a8")
assert y_layer.shape == exact.shape
print("   w4a8 through models.layers.linear (STE backward-ready):",
      y_layer.shape, y_layer.dtype)

# 6 — continuous batching: mixed-length requests, staggered arrivals, fewer
# slots than requests (queueing + slot reuse). Each request's greedy tokens
# must equal a solo run — the scheduler is invisible in the outputs.
from repro.configs import get_config, reduced
from repro.launch.serve import serve_batch
from repro.models import init_params
from repro.serving import EngineConfig, ServingEngine

cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
params = init_params(cfg, jax.random.PRNGKey(0))
engine = ServingEngine(cfg, params, EngineConfig(
    n_slots=2, cache_len=32, prefill_buckets=(8, 16)))
prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (5, 9, 3)]
metrics = engine.run([(0, prompts[0], 6), (0, prompts[1], 4), (2, prompts[2], 5)])
for req in sorted(metrics.finished, key=lambda r: r.req_id):
    solo, _ = serve_batch(cfg, params,
                          {"tokens": jnp.asarray([req.prompt], jnp.int32)},
                          cache_len=32, gen_tokens=req.max_new_tokens)
    assert req.output_tokens == np.asarray(solo)[0].tolist()
print(f"6. continuous batching: 3 staggered requests on 2 slots == solo decode; "
      f"{metrics.report()['tokens_per_s']:.0f} tok/s")
print("quickstart OK")
