"""Benchmark regression gate over the ``BENCH_*.json`` trajectories.

``bench_record.append_run`` accumulates every benchmark run across PRs —
different sweeps (engine-vs-static, prefix, spec) append into the SAME
file, so a trajectory interleaves run kinds.  This script turns it into a
CI gate: for each *headline metric*, compare the newest run carrying that
metric against the trailing median of the prior runs carrying it, and
fail (exit 1) when it regresses by more than ``--threshold`` (default
15%).

Only *machine-independent ratio* metrics gate — each sweep's headline
speedup (engine-vs-static, spec-vs-plain, cached-vs-cold) plus the
tail-latency ratios (engine-vs-static and cached-vs-cold p99 TTFT, which
gate in the *lower-is-better* direction), never raw tok/s, whose absolute
value depends on the host CI happens to land on.
Runs are additionally filtered to the newest run's platform (cpu / tpu
...), so a trajectory spanning machines still compares like with like.
With fewer than ``--min-priors`` comparable prior runs a metric passes
trivially — a fresh trajectory can't regress against itself.

    PYTHONPATH=src python benchmarks/bench_check.py [files...] \
        [--threshold 0.15] [--min-priors 2]

With no files, checks every ``BENCH_serve*.json`` next to this script.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import statistics
import sys

# the machine-independent headline ratios (higher is better), one per
# sweep kind: continuous-vs-static, spec-on-vs-off, prefix-cached-vs-cold,
# tensor-parallel tp=N-vs-tp=1, plus deadline-respecting throughput share
# under overload (goodput tok/s over total tok/s at stagger 0 — the SLO
# accounting headline)
GATED_METRICS = (
    "speedup_vs_static",
    "speedup_vs_plain",
    "speedup_vs_cold",
    "tp_speedup",
    "goodput_frac_overload",
)

# tail-latency ratios where LOWER is better (engine p99 TTFT over static,
# cached p99 TTFT over cold, overloaded-engine p99 TTFT over static):
# these fail when the value *rises* past baseline * (1 + threshold)
GATED_METRICS_LOWER = (
    "ttft_p99_vs_static",
    "ttft_p99_ratio_vs_cold",
    "ttft_p99_overload_vs_static",
)


def check_metric(path: pathlib.Path, runs: list, metric: str,
                 threshold: float, min_priors: int,
                 lower_is_better: bool = False) -> dict | None:
    """Gate one headline metric's trajectory.

    Returns a verdict row (``{"file", "metric", "value", "baseline",
    "bound", "verdict"}``) for the summary table, or None when no run in
    this trajectory carries the metric.  ``verdict`` is one of ``pass``,
    ``FAIL`` or ``building`` (too few comparable priors to gate).
    """
    series = [r for r in runs if r.get(metric) is not None]
    if not series:
        return None
    newest = series[-1]
    value = newest[metric]
    row = {"file": path.name, "metric": metric, "value": value,
           "baseline": None, "bound": None}
    priors = [r[metric] for r in series[:-1]
              if r.get("platform") == newest.get("platform")]
    if len(priors) < min_priors:
        print(f"[bench_check] {path.name}: {metric}={value:.3f}, only "
              f"{len(priors)} comparable prior run(s) (< {min_priors}) "
              f"-- pass (building trajectory)")
        row["verdict"] = "building"
        return row
    baseline = statistics.median(priors)
    if lower_is_better:
        bound = baseline * (1.0 + threshold)
        ok = value <= bound
        edge = "ceiling"
    else:
        bound = baseline * (1.0 - threshold)
        ok = value >= bound
        edge = "floor"
    verdict = "pass" if ok else "FAIL"
    print(f"[bench_check] {path.name}: {metric}={value:.3f} vs trailing "
          f"median {baseline:.3f} over {len(priors)} runs "
          f"({edge} {bound:.3f}) -- {verdict}")
    row.update(baseline=baseline, bound=f"{edge} {bound:.3f}",
               verdict=verdict)
    return row


def check_file(path: pathlib.Path, threshold: float,
               min_priors: int) -> list[dict]:
    """All verdict rows for one trajectory file (empty = nothing to gate)."""
    # a missing or zero-byte trajectory is a fresh start, not a failure —
    # CI on a new branch has nothing to gate against; only a file that
    # EXISTS with content but cannot parse is treated as corruption
    if not path.exists() or path.stat().st_size == 0:
        print(f"[bench_check] {path.name}: missing or empty -- skipped "
              f"(fresh trajectory)")
        return []
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"[bench_check] {path.name}: unreadable ({e}) -- FAIL")
        return [{"file": path.name, "metric": "(parse)", "value": None,
                 "baseline": None, "bound": None, "verdict": "FAIL"}]
    runs = doc.get("runs") or []
    if not runs:
        print(f"[bench_check] {path.name}: no runs -- skipped")
        return []
    rows = [check_metric(path, runs, m, threshold, min_priors)
            for m in GATED_METRICS]
    rows += [check_metric(path, runs, m, threshold, min_priors,
                          lower_is_better=True)
             for m in GATED_METRICS_LOWER]
    return [r for r in rows if r is not None]


def _fmt(x) -> str:
    return "—" if x is None else (f"{x:.3f}" if isinstance(x, float) else str(x))


def summary_table(rows: list[dict]) -> str:
    """The verdict table as GitHub-flavoured markdown (for
    ``$GITHUB_STEP_SUMMARY``)."""
    lines = ["## Benchmark regression gate", "",
             "| file | metric | value | trailing median | gate | verdict |",
             "| --- | --- | --- | --- | --- | --- |"]
    for r in rows:
        mark = {"pass": "✅ pass", "FAIL": "❌ FAIL",
                "building": "🏗️ building"}.get(r["verdict"], r["verdict"])
        lines.append(f"| {r['file']} | `{r['metric']}` | {_fmt(r['value'])} "
                     f"| {_fmt(r['baseline'])} | {_fmt(r['bound'])} "
                     f"| {mark} |")
    if not rows:
        lines.append("| — | — | — | — | — | nothing to gate |")
    return "\n".join(lines) + "\n"


def write_step_summary(rows: list[dict]) -> None:
    """Append the verdict table to GitHub Actions' job summary, if any."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    try:
        with open(path, "a") as f:
            f.write(summary_table(rows) + "\n")
    except OSError as e:  # a broken summary file must not flip the gate
        print(f"[bench_check] could not write GITHUB_STEP_SUMMARY: {e}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json trajectories (default: "
                         "BENCH_serve*.json beside this script)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional regression vs the "
                         "trailing median (default 0.15)")
    ap.add_argument("--min-priors", type=int, default=2,
                    help="comparable prior runs required before the gate "
                         "engages (default 2)")
    args = ap.parse_args()
    if not 0.0 < args.threshold < 1.0:
        ap.error("--threshold must be in (0, 1)")

    here = pathlib.Path(__file__).parent
    files = ([pathlib.Path(f) for f in args.files] if args.files
             else sorted(here.glob("BENCH_serve*.json")))
    if not files:
        print("[bench_check] no trajectory files found -- nothing to gate")
        write_step_summary([])
        return 0
    rows = [r for f in files
            for r in check_file(f, args.threshold, args.min_priors)]
    write_step_summary(rows)
    return 0 if all(r["verdict"] != "FAIL" for r in rows) else 1


if __name__ == "__main__":
    sys.exit(main())
