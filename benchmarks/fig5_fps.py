"""Benchmark: paper Fig. 5 — FPS / FPS/W / FPS/W/mm2, SPOGA vs baselines."""

from repro.core.accelerator_sim import (
    ACCELS, WORKLOADS, fig5_comparison, headline_ratios,
)


def run() -> list[str]:
    comp = fig5_comparison()
    lines = ["", "=== Fig. 5: system-level comparison (4 CNNs, 8 GEMM groups) ==="]
    lines.append(f"{'accel':14s} {'workload':14s} {'FPS':>12s} {'FPS/W':>10s} "
                 f"{'FPS/W/mm2':>11s} {'power W':>9s} {'area mm2':>9s}")
    for name in ACCELS:
        for w in WORKLOADS:
            r = comp[name][w]
            lines.append(
                f"{name:14s} {w:14s} {r.fps:12.1f} {r.fps_per_w:10.3f} "
                f"{r.fps_per_w_mm2:11.5f} {r.power_w:9.2f} {r.area_mm2:9.1f}")
        g = comp[name]["gmean"]
        lines.append(
            f"{name:14s} {'GMEAN':14s} {g['fps']:12.1f} {g['fps_per_w']:10.3f} "
            f"{g['fps_per_w_mm2']:11.5f}")
    lines.append("")
    lines.append("=== headline ratios vs paper Sec. IV-C ===")
    for key, vals in headline_ratios(comp).items():
        delta = 100.0 * (vals["ours"] / vals["paper"] - 1.0)
        lines.append(f"{key:45s} ours={vals['ours']:6.2f}  paper={vals['paper']:5.1f}"
                     f"  ({delta:+.0f}%)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
