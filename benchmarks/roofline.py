"""Roofline analysis from the dry-run's compiled artifacts (EXPERIMENTS.md).

Reads ``results/dryrun.jsonl`` (written by ``repro.launch.dryrun``) and for
every (arch x shape x mesh x quant_mode) cell derives the three roofline
terms on TPU v5e targets:

    compute    = HLO_FLOPs_per_device / peak_FLOPs          [197e12 bf16]
    memory     = HLO_bytes_per_device / HBM_bw              [819e9 B/s]
    collective = collective_bytes_per_device / (links * 50e9 B/s)

plus MODEL_FLOPS = 6*N*D (train) or 2*N*D (prefill/decode), with N the
*active* parameter count (MoE: shared + top-k routed), and the useful-
compute ratio MODEL_FLOPS / (HLO_FLOPs * devices).

The dominant term is the bottleneck the perf loop (EXPERIMENTS.md, Perf)
iterates on.
"""

from __future__ import annotations

import json
import math
import os

PEAK_FLOPS_BF16 = 197e12      # per v5e chip
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9                # B/s per chip
ICI_LINK_BW = 50e9            # B/s per link per direction
LINKS_PER_CHIP = 4            # 2D torus (16x16 pod)

_PARAM_CACHE: dict[str, tuple[float, float]] = {}


def param_counts(arch: str) -> dict:
    """Active/matmul parameter decomposition (cached; shapes only).

    * ``active``      — total with MoE experts scaled to top-k/E.
    * ``matmul``      — active params that do per-token matmul work
                        (excludes the embedding gather; includes the
                        unembedding head once for tied embeddings).
    * ``enc_matmul``  — encoder-stack share of ``matmul`` (enc-dec only).
    * ``head``        — unembedding matrix size (V*d).
    """
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax

    from repro.configs import get_config
    from repro.models import model as model_lib

    cfg = get_config(arch)
    shapes = model_lib.param_shapes(cfg)
    total = active = matmul = enc_matmul = 0.0
    head = float(cfg.vocab_size * cfg.d_model)
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = math.prod(leaf.shape)
        total += n
        keys = [str(getattr(e, "key", getattr(e, "name", ""))) for e in path]
        name = keys[-1]
        a = n
        if name.startswith("experts_") and cfg.moe is not None:
            a = n * cfg.moe.top_k / cfg.moe.num_experts
        active += a
        if name == "embed":          # gather, not matmul (head counted below)
            continue
        matmul += a
        if any(k.startswith("enc_") for k in keys):
            enc_matmul += a
    if cfg.tie_embeddings:
        matmul += head               # tied: the table is also the head matmul
    out = {"total": total, "active": active, "matmul": matmul,
           "enc_matmul": enc_matmul, "head": head}
    _PARAM_CACHE[arch] = out
    return out


def model_flops(rec: dict) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = matmul-active params,
    adjusted for what each step actually computes: prefill evaluates the
    head at the LAST position only, and enc-dec prefill runs the encoder
    over the source but the decoder on a single token."""
    from repro.configs import SHAPES

    shape = SHAPES[rec["shape"]]
    pc = param_counts(rec["arch"])
    b, s = shape.global_batch, shape.seq_len
    if rec["kind"] == "train":
        return 6.0 * pc["matmul"] * b * s
    body = pc["matmul"] - pc["head"]            # per-token matmul params
    if rec["kind"] == "prefill":
        if pc["enc_matmul"] > 0:                 # enc-dec: encoder over S
            return 2.0 * pc["enc_matmul"] * b * s + 2.0 * pc["matmul"] * b
        return 2.0 * body * b * s + 2.0 * pc["head"] * b
    return 2.0 * pc["matmul"] * b               # decode: 1 token/seq, full head


def roofline_terms(rec: dict) -> dict:
    peak = PEAK_FLOPS_INT8 if rec.get("quant_mode", "bf16").startswith("int8") \
        else PEAK_FLOPS_BF16
    cost = rec.get("cost_cal") or rec["cost"]          # depth-calibrated if present
    coll = rec.get("collectives_cal") or rec["collectives"]
    compute = max(cost["flops_per_device"], 0.0) / peak
    memory = max(cost["bytes_accessed_per_device"], 0.0) / HBM_BW
    collective = max(coll["total_bytes"], 0.0) / (LINKS_PER_CHIP * ICI_LINK_BW)
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    hlo_total = cost["flops_per_device"] * rec["devices"]
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "roofline_frac": compute / bound if bound else 0.0,
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total > 0 else 0.0,
        # achieved fraction of peak if the dominant term sets step time
        "mfu_bound": (mf / rec["devices"] / bound) / PEAK_FLOPS_BF16 if bound else 0.0,
    }


def load_records(path: str = "results/dryrun.jsonl") -> dict:
    """Latest ok record per (arch, shape, mesh, quant_mode, tags)."""
    recs: dict[tuple, dict] = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if not r.get("ok"):
                continue
            key = (r["arch"], r["shape"], r["mesh"], r.get("quant_mode", "bf16"),
                   r.get("tag", ""))
            recs[key] = r
    return recs


def run(path: str = "results/dryrun.jsonl", mesh: str = "16x16",
        quant_mode: str | None = "bf16") -> list[str]:
    recs = load_records(path)
    lines = ["", f"=== roofline ({mesh}, v5e: 197TF bf16 / 819GB/s HBM / "
                 f"{LINKS_PER_CHIP}x50GB/s ICI) ==="]
    lines.append(
        f"{'arch':22s} {'shape':12s} {'qm':10s} {'compute_s':>10s} {'memory_s':>10s} "
        f"{'collect_s':>10s} {'dom':>7s} {'rl_frac':>8s} {'useful':>7s} {'mfu_bnd':>8s}")
    rows = [r for k, r in sorted(recs.items())
            if r["mesh"] == mesh and (quant_mode is None or r["quant_mode"] == quant_mode)
            and not r.get("tag")]
    for r in rows:
        t = roofline_terms(r)
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['quant_mode']:10s} "
            f"{t['compute_s']:10.3e} {t['memory_s']:10.3e} {t['collective_s']:10.3e} "
            f"{t['dominant']:>7s} {t['roofline_frac']:8.3f} {t['useful_ratio']:7.3f} "
            f"{t['mfu_bound']:8.4f}")
    if not rows:
        lines.append("(no dry-run records found — run python -m repro.launch.dryrun)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
