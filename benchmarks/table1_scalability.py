"""Benchmark: paper Table I — scalability (N, M) vs data rate & laser power."""

from repro.core.photonic_model import PAPER_TABLE_I, scalability_table


def run() -> list[str]:
    lines = ["", "=== Table I: scalability (N x M per GEMM core) ==="]
    table = scalability_table()
    hdr = f"{'Architecture':16s} " + "".join(
        f"| {dr:>2g} GS/s (ours) | (paper) " for dr in (1.0, 5.0, 10.0)
    )
    lines.append(hdr)
    n_match = n_total = 0
    for row, cells in PAPER_TABLE_I.items():
        parts = [f"{row:16s} "]
        for dr, paper_nm in cells.items():
            ours = table[row][dr]
            ok = ours == paper_nm
            n_match += ok
            n_total += 1
            parts.append(f"| {ours[0]:>4d}x{ours[1]:<3d} {'ok ' if ok else 'XX '} "
                         f"| {paper_nm[0]:>3d}x{paper_nm[1]:<3d} ")
        lines.append("".join(parts))
    lines.append(f"Table I reproduction: {n_match}/{n_total} cells exact")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
