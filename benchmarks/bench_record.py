"""Shared benchmark-record bookkeeping: stamp runs, append across PRs.

Every benchmark writes a ``BENCH_*.json`` of the form

    {"benchmark": "<name>", "runs": [<run>, <run>, ...]}

where each run is stamped with git SHA + UTC date + platform, and new runs
are *appended* so the file accumulates the perf trajectory across PRs
instead of overwriting it.  Legacy single-run files (a dict with a
top-level ``records`` list) are migrated into the first ``runs`` entry on
the next append.
"""

from __future__ import annotations

import datetime
import json
import pathlib
import subprocess


def git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=pathlib.Path(__file__).parent, capture_output=True, text=True,
            timeout=10, check=True,
        )
        return out.stdout.strip()
    except Exception:
        return "unknown"


def run_stamp() -> dict:
    import jax

    return {
        "git_sha": git_sha(),
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "platform": jax.default_backend(),
        "jax_version": jax.__version__,
    }


def append_run(path, benchmark: str, run: dict) -> dict:
    """Stamp ``run`` and append it to ``path``. Returns the stamped run."""
    path = pathlib.Path(path)
    run = {**run_stamp(), **run}
    doc = {"benchmark": benchmark, "runs": []}
    if path.exists():
        try:
            old = json.loads(path.read_text())
        except json.JSONDecodeError:
            # never silently discard the accumulated trajectory: set the
            # unparseable file aside and start a fresh one
            backup = path.with_suffix(path.suffix + ".corrupt")
            path.rename(backup)
            print(f"[bench_record] WARNING: {path} was not valid JSON; "
                  f"moved to {backup} and starting a new trajectory")
            old = {}
        if isinstance(old, dict) and isinstance(old.get("runs"), list):
            doc["runs"] = old["runs"]
        elif isinstance(old, dict) and "records" in old:
            # legacy single-run layout -> first entry of the trajectory
            legacy = {k: v for k, v in old.items() if k != "benchmark"}
            legacy.setdefault("git_sha", "pre-trajectory")
            doc["runs"] = [legacy]
    doc["runs"].append(run)
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return run
