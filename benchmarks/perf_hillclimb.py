"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Runs tagged dry-run variants of the three selected cells and appends them
to ``results/perf.jsonl``; each variant is one hypothesis in the
hypothesis -> change -> measure -> validate loop.

MUST run as its own process (sets the 512-device XLA flag):

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--cell A|B|C]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json      # noqa: E402

OUT = "results/perf.jsonl"


def run(tag: str, **kw):
    from repro.launch.dryrun import run_cell

    rec = run_cell(extra_tags={"tag": tag}, **kw)
    from benchmarks.roofline import roofline_terms

    t = roofline_terms(rec)
    rec["roofline"] = {k: v for k, v in t.items() if k != "model_flops"}
    os.makedirs("results", exist_ok=True)
    with open(OUT, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"[{tag}] compute={t['compute_s']:.3e}s memory={t['memory_s']:.3e}s "
          f"collective={t['collective_s']:.3e}s dom={t['dominant']} "
          f"hbm={rec.get('hbm_per_device_gib')}GiB")
    return rec


# --------------------------------------------------------------------------
# Cell A — paper-representative: mistral-large-123b x train_4k.
# The paper's axis: INT8 GEMM dataflows. bf16 -> int8_deas (prior work) ->
# int8_spoga (paper) -> int8_direct (beyond paper), plus remat & collective
# iterations on the dominant terms.
# --------------------------------------------------------------------------

def cell_a():
    base = dict(arch="mistral-large-123b", shape_name="train_4k",
                multi_pod=False, microbatches=8)
    run("A0_bf16_baseline", quant_mode="bf16", **base)
    run("A1_int8_deas_paper_baseline", quant_mode="int8_deas", **base)
    run("A2_int8_spoga_paper", quant_mode="int8_spoga", **base)
    run("A3_int8_direct_beyond", quant_mode="int8_direct", **base)
    # memory-term iterations on the best dataflow
    run("A4_spoga_remat_dots", quant_mode="int8_spoga", remat_policy="dots", **base)
    run("A5_spoga_bf16_grads", quant_mode="int8_spoga",
        grad_reduce_dtype="bf16", **base)
    run("A6_spoga_mb4", quant_mode="int8_spoga",
        **{**base, "microbatches": 4})


# --------------------------------------------------------------------------
# Cell B — worst roofline fraction: mistral-large-123b x decode_32k
# (memory-bound on KV-cache reads; rl_frac ~0.003).
# --------------------------------------------------------------------------

def cell_b():
    base = dict(arch="mistral-large-123b", shape_name="decode_32k",
                multi_pod=False)
    run("B0_bf16_cache_baseline", quant_mode="bf16", **base)
    run("B1_int8_kv_cache", quant_mode="bf16", kv_cache_dtype="int8", **base)
    run("B2_int8_kv_plus_weights", quant_mode="int8_direct",
        kv_cache_dtype="int8", **base)


# --------------------------------------------------------------------------
# Cell C — most collective-bound: granite-moe-3b-a800m x prefill_32k
# (collective term ~1.2x the memory term at baseline).
# --------------------------------------------------------------------------

def cell_c():
    base = dict(arch="granite-moe-3b-a800m", shape_name="prefill_32k",
                multi_pod=False)
    run("C0_baseline", quant_mode="bf16", **base)
    run("C1_no_fsdp_serving", quant_mode="bf16", fsdp=False, **base)
    run("C2_no_fsdp_int8", quant_mode="int8_spoga", fsdp=False, **base)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "all"])
    args = ap.parse_args()
    if args.cell in ("A", "all"):
        cell_a()
    if args.cell in ("B", "all"):
        cell_b()
    if args.cell in ("C", "all"):
        cell_c()


if __name__ == "__main__":
    main()
