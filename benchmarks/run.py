"""Benchmark harness entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip-kernels]

Sections:
  * Table I   — photonic scalability (N x M vs data rate / laser power)
  * Fig. 5    — FPS / FPS/W / FPS/W/mm2 for SPOGA vs HOLYLIGHT vs DEAPCNN
  * kernels   — INT8 GEMM dataflow comparison (HLO bytes + host timing)
  * roofline  — v5e roofline terms per (arch x shape) from the dry-run
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-kernels", action="store_true",
                    help="skip the XLA-timed kernel section (fast mode)")
    ap.add_argument("--dryrun-jsonl", default="results/dryrun.jsonl")
    args = ap.parse_args()

    from benchmarks import fig5_fps, table1_scalability

    out: list[str] = []
    out += table1_scalability.run()
    out += fig5_fps.run()

    if not args.skip_kernels:
        from benchmarks import kernel_bench

        lines, _records = kernel_bench.run()
        out += lines

    from benchmarks import roofline

    out += roofline.run(args.dryrun_jsonl, mesh="16x16")
    out += roofline.run(args.dryrun_jsonl, mesh="2x16x16")

    print("\n".join(out))


if __name__ == "__main__":
    main()
