"""Benchmark: the three INT8 GEMM dataflows (Sec. III-B / Fig. 2).

Two views:

1. **Analytic TPU HBM traffic** per dataflow, derived from the Pallas
   kernels' BlockSpecs — the architectural quantity SPOGA improves.
   ``deas`` pays an extra 4 int32 intermediate-matrix writes + 4 reads
   (the "ADC + memory + DEAS" pipeline of prior work); ``spoga`` keeps
   partials in VMEM and writes each output tile once.
2. **Host XLA wall-clock** of the algebraically identical jnp paths
   (CPU backend; indicative only — the structural claim is (1)).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spoga import deas_matmul, direct_matmul, spoga_matmul
from repro.kernels.spoga_gemm import DEFAULT_BLOCK_K, DEFAULT_BLOCK_M, DEFAULT_BLOCK_N

SHAPES = ((256, 512, 256), (512, 2048, 512), (1024, 4096, 1024))


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def analytic_hbm_bytes(m: int, k: int, n: int, mode: str) -> int:
    """HBM bytes moved by the Pallas dataflow (BlockSpec-level model)."""
    bm = min(DEFAULT_BLOCK_M, m)
    bn = min(DEFAULT_BLOCK_N, n)
    bk = min(DEFAULT_BLOCK_K, k)
    gm, gn, gk = _ceil(m, bm), _ceil(n, bn), _ceil(k, bk)
    # per K-sweep of one (i, j) tile: x tile + w tile per k step (int8)
    gemm_reads = gm * gn * gk * (bm * bk + bk * bn)
    out_write = gm * gn * (bm * bn) * 4                      # int32
    if mode == "spoga":
        # slicing happens in VMEM; 1 fused sweep, 1 output write
        return gemm_reads + out_write
    if mode == "direct":
        return gemm_reads + out_write
    if mode == "deas":
        # 4 slice GEMMs (each sweeps + writes an int32 intermediate) +
        # DEAS combine re-reading all four and writing the final matrix.
        slice_cost = 4 * (gemm_reads + out_write)
        combine = 4 * (m * n * 4) + m * n * 4
        return slice_cost + combine
    raise ValueError(mode)


def _time(fn, *args, iters: int = 10) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[str]:
    lines = ["", "=== kernel bench: INT8 GEMM dataflows ==="]
    lines.append(f"{'shape':>18s} {'mode':>8s} {'us/call(host)':>14s} "
                 f"{'TPU HBM bytes':>14s} {'vs spoga':>9s}")
    rng = np.random.default_rng(0)
    fns = {
        "deas": jax.jit(deas_matmul),
        "spoga": jax.jit(spoga_matmul),
        "direct": jax.jit(direct_matmul),
    }
    for m, k, n in SHAPES:
        x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        base = analytic_hbm_bytes(m, k, n, "spoga")
        for name, fn in fns.items():
            us = _time(fn, x, w)
            nbytes = analytic_hbm_bytes(m, k, n, name)
            lines.append(f"{f'{m}x{k}x{n}':>18s} {name:>8s} {us:14.1f} "
                         f"{nbytes:14.3e} {nbytes / base:9.2f}x")
    lines.append("(deas/spoga HBM ratio == the intermediate-matrix round trips "
                 "the paper eliminates; Fig. 2a vs 2b)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
