"""Benchmark: the INT8 GEMM dataflows across every registered backend.

Three views (Sec. III-B / Fig. 2):

1. **Analytic TPU HBM traffic** per dataflow, derived from the Pallas
   kernels' BlockSpecs — the architectural quantity SPOGA improves.
   ``deas`` pays an extra 4 int32 intermediate-matrix writes + 4 reads
   (the "ADC + memory + DEAS" pipeline of prior work); ``spoga`` keeps
   partials in VMEM and writes each output tile once.
2. **Host XLA wall-clock** of every registry backend that compiles on this
   platform (the Pallas interpreter is skipped on CPU above tiny shapes —
   it runs the kernel body in Python and would swamp the table).
3. A machine-readable ``BENCH_kernels.json`` next to this file (override
   with ``--out``): per-backend, per-shape timings + analytic bytes. Each
   invocation APPENDS a run stamped with git SHA + date (``bench_record``),
   so the file accumulates the perf trajectory across PRs.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--out PATH] [--quick]
"""

import argparse
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from bench_record import append_run  # noqa: E402

from repro.backends import list_backends, resolve_backend
from repro.kernels.spoga_gemm import DEFAULT_BLOCK_K, DEFAULT_BLOCK_M, DEFAULT_BLOCK_N

SHAPES = ((256, 512, 256), (512, 2048, 512), (1024, 4096, 1024))
QUICK_SHAPES = ((256, 512, 256),)

# Pallas-interpreter backends execute the kernel body in Python; on
# non-TPU hosts only time them on the smallest shape.
_INTERPRETED_OFF_TPU = ("pallas_spoga", "pallas_spoga_dequant", "pallas_deas",
                        "pallas_interpret")


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def analytic_hbm_bytes(m: int, k: int, n: int, family: str) -> int:
    """HBM bytes moved by the Pallas dataflow (BlockSpec-level model)."""
    bm = min(DEFAULT_BLOCK_M, m)
    bn = min(DEFAULT_BLOCK_N, n)
    bk = min(DEFAULT_BLOCK_K, k)
    gm, gn, gk = _ceil(m, bm), _ceil(n, bn), _ceil(k, bk)
    # per K-sweep of one (i, j) tile: x tile + w tile per k step (int8)
    gemm_reads = gm * gn * gk * (bm * bk + bk * bn)
    out_write = gm * gn * (bm * bn) * 4                      # int32
    if family in ("spoga", "direct"):
        # slicing happens in VMEM; 1 fused sweep, 1 output write
        return gemm_reads + out_write
    if family == "deas":
        # 4 slice GEMMs (each sweeps + writes an int32 intermediate) +
        # DEAS combine re-reading all four and writing the final matrix.
        slice_cost = 4 * (gemm_reads + out_write)
        combine = 4 * (m * n * 4) + m * n * 4
        return slice_cost + combine
    raise ValueError(family)


def _time(fn, *args, iters: int = 10) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(shapes=SHAPES) -> tuple[list[str], list[dict]]:
    on_tpu = jax.default_backend() == "tpu"
    lines = ["", "=== kernel bench: INT8 GEMM dataflows x backend registry ==="]
    lines.append(f"{'shape':>18s} {'backend':>22s} {'us/call':>12s} "
                 f"{'TPU HBM bytes':>14s} {'vs spoga':>9s}")
    rng = np.random.default_rng(0)
    records = []
    for m, k, n in shapes:
        x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        base = analytic_hbm_bytes(m, k, n, "spoga")
        for name in list_backends():
            backend, spec = resolve_backend("int8_spoga", name)
            nbytes = analytic_hbm_bytes(m, k, n, backend.family)
            rec = {
                "bench": "int8_gemm",
                "backend": name,
                "family": backend.family,
                "shape": [m, k, n],
                "analytic_hbm_bytes": nbytes,
                "hbm_vs_spoga": round(nbytes / base, 3),
                "platform": jax.default_backend(),
                "us_per_call": None,
            }
            timed = on_tpu or name not in _INTERPRETED_OFF_TPU \
                or (m, k, n) == min(shapes)
            if timed:
                fn = jax.jit(lambda a, b, _b=backend, _s=spec: _b.gemm(a, b, _s))
                rec["us_per_call"] = round(_time(fn, x, w), 1)
            us = f"{rec['us_per_call']:12.1f}" if rec["us_per_call"] is not None \
                else f"{'(skipped)':>12s}"
            lines.append(f"{f'{m}x{k}x{n}':>18s} {name:>22s} {us} "
                         f"{nbytes:14.3e} {nbytes / base:9.2f}x")
            records.append(rec)
    lines.append("(deas/spoga HBM ratio == the intermediate-matrix round trips "
                 "the paper eliminates; Fig. 2a vs 2b. Interpreted Pallas "
                 "backends are timed only on the smallest shape off-TPU.)")
    return lines, records


def main():
    ap = argparse.ArgumentParser()
    default_out = pathlib.Path(__file__).parent / "BENCH_kernels.json"
    ap.add_argument("--out", default=str(default_out),
                    help="machine-readable results path (JSON)")
    ap.add_argument("--quick", action="store_true",
                    help="smallest shape only (CI-friendly)")
    args = ap.parse_args()
    lines, records = run(QUICK_SHAPES if args.quick else SHAPES)
    print("\n".join(lines))
    stamped = append_run(args.out, "kernel_bench",
                         {"quick": args.quick, "records": records})
    print(f"appended {len(records)} records to {args.out} "
          f"(sha {stamped['git_sha']}, {stamped['date']})")


if __name__ == "__main__":
    main()
