"""Serving benchmark: continuous batching vs the static-batch baseline,
and the paged KV cache vs the slot cache at a fixed KV budget.

A mixed-length workload (bimodal generation budgets — the realistic case
that kills lockstep batching) is served over identical requests:

* **static** — FIFO groups of ``slots`` requests through
  ``repro.api.serve_batch``: prompts padded to a common length, every
  lane decodes until the *longest* budget in its group finishes (finished
  lanes burn compute), next group waits for the whole previous one.
* **engine** — the continuous-batching engine via the ``repro.api.LLM``
  facade: slot-based KV cache, finished lanes evicted and refilled from
  the queue each step, prefill interleaved with decode.
* **paged**  — the same facade on ``KVConfig(mode="paged")`` with the
  *same page budget* the slot pool would occupy, but more lanes: requests
  reserve their own worst case instead of the global ``cache_len``, so
  mixed-length traffic packs strictly more concurrent requests into the
  same KV memory (the ``peak_running`` column).

Throughput counts *useful* tokens only (each request's own budget), so the
static baseline is not charged for the padded garbage it produces — the
gap measured is pure scheduling, the batch-level analogue of the dataflow
utilization SPOGA argues for at the GEMM level.

``--prefix`` switches to the shared-prefix sweep: every request carries
the same system prompt plus a unique tail, served twice from the same
paged pool — ``KVConfig(prefix_cache=True)`` vs cold — to measure what
the radix-tree prefix cache (``repro/prefix/``) buys in tok/s and TTFT.

``--spec`` switches to the speculative-decoding sweep: a repetitive
(draftable) workload served spec-on vs spec-off from the same paged pool,
measuring the tok/s win and draft acceptance rate of the prompt-lookup
draft-verify loop (``repro/spec/``).

``--tp N`` switches to the tensor-parallel sweep: the same paged workload
served at tp=1 vs tp=N over a "model"-axis device mesh (``repro/shard``),
recording the ``tp_speedup`` scaling cell.

Appends a stamped run (git SHA + date) to ``BENCH_serve.json``:

    PYTHONPATH=src python benchmarks/serve_bench.py [--quick] [--prefix|--spec|--tp N] [--out PATH]
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from bench_record import append_run  # noqa: E402

from repro.api import (
    LLM,
    KVConfig,
    MeshConfig,
    QuantRuntime,
    RuntimeConfig,
    SchedulerConfig,
    SpecConfig,
    serve_batch,
)
from repro.serving.sampling import SamplingParams
from repro.configs import (
    default_cache_len,
    default_page_count,
    get_config,
    reduced,
)
from repro.models import init_params

PAGE_SIZE = 16


def make_workload(cfg, n_requests: int, prompt_len: int, gen: int, seed: int = 0):
    """(prompt, budget) pairs: prompts in [prompt_len/2, prompt_len], budgets
    bimodal {gen/4, gen} — short interactive turns mixed with long ones."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        budget = int(gen if i % 2 == 0 else max(1, gen // 4))
        reqs.append((rng.integers(0, cfg.vocab_size, plen).tolist(), budget))
    return reqs


def run_static(cfg, params, workload, slots: int, prompt_len: int, cache_len: int):
    """FIFO groups of ``slots``; one rectangular serve_batch per group."""
    useful = 0
    ttfts = []
    per_tok = []
    t_start = time.perf_counter()
    prefill_s = decode_s = 0.0
    steps = 0
    for g0 in range(0, len(workload), slots):
        group = workload[g0:g0 + slots]
        gen = max(b for _, b in group)
        toks = np.zeros((len(group), prompt_len), np.int32)
        for i, (p, _) in enumerate(group):
            toks[i, :len(p)] = p  # static batching right-pads the prompt
        _, stats = serve_batch(cfg, params, {"tokens": jnp.asarray(toks)},
                               cache_len=cache_len, gen_tokens=gen)
        prefill_s += stats["prefill_s"]
        decode_s += stats["decode_s"]
        steps += gen
        useful += sum(b for _, b in group)
        # every request in the group sees its first token when the group's
        # prefill returns; earlier groups delay later ones head-of-line
        ttfts += [time.perf_counter() - t_start - stats["decode_s"]] * len(group)
        # lockstep decode: every lane advances one token per group step,
        # so each request's per-token latency is the group's step time
        per_tok += [stats["decode_s"] / max(gen, 1)] * len(group)
    wall = time.perf_counter() - t_start
    return {
        "mode": "static",
        "requests": len(workload),
        "generated_tokens": useful,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(useful / wall, 2),
        "decode_steps": steps,
        "prefill_s": round(prefill_s, 4),
        "decode_s": round(decode_s, 4),
        "ttft_mean_s": round(float(np.mean(ttfts)), 4),
        "ttft_max_s": round(float(np.max(ttfts)), 4),
        "ttft_p50_s": round(float(np.percentile(ttfts, 50)), 4),
        "ttft_p95_s": round(float(np.percentile(ttfts, 95)), 4),
        "ttft_p99_s": round(float(np.percentile(ttfts, 99)), 4),
        "per_token_p50_s": round(float(np.percentile(per_tok, 50)), 5),
        "per_token_p95_s": round(float(np.percentile(per_tok, 95)), 5),
        "per_token_p99_s": round(float(np.percentile(per_tok, 99)), 5),
    }


def run_engine(cfg, params, workload, slots: int, cache_len: int, buckets,
               stagger: int = 0, quant_mode: str = "bf16",
               kv_dtype: str = "bf16", prefill_chunk=None, spec=None,
               deadline=None, tp: int = 1, **kv_kw):
    """One facade cell: the RuntimeConfig IS the cell description.
    ``deadline`` attaches an SLO deadline (seconds from submit) to every
    request so the record carries goodput / hit-miss accounting; ``tp``
    shards the cell over a tensor-parallel device mesh (repro/shard)."""
    runtime = RuntimeConfig(
        quant=QuantRuntime(mode=quant_mode),
        kv=KVConfig(dtype=kv_dtype, cache_len=cache_len, **kv_kw),
        scheduler=SchedulerConfig(n_slots=slots, prefill_buckets=buckets,
                                  prefill_chunk=prefill_chunk),
        spec=spec if spec is not None else SpecConfig(),
        mesh=MeshConfig(tp=tp),
    )
    llm = LLM(config=cfg, params=params, runtime=runtime)
    if deadline is not None:
        sp = SamplingParams(deadline_s=deadline)
        arrivals = [(i * stagger, p, b, sp)
                    for i, (p, b) in enumerate(workload)]
    else:
        arrivals = [(i * stagger, p, b) for i, (p, b) in enumerate(workload)]
    metrics = llm.engine.run(arrivals)
    rep = metrics.report()
    if spec is not None and spec.enabled:
        rep["mode"] = "paged+spec"
    elif kv_kw.get("prefix_cache"):
        rep["mode"] = "paged+prefix"
    elif kv_kw.get("mode") == "paged":
        rep["mode"] = "paged"
    else:
        rep["mode"] = "engine"
    rep["stagger"] = stagger
    return rep


def make_prefix_workload(cfg, n_requests: int, shared_len: int, tail_len: int,
                         gen: int, seed: int = 0):
    """Every request = one shared system prompt + a unique tail — the
    production shape (few-shot templates, system prompts) the prefix cache
    targets.  Budgets stay bimodal like the main workload."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, shared_len).tolist()
    reqs = []
    for i in range(n_requests):
        tlen = int(rng.integers(max(1, tail_len // 2), tail_len + 1))
        budget = int(gen if i % 2 == 0 else max(1, gen // 4))
        reqs.append((prefix + rng.integers(0, cfg.vocab_size, tlen).tolist(),
                     budget))
    return reqs


def prefix_sweep(cfg, params, args, out_path: str) -> None:
    """Shared-prefix workload, cached vs cold at the SAME page budget: both
    cells serve the identical requests from the identical paged pool with
    chunked admission; the only difference is ``KVConfig.prefix_cache``.
    The cached cell skips every shared page's prefill after the first
    request, so it wins tok/s and (especially) TTFT."""
    shared_len = args.shared_prefix
    prompt_len = shared_len + args.prompt_len
    cache_len = default_cache_len(prompt_len, args.gen)
    slots = 2 if args.quick else max(int(s) for s in args.slots.split(","))
    kw = dict(
        quant_mode=args.quant_mode, kv_dtype=args.kv_cache_dtype,
        prefill_chunk=PAGE_SIZE, mode="paged", page_size=PAGE_SIZE,
        n_pages=default_page_count(slots, cache_len, PAGE_SIZE),
    )
    workload = make_prefix_workload(cfg, args.requests, shared_len,
                                    args.prompt_len, args.gen)
    print(f"=== prefix sweep: {cfg.name} | {args.requests} requests, "
          f"{shared_len}-token shared prefix + tails<={args.prompt_len}, "
          f"{slots} lanes, kv={args.kv_cache_dtype} ===")
    records = []
    warm = [(p, 2) for p, _ in workload[:slots]]
    for prefix_on in (False, True):
        run_engine(cfg, params, warm, slots, cache_len, None,
                   prefix_cache=prefix_on, **kw)
        rec = max((run_engine(cfg, params, workload, slots, cache_len, None,
                              prefix_cache=prefix_on, **kw)
                   for _ in range(args.repeats)),
                  key=lambda r: r["tokens_per_s"])
        rec["slots"] = slots
        records.append(rec)
        tag = "cached" if prefix_on else "cold"
        print(f"{tag:>8s} {rec['tokens_per_s']:8.1f} tok/s | "
              f"TTFT mean {rec['ttft_mean_s']*1e3:7.1f}ms "
              f"p99 {rec['ttft_p99_s']*1e3:7.1f}ms "
              f"max {rec['ttft_max_s']*1e3:7.1f}ms | "
              f"{rec['prefix_hits']} hits, {rec['prefix_hit_tokens']} prompt "
              f"tokens reused, {rec['prefix_cow_forks']} forks")
    cold, cached = records
    run = {
        "arch": cfg.name,
        "config": {
            "requests": args.requests, "shared_prefix": shared_len,
            "tail_len": args.prompt_len, "gen": args.gen, "lanes": slots,
            "kv_cache_dtype": args.kv_cache_dtype,
            "quant_mode": args.quant_mode, "reduced": not args.full,
        },
        "speedup_vs_cold": round(cached["tokens_per_s"]
                                 / max(cold["tokens_per_s"], 1e-9), 3),
        "ttft_ratio_vs_cold": round(cached["ttft_mean_s"]
                                    / max(cold["ttft_mean_s"], 1e-9), 3),
        "ttft_p99_ratio_vs_cold": round(cached["ttft_p99_s"]
                                        / max(cold["ttft_p99_s"], 1e-9), 3),
        "records": records,
    }
    print(f"prefix cache: {run['speedup_vs_cold']:.2f}x tok/s, "
          f"TTFT mean {run['ttft_ratio_vs_cold']:.2f}x / "
          f"p99 {run['ttft_p99_ratio_vs_cold']:.2f}x vs cold at the same "
          f"page budget")
    stamped = append_run(out_path, "serve_bench_prefix", run)
    print(f"appended run to {out_path} (sha {stamped['git_sha']}, "
          f"{stamped['date']})")


def make_repetitive_workload(cfg, n_requests: int, prompt_len: int, gen: int,
                             seed: int = 0, period: int = 8):
    """Prompts that are a short random pattern tiled to ``prompt_len`` —
    the structured-text shape (templated output, code, extraction) where
    prompt-lookup drafting shines: the continuation keeps reciting n-grams
    already present in the context."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        pattern = rng.integers(0, cfg.vocab_size, period).tolist()
        plen = int(rng.integers(max(period + 1, prompt_len // 2),
                                prompt_len + 1))
        reps = -(-plen // period)
        budget = int(gen if i % 2 == 0 else max(1, gen // 4))
        reqs.append(((pattern * reps)[:plen], budget))
    return reqs


def spec_sweep(cfg, params, args, out_path: str) -> None:
    """Speculative decoding on the paged engine, spec-on vs spec-off at the
    SAME pool budget on a repetitive (draftable) workload.  The spec cell
    drafts ``k`` tokens per lane with the model-free prompt-lookup n-gram
    drafter and verifies them in ONE batched dispatch; the win is decode
    dispatches shrinking by ~(1 + acceptance * k) while greedy outputs stay
    bitwise identical (the engine's exactness tests pin that separately)."""
    cache_len = default_cache_len(args.prompt_len, args.gen)
    # speculation attacks per-step dispatch overhead, which dominates at
    # LOW concurrency (wide batches amortize it away) — sweep the smallest
    # configured lane count, the regime the feature is for
    slots = 2 if args.quick else min(int(s) for s in args.slots.split(","))
    kw = dict(
        quant_mode=args.quant_mode, kv_dtype=args.kv_cache_dtype,
        prefill_chunk=PAGE_SIZE, mode="paged", page_size=PAGE_SIZE,
        n_pages=default_page_count(slots, cache_len, PAGE_SIZE),
    )
    spec = SpecConfig(enabled=True, k=args.spec_k, drafter="ngram")
    workload = make_repetitive_workload(cfg, args.requests, args.prompt_len,
                                        args.gen)
    print(f"=== spec sweep: {cfg.name} | {args.requests} requests, "
          f"repetitive prompts<={args.prompt_len}, k={args.spec_k}, "
          f"{slots} lanes, kv={args.kv_cache_dtype} ===")
    records = []
    warm = [(p, 2) for p, _ in workload[:slots]]
    for cell_spec in (None, spec):
        run_engine(cfg, params, warm, slots, cache_len, None,
                   spec=cell_spec, **kw)
        rec = max((run_engine(cfg, params, workload, slots, cache_len, None,
                              spec=cell_spec, **kw)
                   for _ in range(args.repeats)),
                  key=lambda r: r["tokens_per_s"])
        rec["slots"] = slots
        records.append(rec)
        tag = "spec" if cell_spec is not None else "plain"
        print(f"{tag:>8s} {rec['tokens_per_s']:8.1f} tok/s | "
              f"{rec['decode_steps']:4d} decode dispatches | "
              f"accept {rec['spec_accepted']}/{rec['spec_proposed']} "
              f"(rate {rec['acceptance_rate']:.2f})")
    plain, spec_rec = records
    run = {
        "arch": cfg.name,
        "config": {
            "requests": args.requests, "prompt_len": args.prompt_len,
            "gen": args.gen, "lanes": slots, "k": args.spec_k,
            "drafter": "ngram", "kv_cache_dtype": args.kv_cache_dtype,
            "quant_mode": args.quant_mode, "reduced": not args.full,
        },
        "speedup_vs_plain": round(spec_rec["tokens_per_s"]
                                  / max(plain["tokens_per_s"], 1e-9), 3),
        "acceptance_rate": spec_rec["acceptance_rate"],
        "dispatch_ratio": round(plain["decode_steps"]
                                / max(spec_rec["decode_steps"], 1), 3),
        "records": records,
    }
    print(f"speculative decoding: {run['speedup_vs_plain']:.2f}x tok/s at "
          f"acceptance {run['acceptance_rate']:.2f} "
          f"({run['dispatch_ratio']:.1f}x fewer decode dispatches)")
    stamped = append_run(out_path, "serve_bench_spec", run)
    print(f"appended run to {out_path} (sha {stamped['git_sha']}, "
          f"{stamped['date']})")


def tp_sweep(cfg, params, args, out_path: str) -> None:
    """Tensor-parallel scaling cell: the SAME paged workload served at
    tp=1 vs tp=N from the same per-run pool budget (repro/shard threads a
    "model"-axis mesh through params, attention heads, experts and the KV
    pool; block tables stay host-side).  On a real multi-chip mesh
    ``tp_speedup`` measures TP scaling; on a forced host mesh (CI:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) the devices
    share one CPU, so the cell is a *correctness + dispatch-overhead*
    record, not a perf claim — bench_check gates only that the ratio
    stays within prior bounds."""
    tp = args.tp
    if jax.device_count() % tp:
        raise SystemExit(
            f"--tp {tp} needs jax.device_count() ({jax.device_count()}) "
            f"divisible by tp; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} to fake "
            f"a host mesh")
    cache_len = default_cache_len(args.prompt_len, args.gen)
    slots = 2 if args.quick else min(int(s) for s in args.slots.split(","))
    kw = dict(
        quant_mode=args.quant_mode, kv_dtype=args.kv_cache_dtype,
        prefill_chunk=PAGE_SIZE, mode="paged", page_size=PAGE_SIZE,
        n_pages=default_page_count(slots, cache_len, PAGE_SIZE),
    )
    workload = make_workload(cfg, args.requests, args.prompt_len, args.gen)
    print(f"=== tp sweep: {cfg.name} | {args.requests} requests, "
          f"prompts<={args.prompt_len}, {slots} lanes, tp 1 vs {tp}, "
          f"{jax.device_count()} devices ===")
    records = []
    warm = [(p, 2) for p, _ in workload[:slots]]
    for cell_tp in (1, tp):
        run_engine(cfg, params, warm, slots, cache_len, None,
                   tp=cell_tp, **kw)
        rec = max((run_engine(cfg, params, workload, slots, cache_len, None,
                              tp=cell_tp, **kw)
                   for _ in range(args.repeats)),
                  key=lambda r: r["tokens_per_s"])
        rec["mode"] = f"paged tp={cell_tp}"
        rec["slots"], rec["tp"] = slots, cell_tp
        records.append(rec)
        print(f"{'tp=' + str(cell_tp):>8s} {rec['tokens_per_s']:8.1f} tok/s | "
              f"{rec['decode_steps']:4d} decode dispatches | "
              f"TTFT mean {rec['ttft_mean_s']*1e3:7.1f}ms "
              f"p99 {rec['ttft_p99_s']*1e3:7.1f}ms")
    base, sharded = records
    run = {
        "arch": cfg.name,
        "config": {
            "requests": args.requests, "prompt_len": args.prompt_len,
            "gen": args.gen, "lanes": slots, "tp": tp,
            "devices": jax.device_count(),
            "kv_cache_dtype": args.kv_cache_dtype,
            "quant_mode": args.quant_mode, "reduced": not args.full,
        },
        "tp_speedup": round(sharded["tokens_per_s"]
                            / max(base["tokens_per_s"], 1e-9), 3),
        "records": records,
    }
    print(f"tensor parallel: {run['tp_speedup']:.2f}x tok/s at tp={tp} vs "
          f"tp=1 (host-mesh runs measure dispatch overhead, not scaling)")
    stamped = append_run(out_path, "serve_bench_tp", run)
    print(f"appended run to {out_path} (sha {stamped['git_sha']}, "
          f"{stamped['date']})")


def paged_kw(slots: int, cache_len: int, n_requests: int):
    """Paged engine at the *slot pool's* KV budget: same page count the
    slot cache would pin (``slots`` worst-case lanes), but lane count
    unconstrained by memory — admission reserves per-request worst cases,
    so concurrency is bounded by actual lengths, not by ``cache_len``."""
    return dict(
        mode="paged",
        page_size=PAGE_SIZE,
        n_pages=default_page_count(slots, cache_len, PAGE_SIZE),
    ), min(max(2 * slots, slots + 1), n_requests)


def main():
    ap = argparse.ArgumentParser()
    default_out = pathlib.Path(__file__).parent / "BENCH_serve.json"
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true",
                    help="published config (default: reduced smoke size)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32,
                    help="long budget; short requests get gen/4 (decode-"
                         "dominated mix — where scheduling matters)")
    ap.add_argument("--slots", default="2,4",
                    help="comma-separated slot counts to sweep")
    ap.add_argument("--staggers", default="0,2",
                    help="comma-separated arrival staggers (engine only)")
    ap.add_argument("--kv-cache-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--quant-mode", default="bf16")
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N per cell (robust to background load)")
    ap.add_argument("--quick", action="store_true",
                    help="single cell, small workload (CI-friendly)")
    ap.add_argument("--prefix", action="store_true",
                    help="shared-prefix sweep instead: cached vs cold paged "
                         "serving of a common-system-prompt workload")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decoding sweep instead: spec-on vs "
                         "spec-off paged serving of a repetitive workload")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="spec sweep: drafted tokens per verify dispatch")
    ap.add_argument("--tp", type=int, default=0, metavar="N",
                    help="tensor-parallel sweep instead: paged serving at "
                         "tp=1 vs tp=N (repro/shard; needs device_count "
                         "divisible by N — force a host mesh with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--shared-prefix", type=int, default=48,
                    help="prefix sweep: shared system-prompt length "
                         "(prompt-len becomes the unique tail length)")
    ap.add_argument("--out", default=str(default_out))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    cfg = cfg.with_(remat=False)
    # resolve the model-side runtime knobs ONCE so every cell (and the
    # static baseline) shares the identical jit-hashable ModelConfig
    cfg = RuntimeConfig(
        quant=QuantRuntime(mode=args.quant_mode),
        kv=KVConfig(dtype=args.kv_cache_dtype),
    ).resolve_model(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))

    if args.prefix:
        if args.quick:
            args.requests = min(args.requests, 6)
            args.repeats = min(args.repeats, 2)
            args.shared_prefix = min(args.shared_prefix, 32)
        prefix_sweep(cfg, params, args, args.out)
        return

    if args.spec:
        if args.quick:
            args.requests = min(args.requests, 6)
            args.repeats = min(args.repeats, 2)
        spec_sweep(cfg, params, args, args.out)
        return

    if args.tp:
        if args.quick:
            args.requests = min(args.requests, 6)
            args.repeats = min(args.repeats, 2)
        tp_sweep(cfg, params, args, args.out)
        return

    cache_len = default_cache_len(args.prompt_len, args.gen)
    buckets = (args.prompt_len,)  # one prefill trace; static pads to the same
    cell_kw = dict(quant_mode=args.quant_mode, kv_dtype=args.kv_cache_dtype)

    if args.quick:
        slot_sweep, stagger_sweep = [2], [0]
        args.requests = min(args.requests, 6)
        args.repeats = min(args.repeats, 2)
    else:
        slot_sweep = [int(s) for s in args.slots.split(",")]
        stagger_sweep = [int(s) for s in args.staggers.split(",")]

    workload = make_workload(cfg, args.requests, args.prompt_len, args.gen)
    records = []
    print(f"=== serve bench: {cfg.name} | {args.requests} requests, "
          f"prompts<={args.prompt_len}, budgets {{{max(1, args.gen//4)},{args.gen}}}, "
          f"kv={args.kv_cache_dtype} ===")
    print(f"{'mode':>8s} {'slots':>6s} {'stagger':>8s} {'tok/s':>8s} "
          f"{'steps':>6s} {'TTFT-mean':>10s} {'TTFT-p99':>9s} {'TTFT-max':>9s}")
    for slots in slot_sweep:
        # warm both paths' jit caches at THIS slot count (prefill/decode
        # shapes depend on it) so compile time never lands in the comparison;
        # 2-token budgets keep the warmup to a couple of steps per shape.
        # Static also compiles a (requests % slots)-wide prefill for its
        # final partial group — warm that shape too.
        warm = [(p, 2) for p, _ in (workload * slots)[:slots]]
        run_static(cfg, params, warm, slots, args.prompt_len, cache_len)
        if args.requests % slots:
            run_static(cfg, params, warm[:args.requests % slots], slots,
                       args.prompt_len, cache_len)
        run_engine(cfg, params, warm, slots, cache_len, buckets, **cell_kw)

        # best-of-N: wall-clock on a shared host is noisy; the fastest
        # repetition is the least-perturbed measurement of each schedule
        rec = max((run_static(cfg, params, workload, slots, args.prompt_len,
                              cache_len) for _ in range(args.repeats)),
                  key=lambda r: r["tokens_per_s"])
        rec["slots"], rec["repeats"] = slots, args.repeats
        records.append(rec)
        print(f"{'static':>8s} {slots:6d} {'-':>8s} {rec['tokens_per_s']:8.1f} "
              f"{rec['decode_steps']:6d} {rec['ttft_mean_s']:10.3f} "
              f"{rec['ttft_p99_s']:9.3f} {rec['ttft_max_s']:9.3f}")
        for stagger in stagger_sweep:
            rec = max((run_engine(cfg, params, workload, slots, cache_len,
                                  buckets, stagger, **cell_kw)
                       for _ in range(args.repeats)),
                      key=lambda r: r["tokens_per_s"])
            rec["slots"], rec["repeats"] = slots, args.repeats
            records.append(rec)
            print(f"{'engine':>8s} {slots:6d} {stagger:8d} "
                  f"{rec['tokens_per_s']:8.1f} {rec['decode_steps']:6d} "
                  f"{rec['ttft_mean_s']:10.3f} {rec['ttft_p99_s']:9.3f} "
                  f"{rec['ttft_max_s']:9.3f}")

        # paged sweep: SAME page budget as the slot pool above, more lanes
        pkw, lanes = paged_kw(slots, cache_len, args.requests)
        run_engine(cfg, params, warm, lanes, cache_len, buckets, 0,
                   **cell_kw, **pkw)
        rec = max((run_engine(cfg, params, workload, lanes, cache_len,
                              buckets, 0, **cell_kw, **pkw)
                   for _ in range(args.repeats)),
                  key=lambda r: r["tokens_per_s"])
        rec["slots"], rec["lanes"], rec["repeats"] = slots, lanes, args.repeats
        records.append(rec)
        print(f"{'paged':>8s} {slots:6d} {0:8d} {rec['tokens_per_s']:8.1f} "
              f"{rec['decode_steps']:6d} {rec['ttft_mean_s']:10.3f} "
              f"{rec['ttft_p99_s']:9.3f} {rec['ttft_max_s']:9.3f}   "
              f"peak {rec['peak_running']} lanes in {rec['pages_total']} pages")

    # SLO/goodput cell: the overload regime — every request arrives at t=0
    # into the SMALLEST lane count, so queue waits dominate the tail.  The
    # deadline is calibrated on this host from the same cell's measured
    # no-deadline latency (1.5x the mean), which lands between the early
    # groups (hit) and the deeply queued tail (miss) — so the goodput
    # fraction measures the scheduler's deadline behaviour, not the
    # machine's absolute speed, and gates as a ratio in bench_check.
    slots = min(slot_sweep)
    calib = next(r for r in records if r["mode"] == "engine"
                 and r["slots"] == slots and r["stagger"] == 0)
    deadline = max(1.5 * calib["latency_mean_s"], 1e-3)
    rec = max((run_engine(cfg, params, workload, slots, cache_len, buckets,
                          0, deadline=deadline, **cell_kw)
               for _ in range(args.repeats)),
              key=lambda r: r["goodput_tokens_per_s"])
    rec["mode"], rec["slots"] = "overload", slots
    rec["deadline_s"] = round(deadline, 4)
    rec["repeats"] = args.repeats
    records.append(rec)
    goodput_frac = (rec["goodput_tokens_per_s"]
                    / max(rec["tokens_per_s"], 1e-9))
    static_p99 = next(r["ttft_p99_s"] for r in records
                      if r["mode"] == "static" and r["slots"] == slots)
    overload_p99_ratio = rec["ttft_p99_s"] / max(static_p99, 1e-9)
    print(f"{'overload':>8s} {slots:6d} {0:8d} {rec['tokens_per_s']:8.1f} "
          f"{rec['decode_steps']:6d} {rec['ttft_mean_s']:10.3f} "
          f"{rec['ttft_p99_s']:9.3f} {rec['ttft_max_s']:9.3f}   "
          f"deadline {deadline*1e3:.0f}ms: {rec['deadline_hits']} hit / "
          f"{rec['deadline_misses']} missed, goodput "
          f"{rec['goodput_tokens_per_s']:.1f} tok/s "
          f"({goodput_frac:.2f} of total)")

    # headline: per-slot-count ratio of the engine's best arrival pattern vs
    # static's best case (all requests available at t=0 — static cannot even
    # express staggered arrivals without waiting to fill a batch). The
    # conservative minimum across slot counts is the reported speedup.
    ratios = {}
    for slots in slot_sweep:
        s = next(r["tokens_per_s"] for r in records
                 if r["mode"] == "static" and r["slots"] == slots)
        e = max(r["tokens_per_s"] for r in records
                if r["mode"] == "engine" and r["slots"] == slots)
        ratios[slots] = e / s
    speedup = min(ratios.values())
    print("continuous/static tokens-per-s: "
          + ", ".join(f"{r:.2f}x @ {s} slots" for s, r in ratios.items())
          + " (mixed budgets; finished lanes refill instead of idling)")

    # tail-latency headline: engine p99 TTFT over static p99 TTFT (LOWER is
    # better — interleaved prefill admits late arrivals without waiting for
    # the whole previous group).  The conservative maximum across slot
    # counts is the reported ratio; bench_check gates it with the
    # lower-is-better direction.
    ttft_ratios = {}
    for slots in slot_sweep:
        s = next(r["ttft_p99_s"] for r in records
                 if r["mode"] == "static" and r["slots"] == slots)
        e = min(r["ttft_p99_s"] for r in records
                if r["mode"] == "engine" and r["slots"] == slots)
        ttft_ratios[slots] = e / max(s, 1e-9)
    print("engine/static TTFT p99: "
          + ", ".join(f"{r:.2f}x @ {s} slots" for s, r in ttft_ratios.items())
          + " (lower is better)")

    # paged headline: concurrency at the slot pool's KV budget — the slot
    # cache can NEVER exceed `slots` concurrent requests in that memory;
    # the paged pool packs by actual lengths
    paged_conc = {}
    for slots in slot_sweep:
        p = next(r for r in records
                 if r["mode"] == "paged" and r["slots"] == slots)
        paged_conc[slots] = (p["peak_running"], p["tokens_per_s"])
    print("paged concurrency at the slot KV budget: "
          + ", ".join(f"{c} lanes vs {s} slots ({t:.1f} tok/s)"
                      for s, (c, t) in paged_conc.items()))

    run = {
        "arch": cfg.name,
        "config": {
            "requests": args.requests, "prompt_len": args.prompt_len,
            "gen": args.gen, "kv_cache_dtype": args.kv_cache_dtype,
            "quant_mode": args.quant_mode, "reduced": not args.full,
        },
        "speedup_vs_static": round(speedup, 3),
        "speedup_by_slots": {str(s): round(r, 3) for s, r in ratios.items()},
        "ttft_p99_vs_static": round(max(ttft_ratios.values()), 3),
        "ttft_p99_by_slots": {str(s): round(r, 3)
                              for s, r in ttft_ratios.items()},
        # SLO headlines (overload cell): deadline-respecting share of
        # throughput, and the overloaded engine's p99 TTFT over static's —
        # both host-independent ratios; bench_check gates them
        "goodput_frac_overload": round(goodput_frac, 3),
        "ttft_p99_overload_vs_static": round(overload_p99_ratio, 3),
        "paged_peak_lanes_by_slots": {str(s): c for s, (c, _) in paged_conc.items()},
        "records": records,
    }
    stamped = append_run(args.out, "serve_bench", run)
    print(f"appended run to {args.out} (sha {stamped['git_sha']}, "
          f"{stamped['date']})")


if __name__ == "__main__":
    main()
