"""Replay a flight-recorder bundle and verify it reproduces bitwise.

    PYTHONPATH=src python -m repro.launch.replay BUNDLE_DIR

Rebuilds the recorded engine from the bundle's manifest, re-feeds the
recorded arrivals on their recorded step schedule with the recorded
decision clock scripted back, and compares greedy token streams and the
scheduler decision journal event-by-event.  Exit 0 iff the replay is
bitwise identical; otherwise the first divergent decision is printed with
both contexts (see ``repro.obs.replay.diff_journals``).

Record a bundle with ``serve --record DIR`` or
``ObsConfig(record_path=DIR)``.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.replay import replay_bundle


def main() -> int:
    ap = argparse.ArgumentParser(
        description="replay a flight-recorder bundle and check it "
                    "reproduces the recorded run bitwise")
    ap.add_argument("bundle", help="bundle directory (serve --record DIR)")
    ap.add_argument("--max-steps", type=int, default=100_000,
                    help="engine-step cap so a divergent replay that can "
                         "never drain still terminates")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable verdict instead of text")
    args = ap.parse_args()

    res = replay_bundle(args.bundle, max_steps=args.max_steps)
    if args.json:
        doc = {
            "bundle": res.bundle,
            "ok": res.ok,
            "n_requests": res.n_requests,
            "n_recorded_events": res.n_recorded_events,
            "n_replayed_events": res.n_replayed_events,
            "token_mismatches": res.token_mismatches,
            "divergence": (res.divergence.format()
                           if res.divergence is not None else None),
            "warnings": res.warnings,
            "error": res.error,
        }
        print(json.dumps(doc, indent=1))
    else:
        print(res.summary())
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
