"""Serving CLI — a thin consumer of the ``repro.api`` facade.

Every flag maps onto one field of the layered ``RuntimeConfig``; the CLI
builds it, hands it to ``LLM``, and drives the engine with a synthetic
staggered-arrival workload:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --requests 8 --slots 4 --prompt-len 32 --gen 16 --stagger 2

``--cache-mode paged`` serves from the global page pool (block tables,
optional ``--prefill-chunk`` chunked long-prompt admission, int8 byte-size
pages via ``--kv-cache-dtype int8``, ``--paged-attn pallas_interpret`` to
force the Pallas kernel through the interpreter off-TPU).
``--prefix-cache`` turns on the shared-prefix KV cache (``repro/prefix/``:
admissions alias cached prompt-prefix pages and prefill only the suffix —
pair it with ``--shared-prefix N`` to give the synthetic workload an
N-token common system prompt).  ``--batched-admission`` stacks same-bucket
prompts into one prefill dispatch (slot and paged modes);
``--admission priority`` ranks the queue by ``Request.priority`` with
starvation-free aging (``prefix-aware`` admits hot-prefix requests
back-to-back); ``--defrag-threshold`` tunes (or ``-1`` disables) the pool
compaction policy; ``--spec K`` turns on speculative decoding (K drafted
tokens per verify dispatch, ``--draft ngram|model``); ``--stream`` prints
every token the moment it reaches the host.

``--runtime SPEC`` sidesteps the per-knob flags entirely: SPEC is a JSON
file (``RuntimeConfig.from_dict``) or a registered preset name
(``repro.api.list_presets()``), and the quant/KV/scheduler flags are
ignored in its favour — only workload flags (``--requests``/
``--prompt-len``/``--gen``/...) still apply.

``--static`` (and enc-dec / frontend archs, which the engine does not
admit) falls back to the lockstep baseline ``repro.api.serve_batch`` —
kept both as the reference implementation the engine is tested against and
as the baseline ``benchmarks/serve_bench.py`` beats.

Observability (``repro/obs/``): ``--trace out.json`` writes the engine's
span timeline as Chrome trace-event JSON (load at https://ui.perfetto.dev);
``--events out.jsonl`` writes the scheduler decision log (one JSON object
per admit/reject/chunk/CoW/defrag/finish event); ``--fence-spans`` makes
spans block on device values so they measure device work, not dispatch;
``--profile DIR`` wraps the first ``--profile-steps`` engine steps in a
``jax.profiler`` device trace; ``--debug-invariants`` checks the page
pool's bookkeeping after every step.  ``--metrics-port P`` serves a live
Prometheus ``/metrics`` endpoint (plus ``/healthz`` and a JSON
``/snapshot``) off the engine's registries; ``--watchdog`` arms the
numerics watchdog (per-layer saturation counters and amax/quant-error
histograms from inside the quantized GEMM pipeline, bitwise
output-invisible); ``--deadline SEC`` attaches an SLO deadline to every
synthetic request so the run reports goodput and hit/miss counts.  All
off by default — the disabled engine runs with null sinks and zero extra
host syncs.

``--tp N`` serves tensor-parallel (``repro/shard``): params, attention
heads, MoE experts and the paged KV pool shard over an N-way "model" mesh
axis while block tables stay host-side and replicated, so prefill /
decode / verify each remain one pjit dispatch per step.  Off-accelerator,
fake the devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.api import (
    LLM,
    KVConfig,
    MeshConfig,
    ObsConfig,
    QuantRuntime,
    RuntimeConfig,
    SamplingDefaults,
    SchedulerConfig,
    SpecConfig,
    list_presets,
    load_runtime,
    serve_batch,
)
from repro.configs import default_cache_len
from repro.models.frontends import fake_audio_frames, fake_vision_embeds


def synthetic_workload(cfg, n_requests: int, prompt_len: int, gen: int,
                       stagger: int, seed: int = 0, shared_prefix: int = 0):
    """Mixed-length prompts/budgets around the nominal sizes, arriving every
    ``stagger`` engine steps — a deterministic stand-in for live traffic.
    ``shared_prefix`` prepends a common system prompt of that many tokens
    to every request (the workload the prefix cache accelerates)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, shared_prefix).tolist()
    arrivals = []
    for i in range(n_requests):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        budget = int(rng.integers(max(1, gen // 2), gen + 1))
        prompt = prefix + rng.integers(0, cfg.vocab_size, plen).tolist()
        arrivals.append((i * stagger, prompt, budget))
    return arrivals


def _static_main(llm: LLM, args) -> None:
    cfg, params = llm.config, llm.params
    key = jax.random.PRNGKey(0)
    kt, ke = jax.random.split(key)
    if cfg.is_encoder_decoder:
        batch = {
            "src_embeds": fake_audio_frames(ke, cfg, args.batch, args.prompt_len),
            "tgt_tokens": jax.random.randint(kt, (args.batch, 8), 0, cfg.vocab_size),
        }
    elif cfg.frontend is not None:
        batch = {"embeds": fake_vision_embeds(ke, cfg, args.batch, args.prompt_len)}
    else:
        batch = {"tokens": jax.random.randint(kt, (args.batch, args.prompt_len), 0,
                                              cfg.vocab_size)}
    cache_len = default_cache_len(args.prompt_len, args.gen)
    tokens, stats = serve_batch(cfg, params, batch, cache_len=cache_len,
                                gen_tokens=args.gen)
    tps = args.batch * args.gen / stats["decode_s"]
    print(f"[serve] generated {tokens.shape} tokens; prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['decode_s']:.2f}s ({tps:.1f} tok/s)")
    print("[serve] sample:", tokens[0][:12].tolist())


def _engine_main(llm: LLM, args) -> None:
    # workload hints anchor the 'auto' bucket ladder to the nominal prompt
    # length (auto_buckets(prompt_len), as the pre-facade CLI built it)
    engine = llm.build_engine(args.prompt_len + args.shared_prefix, args.gen)
    if llm.metrics_server is not None:
        print(f"[obs] metrics server at {llm.metrics_server.url}/metrics "
              f"(also /healthz, /snapshot)")
    sampling = llm.runtime.sampling.to_params()
    if args.deadline is not None:
        sampling = dataclasses.replace(sampling, deadline_s=args.deadline)
    arrivals = [(s, p, g, sampling)
                for s, p, g in synthetic_workload(llm.config, args.requests,
                                                  args.prompt_len, args.gen,
                                                  args.stagger, args.seed,
                                                  args.shared_prefix)]
    on_token = (lambda req, tok: print(f"[stream] req {req.req_id}: {tok}",
                                       flush=True)) if args.stream else None
    metrics = engine.run(arrivals, on_token=on_token)
    print(metrics.format_report())
    if engine.paged:
        m = metrics
        print(f"[engine] paged: peak {m.peak_running} concurrent lanes, "
              f"{m.peak_pages_used}/{m.pages_total} pages "
              f"(page_size {m.page_size}), {m.chunk_steps} prefill chunks, "
              f"{m.defrag_count} defrags")
    if engine.prefix is not None:
        m = metrics
        print(f"[engine] prefix cache: {m.prefix_hits} hits / "
              f"{m.prefix_misses} misses, {m.prefix_hit_tokens} prompt "
              f"tokens reused, {m.prefix_cow_forks} CoW forks, "
              f"{m.prefix_evicted_pages} pages evicted, "
              f"{m.prefix_tree_pages} pages cached")
    if metrics.verify_dispatches:
        r = metrics.report()
        print(f"[engine] spec decode: {metrics.spec_accepted}/"
              f"{metrics.spec_proposed} drafts accepted "
              f"(rate {r['acceptance_rate']:.2f}) across "
              f"{metrics.verify_dispatches} verify dispatches")
    if metrics.stacked_prefills:
        print(f"[engine] batched admission: {metrics.prefills} prefills in "
              f"{metrics.prefill_dispatches} dispatches "
              f"({metrics.stacked_prefills} stacked)")
    if metrics.deadline_hits or metrics.deadline_misses:
        r = metrics.report()
        print(f"[engine] SLO: {metrics.deadline_hits} hit / "
              f"{metrics.deadline_misses} missed deadlines "
              f"(hit rate {r['deadline_hit_rate']:.2f}, "
              f"{metrics.deadline_late_admissions} already late at "
              f"admission) | goodput {r['goodput_tokens_per_s']:.1f} tok/s "
              f"of {r['tokens_per_s']:.1f} total")
    if metrics.finished:
        first = min(metrics.finished, key=lambda r: r.req_id)
        print(f"[engine] sample (req {first.req_id}):", first.output_tokens[:12])
    if llm.obs.enabled:
        r = metrics.report()
        print(f"[obs] TTFT p50/p95/p99 {r['ttft_p50_s']*1e3:.1f}/"
              f"{r['ttft_p95_s']*1e3:.1f}/{r['ttft_p99_s']*1e3:.1f} ms | "
              f"per-token p50/p99 {r['per_token_p50_s']*1e3:.2f}/"
              f"{r['per_token_p99_s']*1e3:.2f} ms | "
              f"queue wait p99 {r['queue_wait_p99_s']*1e3:.1f} ms | "
              f"{len(llm.obs.events)} scheduler events, "
              f"{len(llm.obs.tracer.events)} spans")
    if llm.runtime.obs.watchdog:
        from repro.obs import watchdog as _watchdog

        sat = _watchdog.saturation_report()
        if sat:
            worst = sorted(sat.items(), key=lambda kv: -kv[1])[:3]
            rendered = ", ".join(f"{k} {v:.4f}" for k, v in worst)
            print(f"[obs] watchdog: worst at-rail occupancy {rendered}")
    for path in llm.obs.save():
        print(f"[obs] wrote {path}")
    llm.close()
    if llm.obs.recorder is not None:
        print(f"[obs] flight recorder: bundle at {llm.obs.recorder.path} "
              f"(replay with `python -m repro.launch.replay "
              f"{llm.obs.recorder.path}`)")


def _obs_from_args(args) -> ObsConfig:
    return ObsConfig(
        trace=args.trace,
        events=args.events,
        fence_spans=args.fence_spans,
        profile_dir=args.profile,
        profile_steps=args.profile_steps,
        debug_invariants=args.debug_invariants,
        metrics_port=args.metrics_port,
        events_max_mb=args.events_max_mb,
        watchdog=args.watchdog,
        record_path=args.record,
    )


def _runtime_from_args(args) -> RuntimeConfig:
    """Flags -> the layered RuntimeConfig (the whole point of the facade:
    this mapping is the CLI's only job)."""
    return RuntimeConfig(
        quant=QuantRuntime(mode=args.quant_mode, gemm_backend=args.gemm_backend),
        kv=KVConfig(
            mode=args.cache_mode,
            dtype=args.kv_cache_dtype,
            cache_len=default_cache_len(args.prompt_len + args.shared_prefix,
                                        args.gen),
            page_size=args.page_size,
            n_pages=args.pages,
            paged_attn_impl=args.paged_attn,
            prefix_cache=args.prefix_cache,
        ),
        scheduler=SchedulerConfig(
            n_slots=args.slots,
            max_prefills_per_step=args.max_prefills,
            prefill_buckets=None if args.no_buckets else "auto",
            prefill_chunk=args.prefill_chunk,
            batched_admission=args.batched_admission,
            admission=args.admission,
            eviction=args.eviction,
            defrag_threshold=(None if args.defrag_threshold < 0
                              else args.defrag_threshold),
        ),
        sampling=SamplingDefaults(
            greedy=args.temperature == 0.0,
            temperature=args.temperature or 1.0,
            top_k=args.top_k,
            seed=args.seed,
        ),
        spec=SpecConfig(
            enabled=args.spec > 0,
            k=args.spec or 4,
            drafter=args.draft,
            draft_arch=args.draft_arch,
        ),
        obs=_obs_from_args(args),
        mesh=MeshConfig(tp=args.tp),
        max_new_tokens=args.gen,
        reduced=args.reduced,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="lockstep static-batch baseline instead of the engine")
    ap.add_argument("--batch", type=int, default=4, help="static path: batch size")
    ap.add_argument("--requests", type=int, default=8, help="engine: request count")
    ap.add_argument("--slots", type=int, default=4, help="engine: KV-cache lanes")
    ap.add_argument("--stagger", type=int, default=2,
                    help="engine: steps between request arrivals")
    ap.add_argument("--max-prefills", type=int, default=1,
                    help="engine: admission dispatches interleaved per step")
    ap.add_argument("--no-buckets", action="store_true",
                    help="engine: exact-length prefill (one trace per length)")
    ap.add_argument("--batched-admission", action="store_true",
                    help="engine: stack same-bucket prompts into one prefill "
                         "dispatch (slot and paged modes)")
    ap.add_argument("--admission", default="fifo",
                    choices=["fifo", "priority", "prefix-aware", "deadline"],
                    help="engine: admission ordering (priority = "
                         "Request.priority with starvation-free aging; "
                         "prefix-aware = requests sharing a hot cached "
                         "prefix admit back-to-back; deadline = FIFO that "
                         "also sheds already-late requests at ingress)")
    ap.add_argument("--eviction", default="budget",
                    choices=["budget", "deadline-preempt"],
                    help="engine: eviction policy (deadline-preempt = "
                         "budget/EOS plus SLO preemption of lanes whose "
                         "request already missed its deadline while queued "
                         "work can still hit)")
    ap.add_argument("--spec", type=int, default=0, metavar="K",
                    help="engine: speculative decoding with K drafted tokens "
                         "per verify dispatch (0 = off; greedy lanes only)")
    ap.add_argument("--draft", default="ngram", choices=["ngram", "model"],
                    help="spec drafter: model-free prompt-lookup n-grams or "
                         "a small draft model (repro/spec/)")
    ap.add_argument("--draft-arch", default=None,
                    help="spec: draft model architecture (default: a "
                         "truncated copy of the target)")
    ap.add_argument("--runtime", default=None,
                    help="RuntimeConfig source: a JSON file (from_dict) or a "
                         f"preset name {list_presets()}; overrides the "
                         "quant/KV/scheduler flags")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples")
    ap.add_argument("--top-k", type=int, default=0, help="0 = no truncation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant-mode", default="bf16")
    ap.add_argument("--gemm-backend", default=None,
                    help="GEMM backend registry name; default auto-selection")
    ap.add_argument("--kv-cache-dtype", default="bf16", choices=["bf16", "int8"],
                    help="int8: SPOGA-style byte-size KV cache (+scales)")
    ap.add_argument("--cache-mode", default="slot", choices=["slot", "paged"],
                    help="paged: global page pool + block tables (repro/paging)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged: tokens per KV page")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged: pool size in pages (default: slot-equivalent budget)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="paged: admit long prompts in chunks of this many "
                         "tokens (multiple of page-size), interleaved with decode")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged: shared-prefix KV cache (radix tree + "
                         "copy-on-write pages; repro/prefix/)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="workload: prepend a common system prompt of this "
                         "many tokens to every request")
    ap.add_argument("--defrag-threshold", type=float, default=0.5,
                    help="paged: compact the pool when fragmentation crosses "
                         "this ratio (-1 disables)")
    ap.add_argument("--paged-attn", default=None,
                    choices=["jnp", "pallas", "pallas_interpret"],
                    help="paged attention impl (default: auto by platform)")
    ap.add_argument("--stream", action="store_true",
                    help="engine: print every token as it reaches the host")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="obs: write the engine span timeline as Chrome "
                         "trace-event JSON (load in Perfetto)")
    ap.add_argument("--events", default=None, metavar="OUT.jsonl",
                    help="obs: write the scheduler decision log as JSONL")
    ap.add_argument("--fence-spans", action="store_true",
                    help="obs: block spans on device values so they measure "
                         "device work (serializes the decode pipeline)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="obs: jax.profiler device trace over the first "
                         "--profile-steps engine steps, written under DIR")
    ap.add_argument("--profile-steps", type=int, default=20,
                    help="obs: engine steps the --profile window covers")
    ap.add_argument("--debug-invariants", action="store_true",
                    help="obs: check page-pool invariants after every step")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="obs: serve live /metrics (Prometheus text "
                         "exposition) + /healthz + /snapshot on this port "
                         "(0 = ephemeral; URL printed at startup)")
    ap.add_argument("--events-max-mb", type=float, default=64.0,
                    help="obs: rotate the --events JSONL stream past this size")
    ap.add_argument("--record", default=None, metavar="DIR",
                    help="obs: arm the flight recorder — capture the run "
                         "(config fingerprint, arrivals, decision journal, "
                         "outputs, decision-clock tape) into DIR; replay "
                         "with `python -m repro.launch.replay DIR`")
    ap.add_argument("--watchdog", action="store_true",
                    help="obs: numerics watchdog — per-layer saturation/"
                         "clip counters and amax/quant-error histograms "
                         "from inside the quantized GEMM pipeline "
                         "(output-invisible; retraces the jits)")
    ap.add_argument("--deadline", type=float, default=None, metavar="SEC",
                    help="SLO: per-request deadline in seconds from submit; "
                         "finished-late requests count as misses and drop "
                         "out of goodput")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard params, attention "
                         "heads, experts and the paged KV pool over a "
                         "'model' mesh axis (repro/shard). Needs "
                         "jax.device_count() divisible by tp; use "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                         "to fake a multi-device host mesh")
    args = ap.parse_args()

    runtime = (load_runtime(args.runtime) if args.runtime
               else _runtime_from_args(args))
    if args.runtime:
        # obs + reduced are session flags, not deployment profile state:
        # they apply on top of whatever --runtime loaded
        if args.reduced:
            runtime = dataclasses.replace(runtime, reduced=True)
        obs = _obs_from_args(args)
        if obs != ObsConfig():
            runtime = dataclasses.replace(runtime, obs=obs)
        if args.tp != 1:
            runtime = dataclasses.replace(runtime, mesh=MeshConfig(tp=args.tp))
    llm = LLM(arch=args.arch, runtime=runtime)
    cfg = llm.config
    engine_capable = not cfg.is_encoder_decoder and cfg.frontend is None
    if args.static or not engine_capable:
        if not engine_capable and not args.static:
            print(f"[serve] {cfg.name}: enc-dec/frontend arch — static path")
        _static_main(llm, args)
    else:
        _engine_main(llm, args)


if __name__ == "__main__":
    main()
