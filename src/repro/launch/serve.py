"""Batched serving driver: prefill a batch of prompts, then decode with the
KV/state caches — greedy sampling.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params
from repro.models.frontends import fake_audio_frames, fake_vision_embeds


def serve_batch(cfg, params, batch, *, cache_len: int, gen_tokens: int):
    """Greedy-decode ``gen_tokens`` for every sequence. Returns (B, gen)."""
    prefill_fn = jax.jit(make_prefill_step(cfg, cache_len))
    step_fn = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
    t0 = time.time()
    logits, cache = prefill_fn(params, batch)
    prefill_s = time.time() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen_tokens - 1):
        logits, cache = step_fn(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    return jnp.stack(out, axis=1), {"prefill_s": prefill_s, "decode_s": decode_s}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant-mode", default="bf16")
    ap.add_argument("--gemm-backend", default=None,
                    help="GEMM backend registry name; default auto-selection")
    ap.add_argument("--kv-cache-dtype", default="bf16", choices=["bf16", "int8"],
                    help="int8: SPOGA-style byte-size KV cache (+scales)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cfg = cfg.with_(quant_mode=args.quant_mode, kv_cache_dtype=args.kv_cache_dtype,
                    gemm_backend=args.gemm_backend)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    kt, ke = jax.random.split(key)
    if cfg.is_encoder_decoder:
        batch = {
            "src_embeds": fake_audio_frames(ke, cfg, args.batch, args.prompt_len),
            "tgt_tokens": jax.random.randint(kt, (args.batch, 8), 0, cfg.vocab_size),
        }
    elif cfg.frontend is not None:
        batch = {"embeds": fake_vision_embeds(ke, cfg, args.batch, args.prompt_len)}
    else:
        batch = {"tokens": jax.random.randint(kt, (args.batch, args.prompt_len), 0,
                                              cfg.vocab_size)}
    cache_len = args.prompt_len + args.gen + 8
    tokens, stats = serve_batch(cfg, params, batch, cache_len=cache_len,
                                gen_tokens=args.gen)
    tps = args.batch * args.gen / stats["decode_s"]
    print(f"[serve] generated {tokens.shape} tokens; prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['decode_s']:.2f}s ({tps:.1f} tok/s)")
    print("[serve] sample:", tokens[0][:12].tolist())


if __name__ == "__main__":
    main()
