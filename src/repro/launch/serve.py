"""Serving CLI — thin wrapper over the continuous-batching engine.

Default path: ``repro.serving.ServingEngine`` (slot-based KV cache,
interleaved prefill/decode, per-request sampling) fed a synthetic workload
of mixed-length prompts with staggered arrivals:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --requests 8 --slots 4 --prompt-len 32 --gen 16 --stagger 2

``--cache-mode paged`` serves from the global page pool (block tables,
optional ``--prefill-chunk`` chunked long-prompt admission, int8 byte-size
pages via ``--kv-cache-dtype int8``, ``--paged-attn pallas_interpret`` to
force the Pallas kernel through the interpreter off-TPU).  ``--stream``
prints every token the moment it reaches the host.

``--static`` (and enc-dec / frontend archs, which the engine does not
admit) falls back to the lockstep static-batch baseline ``serve_batch`` —
kept both as the reference implementation the engine is tested against and
as the baseline ``benchmarks/serve_bench.py`` beats.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import default_cache_len, get_config, reduced as reduce_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params
from repro.models.frontends import fake_audio_frames, fake_vision_embeds
from repro.serving import EngineConfig, SamplingParams, ServingEngine


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg, cache_len: int):
    """jit wrappers keyed by (cfg, cache_len) — ``make_*_step`` returns a new
    closure per call, so without this every ``serve_batch`` call recompiles."""
    return (jax.jit(make_prefill_step(cfg, cache_len)),
            jax.jit(make_serve_step(cfg), donate_argnums=(2,)))


def serve_batch(cfg, params, batch, *, cache_len: int, gen_tokens: int):
    """Static-batch lockstep baseline: every sequence prefills together and
    decodes ``gen_tokens`` steps together (greedy). Returns (B, gen)."""
    prefill_fn, step_fn = _jitted_steps(cfg, cache_len)
    t0 = time.time()
    logits, cache = prefill_fn(params, batch)
    prefill_s = time.time() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen_tokens - 1):
        logits, cache = step_fn(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    return jnp.stack(out, axis=1), {"prefill_s": prefill_s, "decode_s": decode_s}


def synthetic_workload(cfg, n_requests: int, prompt_len: int, gen: int,
                       stagger: int, seed: int = 0):
    """Mixed-length prompts/budgets around the nominal sizes, arriving every
    ``stagger`` engine steps — a deterministic stand-in for live traffic."""
    rng = np.random.default_rng(seed)
    arrivals = []
    for i in range(n_requests):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        budget = int(rng.integers(max(1, gen // 2), gen + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        arrivals.append((i * stagger, prompt, budget))
    return arrivals


def _static_main(cfg, params, args):
    key = jax.random.PRNGKey(0)
    kt, ke = jax.random.split(key)
    if cfg.is_encoder_decoder:
        batch = {
            "src_embeds": fake_audio_frames(ke, cfg, args.batch, args.prompt_len),
            "tgt_tokens": jax.random.randint(kt, (args.batch, 8), 0, cfg.vocab_size),
        }
    elif cfg.frontend is not None:
        batch = {"embeds": fake_vision_embeds(ke, cfg, args.batch, args.prompt_len)}
    else:
        batch = {"tokens": jax.random.randint(kt, (args.batch, args.prompt_len), 0,
                                              cfg.vocab_size)}
    cache_len = default_cache_len(args.prompt_len, args.gen)
    tokens, stats = serve_batch(cfg, params, batch, cache_len=cache_len,
                                gen_tokens=args.gen)
    tps = args.batch * args.gen / stats["decode_s"]
    print(f"[serve] generated {tokens.shape} tokens; prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['decode_s']:.2f}s ({tps:.1f} tok/s)")
    print("[serve] sample:", tokens[0][:12].tolist())


def _engine_main(cfg, params, args):
    from repro.serving.engine import RECURRENT_KINDS

    sampling = SamplingParams(
        greedy=args.temperature == 0.0,
        temperature=args.temperature or 1.0,
        top_k=args.top_k,
        seed=args.seed,
    )
    # recurrent stacks must prefill at exact lengths (padding pollutes state)
    use_buckets = not args.no_buckets and not (RECURRENT_KINDS & set(cfg.block_pattern))
    ecfg = EngineConfig.for_workload(
        args.prompt_len, args.gen,
        n_slots=args.slots,
        max_prefills_per_step=args.max_prefills,
        prefill_buckets=_auto_buckets(args.prompt_len) if use_buckets else None,
        cache_mode=args.cache_mode,
        page_size=args.page_size,
        n_pages=args.pages,
        prefill_chunk=args.prefill_chunk,
    )
    engine = ServingEngine(cfg, params, ecfg)
    arrivals = [(s, p, g, sampling)
                for s, p, g in synthetic_workload(cfg, args.requests,
                                                  args.prompt_len, args.gen,
                                                  args.stagger, args.seed)]
    on_token = (lambda req, tok: print(f"[stream] req {req.req_id}: {tok}",
                                       flush=True)) if args.stream else None
    metrics = engine.run(arrivals, on_token=on_token)
    print(metrics.format_report())
    if engine.paged:
        m = metrics
        print(f"[engine] paged: peak {m.peak_running} concurrent lanes, "
              f"{m.peak_pages_used}/{m.pages_total} pages "
              f"(page_size {m.page_size}), {m.chunk_steps} prefill chunks")
    if metrics.finished:
        first = min(metrics.finished, key=lambda r: r.req_id)
        print(f"[engine] sample (req {first.req_id}):", first.output_tokens[:12])


def _auto_buckets(prompt_len: int):
    """Power-of-two buckets covering [1, prompt_len] — bounds prefill traces."""
    buckets, b = [], 8
    while b < prompt_len:
        buckets.append(b)
        b *= 2
    buckets.append(prompt_len)
    return tuple(buckets)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="lockstep static-batch baseline instead of the engine")
    ap.add_argument("--batch", type=int, default=4, help="static path: batch size")
    ap.add_argument("--requests", type=int, default=8, help="engine: request count")
    ap.add_argument("--slots", type=int, default=4, help="engine: KV-cache lanes")
    ap.add_argument("--stagger", type=int, default=2,
                    help="engine: steps between request arrivals")
    ap.add_argument("--max-prefills", type=int, default=1,
                    help="engine: admissions interleaved per step")
    ap.add_argument("--no-buckets", action="store_true",
                    help="engine: exact-length prefill (one trace per length)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples")
    ap.add_argument("--top-k", type=int, default=0, help="0 = no truncation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant-mode", default="bf16")
    ap.add_argument("--gemm-backend", default=None,
                    help="GEMM backend registry name; default auto-selection")
    ap.add_argument("--kv-cache-dtype", default="bf16", choices=["bf16", "int8"],
                    help="int8: SPOGA-style byte-size KV cache (+scales)")
    ap.add_argument("--cache-mode", default="slot", choices=["slot", "paged"],
                    help="paged: global page pool + block tables (repro/paging)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged: tokens per KV page")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged: pool size in pages (default: slot-equivalent budget)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="paged: admit long prompts in chunks of this many "
                         "tokens (multiple of page-size), interleaved with decode")
    ap.add_argument("--paged-attn", default=None,
                    choices=["jnp", "pallas", "pallas_interpret"],
                    help="paged attention impl (default: auto by platform)")
    ap.add_argument("--stream", action="store_true",
                    help="engine: print every token as it reaches the host")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cfg = cfg.with_(quant_mode=args.quant_mode, kv_cache_dtype=args.kv_cache_dtype,
                    gemm_backend=args.gemm_backend, paged_attn_impl=args.paged_attn)
    params = init_params(cfg, jax.random.PRNGKey(0))

    engine_capable = not cfg.is_encoder_decoder and cfg.frontend is None
    if args.static or not engine_capable:
        if not engine_capable and not args.static:
            print(f"[serve] {cfg.name}: enc-dec/frontend arch — static path")
        _static_main(cfg, params, args)
    else:
        _engine_main(cfg, params, args)


if __name__ == "__main__":
    main()
