import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes with ShapeDtypeStruct inputs (no allocation), and record
memory / cost / collective statistics for the roofline analysis.

MUST be invoked as its own process (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above runs before any other import so the host platform
exposes 512 placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
"""

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import SHAPES, cells, get_config          # noqa: E402
from repro.configs.base import TrainConfig                   # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.steps import lower_cell                    # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _bytes_of_shape(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum result-buffer bytes per collective kind from (S)HLO text."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            marker = f" {kind}("
            alt = f" {kind}-start("
            if marker in line or alt in line:
                cut = line.split(marker)[0] if marker in line else line.split(alt)[0]
                nbytes = sum(
                    _bytes_of_shape(dt, dims) for dt, dims in _SHAPE_RE.findall(cut)
                )
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += nbytes
                break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items() if k in _COLLECTIVES)
    return stats


def calibrated_costs(cfg, shape, mesh, tcfg) -> dict | None:
    """Depth-correct flops/bytes/collectives via shallow *unrolled* lowers.

    XLA's HloCostAnalysis counts a while-loop body once, so the scanned
    layer stack under-reports by ~n_layers.  Lowering the same cell at 1
    and 2 pattern-periods with ``scan_unroll=True`` (no while loops) gives
    an exact per-period cost; extrapolating to the full depth recovers the
    true per-step totals.  Memory analysis still comes from the full-depth
    scan compile (that is the deployable program).
    """
    from repro.models.transformer import layer_layout

    lead, n_periods, tail = layer_layout(cfg)
    period = cfg.pattern_period
    if n_periods < 2:
        return None

    def costs_at(k: int):
        kw = dict(n_layers=lead + k * period, scan_unroll=True)
        if cfg.is_encoder_decoder:
            kw["n_encoder_layers"] = max(1, k * cfg.n_encoder_layers // n_periods)
        cfg_k = cfg.with_(**kw)
        compiled = lower_cell(cfg_k, shape, mesh, tcfg).compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_stats(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll)

    f1, b1, c1 = costs_at(1)
    f2, b2, c2 = costs_at(2)
    scale = (n_periods - 1) + len(tail) / period

    def extrap(v1, v2):
        # clamp: fusion differences between the two shallow compiles can
        # make v2 < v1 on fixed-cost-dominated cells; the linear
        # extrapolation must never fall below the single-period compile.
        return max(v1 + (v2 - v1) * scale, v1, 0.0)

    coll = {}
    for kind in _COLLECTIVES:
        coll[kind] = {
            "count": int(round(extrap(c1[kind]["count"], c2[kind]["count"]))),
            "bytes": int(round(extrap(c1[kind]["bytes"], c2[kind]["bytes"]))),
        }
    coll["total_bytes"] = sum(v["bytes"] for k, v in coll.items() if k in _COLLECTIVES)
    return {
        "flops_per_device": extrap(f1, f2),
        "bytes_accessed_per_device": extrap(b1, b2),
        "collectives": coll,
        "periods": n_periods,
    }


# Gradient-accumulation defaults for train_4k so activations fit 16 GiB
# v5e HBM (chosen from the measured buffer tables, EXPERIMENTS.md Dry-run).
TRAIN_MICROBATCHES = {
    "mistral-large-123b": 8,
    "granite-moe-3b-a800m": 4,
    "deepseek-moe-16b": 4,
    "recurrentgemma-9b": 2,
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, quant_mode: str,
             zero1: bool = True, fsdp: bool = True, microbatches: int = 1,
             calibrate: bool = True, remat_policy: str = "nothing",
             kv_cache_dtype: str = "bf16", grad_reduce_dtype: str = "f32",
             gemm_backend: str | None = None,
             extra_tags: dict | None = None) -> dict:
    cfg = get_config(arch).with_(quant_mode=quant_mode,
                                 remat_policy=remat_policy,
                                 kv_cache_dtype=kv_cache_dtype,
                                 gemm_backend=gemm_backend)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tcfg = TrainConfig(zero1=zero1, fsdp=fsdp, microbatches=microbatches,
                       grad_reduce_dtype=grad_reduce_dtype)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "quant_mode": quant_mode,
        "gemm_backend": gemm_backend,
        "zero1": zero1,
        "fsdp": fsdp,
        "microbatches": microbatches,
        "remat_policy": remat_policy,
        "kv_cache_dtype": kv_cache_dtype,
        "grad_reduce_dtype": grad_reduce_dtype,
        **(extra_tags or {}),
    }
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, tcfg)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        }
        rec["hbm_per_device_gib"] = round(
            (mem.argument_size_in_bytes + mem.output_size_in_bytes
             + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3
        )
    cost = compiled.cost_analysis() or {}
    rec["cost"] = {
        "flops_per_device": float(cost.get("flops", -1)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)),
    }
    rec["collectives"] = collective_stats(compiled.as_text())
    if calibrate:
        try:
            cal = calibrated_costs(cfg, shape, mesh, tcfg)
            if cal is not None:
                rec["cost_cal"] = {
                    "flops_per_device": cal["flops_per_device"],
                    "bytes_accessed_per_device": cal["bytes_accessed_per_device"],
                }
                rec["collectives_cal"] = cal["collectives"]
        except Exception as e:  # noqa: BLE001 — calibration is best-effort
            rec["cal_error"] = f"{type(e).__name__}: {e}"
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--quant-mode", default="bf16")
    ap.add_argument("--gemm-backend", default=None,
                    help="GEMM backend registry name; default auto-selection")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the unrolled cost-calibration lowers")
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        todo = [(a, s) for a, s, skipped in cells() if not skipped]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    done = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"], r["quant_mode"]))
                except json.JSONDecodeError:
                    pass

    n_fail = 0
    for arch, shape_name in todo:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            key = (arch, shape_name, mesh_name, args.quant_mode)
            if key in done:
                print(f"[skip] {key}")
                continue
            print(f"[dryrun] {arch} x {shape_name} on {mesh_name} ({args.quant_mode})",
                  flush=True)
            try:
                mb = args.microbatches
                if mb == 1 and shape_name == "train_4k":
                    mb = TRAIN_MICROBATCHES.get(arch, 1)
                rec = run_cell(arch, shape_name, mp, args.quant_mode,
                               zero1=not args.no_zero1, fsdp=not args.no_fsdp,
                               microbatches=mb,
                               calibrate=not args.no_calibrate,
                               gemm_backend=args.gemm_backend)
                print(f"  ok: hbm/dev={rec.get('hbm_per_device_gib')}GiB "
                      f"flops/dev={rec['cost']['flops_per_device']:.3e} "
                      f"coll={rec['collectives']['total_bytes']:.3e}B "
                      f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
                rec = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "quant_mode": args.quant_mode, "ok": False,
                    "error": f"{type(e).__name__}: {e}",
                }
                n_fail += 1
                print(f"  FAIL: {rec['error']}", flush=True)
                traceback.print_exc()
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    print(f"dry-run complete, failures: {n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
