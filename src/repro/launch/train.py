"""End-to-end training driver.

Runs REAL steps on the available devices (CPU here; the same code path
pjit-shards on a TPU mesh), with checkpointing, restart recovery,
straggler monitoring and optional int8-compressed data-parallel gradient
all-reduce (shard_map).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced as reduce_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim.optimizers import adamw_init
from repro.runtime.fault_tolerance import StragglerMonitor


def train_loop(cfg, tcfg: TrainConfig, *, steps: int, batch: int, seq: int,
               ckpt_dir: str | None = None, checkpoint_every: int = 10,
               log_every: int = 1, seed: int = 0):
    """Returns (final params, losses list)."""
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt_state = adamw_init(params)
    pipeline = SyntheticTokenPipeline(cfg.vocab_size, seq, batch, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    manager = CheckpointManager(ckpt_dir, keep_n=2, async_save=True) if ckpt_dir else None
    monitor = StragglerMonitor(n_hosts=1)

    start = 0
    if manager is not None:
        try:
            start, (params, opt_state), _ = manager.restore_latest((params, opt_state))
            print(f"[train] resumed from step {start}")
        except FileNotFoundError:
            pass

    losses = []
    for step in range(start, steps):
        tokens = pipeline.global_batch_at(step)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, {"tokens": tokens})
        loss = float(metrics["loss"])
        monitor.record(0, time.time() - t0)
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({time.time() - t0:.2f}s)", flush=True)
        if manager is not None and (step + 1) % checkpoint_every == 0:
            manager.save(step + 1, (params, opt_state))
    if manager is not None:
        manager.save(steps, (params, opt_state))
        manager.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quant-mode", default="bf16")
    ap.add_argument("--gemm-backend", default=None,
                    help="GEMM backend registry name (e.g. jnp_spoga, "
                         "pallas_spoga_dequant, pallas_interpret); "
                         "default: platform auto-selection")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cfg = cfg.with_(quant_mode=args.quant_mode, gemm_backend=args.gemm_backend)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=5, total_steps=args.steps)
    _, losses = train_loop(cfg, tcfg, steps=args.steps, batch=args.batch,
                           seq=args.seq, ckpt_dir=args.ckpt_dir)
    print(f"[train] first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
