"""Step builders shared by the dry-run, trainer and server.

For a (arch-config, shape-cell, mesh) triple this module produces:

* the jittable step function (train_step / prefill_step / serve_step),
* ShapeDtypeStruct trees for every input (``input_specs`` — no allocation),
* in/out NamedShardings,

so ``jax.jit(step, in_shardings, out_shardings).lower(**specs).compile()``
is the single code path everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import batch_specs
from repro.models import model as model_lib
from repro.optim.optimizers import adamw_init, adamw_update
from repro.runtime import sharding as shard_lib

# enc-dec decode cells cross-attend to a fixed-length encoded source
CROSS_LEN_FOR_DECODE = 4_096


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, grad_specs=None):
    """fwd+bwd (+ optional gradient accumulation over microbatches) + AdamW.

    Microbatching (``tcfg.microbatches > 1``) scans fwd+bwd over k slices
    of the batch, accumulating fp32 grads — the activation working set
    shrinks by k while arithmetic is unchanged (mean of per-microbatch
    grads == full-batch grad for a mean loss over equal slices).

    ``grad_specs``: PartitionSpec tree matching params; constraining each
    microbatch gradient to the FSDP spec lets the SPMD partitioner emit
    reduce-scatter instead of (all-reduce + slice) — without it, full-size
    fp32 gradient buffers dominate HBM at 100B scale.
    """

    def grads_of(params, batch):
        loss, g = jax.value_and_grad(model_lib.lm_loss)(params, cfg, batch)
        if tcfg.grad_reduce_dtype == "bf16":
            # halve the DP reduce-scatter payload; AdamW's f32 master
            # update absorbs the rounding (same trick as mixed precision)
            g = jax.tree_util.tree_map(lambda gg: gg.astype(jnp.bfloat16), g)
        if grad_specs is not None:
            g = jax.tree_util.tree_map(
                lambda gg, sp: jax.lax.with_sharding_constraint(gg, sp), g, grad_specs
            )
        return loss, g

    def train_step(params, opt_state, batch):
        k = tcfg.microbatches
        if k > 1:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
            )

            def constrain(tree):
                if grad_specs is None:
                    return tree
                return jax.tree_util.tree_map(
                    lambda t, sp: jax.lax.with_sharding_constraint(t, sp),
                    tree, grad_specs)

            def accum(acc, mb):
                loss_sum, g_acc = acc
                loss, g = grads_of(params, mb)
                # constrain the f32 accumulator to the FSDP grad specs:
                # unconstrained, XLA keeps it replicated over "data" and
                # all-gathers every microbatch's sharded gradient in f32 —
                # measured 91 GiB/device of weight-shaped all-gathers per
                # layer-step at 123B (EXPERIMENTS.md Perf A-log).
                g_acc = constrain(jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                ))
                return (loss_sum + loss, g_acc), None

            zeros = constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ))
            # unroll with the rest of the scans during cost calibration —
            # HloCostAnalysis counts a while body once (see dryrun.py)
            (loss_sum, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zeros), micro,
                                                unroll=cfg.scan_unroll)
            loss = loss_sum / k
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
        else:
            loss, grads = grads_of(params, batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, tcfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int, with_lengths: bool = False):
    """``with_lengths``: the serving engine's variant — takes a per-sequence
    (B,) true-lengths array so right-padded prompt buckets prefill exactly."""
    if with_lengths:
        def prefill_step(params, batch, lengths):
            return model_lib.prefill(params, cfg, batch, cache_len, lengths=lengths)
    else:
        def prefill_step(params, batch):
            return model_lib.prefill(params, cfg, batch, cache_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig, with_active: bool = False):
    """``with_active``: the serving engine's variant — takes a (B,) live-lane
    mask so idle lanes' positions are pinned instead of drifting and paged
    writes are redirected to the trash page (see ``model.decode_step``)."""
    if with_active:
        def serve_step(params, tokens, cache, active):
            return model_lib.decode_step(params, cfg, tokens, cache, active)
    else:
        def serve_step(params, tokens, cache):
            return model_lib.decode_step(params, cfg, tokens, cache)

    return serve_step


def _cache_len_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.sliding_window is not None and not any(
        k in ("attn", "moe") for k in cfg.block_pattern
    ):
        # hybrid/local-only stacks never need more than the window
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def cell_program(cfg: ModelConfig, shape: ShapeConfig, mesh, tcfg: TrainConfig | None = None):
    """-> (fn, kwargs_specs: dict[str, ShapeDtypeStruct tree],
          in_shardings: dict, out_shardings, donate_argnames)"""
    tcfg = tcfg or TrainConfig()
    p_shapes = model_lib.param_shapes(cfg)
    p_specs = shard_lib.param_specs(p_shapes, mesh, cfg, fsdp=tcfg.fsdp)

    if shape.kind == "train":
        fn = make_train_step(cfg, tcfg, grad_specs=p_specs)
        opt_shapes = jax.eval_shape(adamw_init, p_shapes)
        opt_specs = shard_lib.opt_state_specs(opt_shapes, p_specs, mesh, tcfg.zero1)
        b_shapes = batch_specs(cfg, shape)
        b_specs = shard_lib.batch_specs_tree(b_shapes, mesh)
        kwargs = {"params": p_shapes, "opt_state": opt_shapes, "batch": b_shapes}
        in_sh = {
            "params": shard_lib.named(p_specs, mesh),
            "opt_state": shard_lib.named(opt_specs, mesh),
            "batch": shard_lib.named(b_specs, mesh),
        }
        out_sh = (
            shard_lib.named(p_specs, mesh),
            shard_lib.named(opt_specs, mesh),
            None,
        )
        return fn, kwargs, in_sh, out_sh, ("params", "opt_state")

    if shape.kind == "prefill":
        cache_len = _cache_len_for(cfg, shape)
        fn = make_prefill_step(cfg, cache_len)
        b_shapes = batch_specs(cfg, shape)
        b_specs = shard_lib.batch_specs_tree(b_shapes, mesh)
        kwargs = {"params": p_shapes, "batch": b_shapes}
        in_sh = {
            "params": shard_lib.named(p_specs, mesh),
            "batch": shard_lib.named(b_specs, mesh),
        }
        cross = b_shapes["src_embeds"].shape[1] if cfg.is_encoder_decoder else 0
        c_shapes = model_lib.cache_shapes(cfg, shape.global_batch, cache_len, cross)
        c_specs = shard_lib.cache_specs(c_shapes, mesh)
        out_sh = (None, shard_lib.named(c_specs, mesh))
        return fn, kwargs, in_sh, out_sh, ()

    # decode: one token against a cache of shape.seq_len
    cache_len = _cache_len_for(cfg, shape)
    fn = make_serve_step(cfg)
    cross = CROSS_LEN_FOR_DECODE if cfg.is_encoder_decoder else 0
    c_shapes = model_lib.cache_shapes(cfg, shape.global_batch, cache_len, cross)
    c_specs = shard_lib.cache_specs(c_shapes, mesh)
    tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    kwargs = {"params": p_shapes, "tokens": tok, "cache": c_shapes}
    in_sh = {
        "params": shard_lib.named(p_specs, mesh),
        "tokens": shard_lib.named(shard_lib.batch_pspec(tok.shape, mesh), mesh),
        "cache": shard_lib.named(c_specs, mesh),
    }
    out_sh = (None, shard_lib.named(c_specs, mesh))
    return fn, kwargs, in_sh, out_sh, ("cache",)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, tcfg: TrainConfig | None = None):
    """Lower (no compile) one cell. Returns the jax ``Lowered`` object."""
    fn, kwargs, in_sh, out_sh, donate = cell_program(cfg, shape, mesh, tcfg)
    names = list(kwargs.keys())
    in_shardings = tuple(in_sh[n] for n in names)
    donate_argnums = tuple(i for i, n in enumerate(names) if n in donate)
    jfn = jax.jit(
        fn,
        in_shardings=in_shardings,
        out_shardings=out_sh,
        donate_argnums=donate_argnums,
    )
    with mesh:
        return jfn.lower(*[kwargs[n] for n in names])
