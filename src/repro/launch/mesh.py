"""Production meshes.

Single pod: 16 x 16 = 256 chips -> ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips -> ("pod", "data", "model"); the
"pod" axis carries pure data parallelism (gradient all-reduce over DCN),
"model" stays inside a pod's ICI domain — the standard multi-pod layout.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import math

import jax


def _make_mesh(shape, axes):
    need = math.prod(shape)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh shape {dict(zip(axes, shape))} needs {need} devices but "
            f"only {have} are visible; on CPU, export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "BEFORE jax initializes (or shrink the mesh)")
    # jax.sharding.AxisType (explicit-mesh API) only exists on newer jax;
    # older releases default every axis to Auto, which is what we want.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return _make_mesh((data, model), ("data", "model"))
