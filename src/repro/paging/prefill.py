"""Chunked prefill over the paged cache: admit a long prompt in page-sized
chunks interleaved with decode steps.

One chunk step embeds ``chunk_len`` prompt tokens at absolute offset
``start``, runs them through the stack — each attention block writes the
chunk's K/V (or MLA latents) into the lane's pages and attends the
gathered prefix + chunk under the ordinary causal mask — and returns the
sampled token for the chunk's last valid row (only the final chunk's
sample is used).  Because the bf16 cache roundtrip is lossless and every
per-row computation is position-independent, the chunked admission is
bitwise the unchunked prefill (see ``models/attention.attention_chunk``);
the engine's exact-match tests pin that down.

Chunkable kinds come in two tiers:

* ``chunkable`` — the attention family whose math is strictly
  row-independent: ``attn`` (incl. the MLA rewrite) and dense FFN layers.
  Chunking is *bitwise* the unchunked prefill; prefix caching and
  speculative verify require exactly this contract.
* ``chunkable_with_state`` — additionally the recurrent kinds
  (``rglru``/``mlstm``/``slstm``), whose cells carry their state across
  chunk boundaries (``models/recurrent.*_chunk``): pad rows are
  neutralized in each cell's own algebra (identity recurrence / zero
  gate injection / carry freeze), so the carried state is exact and
  chunk-boundary placement only reorders float reductions (sLSTM is
  bitwise; RG-LRU/mLSTM are allclose — the associative/chunk scans
  regroup).  This is what lets the engine chunk-admit xLSTM-style
  stacks instead of forcing exact-length one-shot admissions.

Excluded by construction:

* ``moe`` — expert capacity is ``ceil(S * k / E * cf)``: it depends on how
  many tokens share the dispatch, so chunking would change which tokens
  drop and break output-invisibility;
* ``local_attn`` — the ring buffer is written modulo the window, which a
  partial chunk would wrap incorrectly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models import transformer as tfm
from repro.models.layers import embed, glu_mlp, rmsnorm, unembed

CHUNKABLE_KINDS = frozenset({"attn", "mla", "dense_ffn_layer"})
# state-carrying kinds the chunk step can ALSO run (see module docstring
# for the weaker exactness contract)
STATEFUL_CHUNK_KINDS = frozenset({"rglru", "mlstm", "slstm"})


def stack_kinds(cfg: ModelConfig) -> frozenset[str]:
    """Effective block kinds across the WHOLE stack (lead dense layers +
    scanned periods + tail remainder) — the one place layout-derived kind
    sets come from, shared by the engine's paged-pool detection and the
    chunkability check below."""
    lead, n_periods, tail_kinds = tfm.layer_layout(cfg)
    kinds = {"dense_ffn_layer"} if lead else set()
    if n_periods:
        kinds |= {tfm.effective_kind(k, cfg) for k in cfg.block_pattern}
    kinds |= {tfm.effective_kind(k, cfg) for k in tail_kinds}
    return frozenset(kinds)


def chunkable(cfg: ModelConfig) -> bool:
    """Can this stack prefill in chunks *bitwise-identically* to the
    unchunked prefill?  (The contract prefix caching and speculative
    verify require.)"""
    if cfg.is_encoder_decoder or cfg.frontend is not None:
        return False
    return stack_kinds(cfg) <= CHUNKABLE_KINDS


def chunkable_with_state(cfg: ModelConfig) -> bool:
    """Can this stack prefill in chunks at all — allowing state-carrying
    recurrent cells whose chunk boundaries regroup float reductions
    (token-equivalent, not bitwise)?  This is the engine's prefill_chunk
    gate; the stricter :func:`chunkable` still gates prefix/spec."""
    if cfg.is_encoder_decoder or cfg.frontend is not None:
        return False
    return stack_kinds(cfg) <= (CHUNKABLE_KINDS | STATEFUL_CHUNK_KINDS)


def _lane_state(cache, lane, start):
    """Slice lane ``lane``'s per-lane state leaves (axis 0), zeroed for
    the first chunk — a freed lane's leaves hold the previous occupant's
    stale state, which admission must not integrate."""
    st = jax.tree_util.tree_map(
        lambda v: jax.lax.dynamic_slice_in_dim(v, lane, 1, axis=0), cache)
    return jax.tree_util.tree_map(
        lambda v: jnp.where(start[0] == 0, jnp.zeros_like(v), v), st)


def _lane_state_update(cache, new_state, lane):
    """Write the (1, ...) state back into lane ``lane`` of every leaf."""
    return jax.tree_util.tree_map(
        lambda full, part: jax.lax.dynamic_update_slice(
            full, part.astype(full.dtype),
            (jnp.asarray(lane, jnp.int32),) + (0,) * (full.ndim - 1)),
        cache, new_state)


def _apply_block_chunk(x, p, kind: str, cfg: ModelConfig, cache, table_row,
                       lane, start, true_len, positions):
    """One block over a (1, C, d) chunk against the paged cache."""
    kind = tfm.effective_kind(kind, cfg)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if kind in STATEFUL_CHUNK_KINDS:
        # recurrent cells: state lives per-lane (axis 0 — scan already
        # peeled the stacked periods axis), carried chunk to chunk
        cell = {"rglru": rec.rglru_chunk, "mlstm": rec.mlstm_chunk,
                "slstm": rec.slstm_chunk}[kind]
        a, new_state = cell(h, p["cell"], cfg,
                            _lane_state(cache, lane, start), true_len[0])
        x = x + a
        cache = _lane_state_update(cache, new_state, lane)
        if kind == "rglru":  # rglru blocks carry their own norm2+MLP;
            h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)  # xLSTM blocks don't
            x = x + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode,
                            backend=cfg.gemm_backend)
        return x, cache
    if kind in ("attn", "dense_ffn_layer"):
        a, cache = attn.attention_chunk(h, p["attn"], cfg, cache, table_row,
                                        start, positions=positions)
    elif kind == "mla":
        a, cache = attn.mla_chunk(h, p["attn"], cfg, cache, table_row,
                                  start, positions=positions)
    else:
        raise ValueError(f"block kind {kind!r} is not chunkable")
    x = x + a
    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
    x = x + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode,
                    backend=cfg.gemm_backend)
    return x, cache


def make_chunk_step(cfg: ModelConfig, chunk_len: int):
    """Build the jittable chunk step.

    chunk_step(params, cache, tokens, lane, start, true_len)
        -> (last-valid-row logits (1, V), new cache)

    ``tokens``: (1, chunk_len) right-padded; ``start``: (1,) absolute
    position of the chunk's first token; ``true_len``: (1,) valid tokens
    in this chunk.  Padded tail rows write garbage pages that the next
    chunk (or the first decode step) overwrites before any query can
    attend them — the same argument that makes bucketed prefill exact.
    ``cache["pos"]`` for the lane is set to ``start + true_len`` so the
    final chunk leaves the lane decode-ready.
    """
    if not chunkable_with_state(cfg):
        raise ValueError(
            f"{cfg.name}: stack has non-chunkable kinds "
            f"{sorted(stack_kinds(cfg) - CHUNKABLE_KINDS - STATEFUL_CHUNK_KINDS)}")

    lead, n_periods, tail_kinds = tfm.layer_layout(cfg)

    def chunk_step(params, cache, tokens, lane, start, true_len):
        x = embed(tokens, params["embed"])
        positions = start[:, None] + jnp.arange(chunk_len, dtype=jnp.int32)[None, :]
        tables = cache["block_tables"]
        table_row = jax.lax.dynamic_slice(
            tables, (lane, 0), (1, tables.shape[1]))

        new_cache = dict(cache)
        new_cache["head_blocks"] = list(cache["head_blocks"])
        for i, p in enumerate(params.get("head_blocks", [])):
            x, c = _apply_block_chunk(x, p, "dense_ffn_layer", cfg,
                                      cache["head_blocks"][i], table_row,
                                      lane, start, true_len, positions)
            new_cache["head_blocks"][i] = c

        if params.get("blocks", ()):
            pattern = cfg.block_pattern

            def period_fn(h, xs):
                slot_params, slot_cache = xs
                out = []
                for s, kind in enumerate(pattern):
                    h, c = _apply_block_chunk(h, slot_params[s], kind, cfg,
                                              slot_cache[s], table_row,
                                              lane, start, true_len,
                                              positions)
                    out.append(c)
                return h, tuple(out)

            x, nb = jax.lax.scan(period_fn, x,
                                 (params["blocks"], cache["blocks"]),
                                 unroll=cfg.scan_unroll)
            new_cache["blocks"] = nb

        new_cache["tail_blocks"] = list(cache["tail_blocks"])
        for i, p in enumerate(params.get("tail_blocks", [])):
            x, c = _apply_block_chunk(x, p, tail_kinds[i], cfg,
                                      cache["tail_blocks"][i], table_row,
                                      lane, start, true_len, positions)
            new_cache["tail_blocks"][i] = c

        new_cache["pos"] = cache["pos"].at[lane].set(
            (start[0] + true_len[0]).astype(jnp.int32))

        idx = jnp.clip(true_len - 1, 0, chunk_len - 1)          # (1,)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        h = rmsnorm(x_last, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = unembed(h, table)[:, 0, :]
        return logits, new_cache

    return chunk_step
