"""Paged KV-cache subsystem: a global device-resident page pool shared by
every serving lane, per-lane block tables, and chunked prefill.

* ``manager.PageManager`` — host-side (numpy) page bookkeeping: alloc /
  free / reservations / defrag, mirrored into a jit-visible int32 block
  table so the decode step never retraces.
* ``cache.PagedCache``   — the device pools (one per layer, built from
  ``models/kvcache.paged_block_cache_shape``) + traceable page scatter.
* ``prefill.make_chunk_step`` — page-sized chunked prefill, so one long
  admission interleaves with in-flight decodes instead of stalling them.

Attention itself lives where the rest of the model math lives:
``models/attention.paged_attention_decode`` (jnp gather twin and the
``kernels/paged_attention`` Pallas kernel) behind the cache-kind dispatch
in ``models/transformer.apply_block_decode``.
"""

from repro.paging.cache import PagedCache, paged_insert, paged_insert_many
from repro.paging.manager import PageManager
from repro.paging.prefill import (
    CHUNKABLE_KINDS,
    STATEFUL_CHUNK_KINDS,
    chunkable,
    chunkable_with_state,
    make_chunk_step,
    stack_kinds,
)

__all__ = [
    "CHUNKABLE_KINDS",
    "STATEFUL_CHUNK_KINDS",
    "PageManager",
    "PagedCache",
    "chunkable",
    "chunkable_with_state",
    "make_chunk_step",
    "paged_insert",
    "paged_insert_many",
    "stack_kinds",
]
