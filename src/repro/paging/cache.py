"""Device-side paged KV cache: page pools + traceable scatter/insert.

``PagedCache`` is the paged counterpart of ``serving.slots.SlotCache``:
one pool pytree allocated once (built from
``models/model.paged_cache_shapes``), with attention-family KV in global
``(n_pages, page_size, ...)`` pools and per-lane state (recurrent cells,
local-attention rings, ``pos``) in lane-indexed leaves.  Host bookkeeping
lives in ``manager.PageManager``; the block table is the only
host-mutated array the jitted decode step reads.

``paged_insert`` is traceable so the engine can fuse
prefill + first-token sample + page scatter into ONE dispatch, exactly
like the slot engine's fused admission.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, default_page_count, pages_for
from repro.models import model as model_lib
from repro.models.kvcache import zeros_like_shapes
from repro.paging.manager import PageManager

# paged-pool leaf -> the key holding the same rows in a contiguous
# (batch=1) prefill cache from ``model.prefill``
_POOL_KEY_MAP = {
    "kp": "k", "vp": "v", "kp_scale": "k_scale", "vp_scale": "v_scale",
    "ckvp": "ckv", "krp": "kr",
}


def _lane_update(full, part, lane, axis):
    """Write the batch=1 ``part`` into lane ``lane`` of ``full`` (per-lane
    leaves: recurrent state, local-attn rings)."""
    starts = tuple(
        jnp.asarray(lane, jnp.int32) if i == axis else 0
        for i in range(full.ndim)
    )
    return jax.lax.dynamic_update_slice(full, part.astype(full.dtype), starts)


def _batch_row(part, row: int, axis: int):
    """Row ``row`` of a batch=k leaf, keeping a size-1 batch axis."""
    idx = [slice(None)] * part.ndim
    idx[axis] = slice(row, row + 1)
    return part[tuple(idx)]


def _scatter_block(pool_blk, single_blk, lane, page_ids, stacked: bool,
                   src_row: int = 0):
    """Insert one layer(-stack)'s prefill cache: paged dicts scatter whole
    pages, per-lane dicts scatter the lane row.  ``stacked`` marks leaves
    with a leading scanned-period axis; ``src_row`` picks the batch row of
    a batch=k prefill cache (stacked admissions scatter one row per lane)."""
    if any(k in pool_blk for k in ("kp", "ckvp")):
        out = {}
        for pk, leaf in pool_blk.items():
            src = single_blk[_POOL_KEY_MAP[pk]]
            if stacked:
                rows = src[:, src_row]                # (periods, S, ...)
                ps = leaf.shape[2]
                rows = rows.reshape(
                    (rows.shape[0], rows.shape[1] // ps, ps) + rows.shape[2:])
                out[pk] = leaf.at[:, page_ids].set(rows.astype(leaf.dtype))
            else:
                rows = src[src_row]                   # (S, ...)
                ps = leaf.shape[1]
                rows = rows.reshape(
                    (rows.shape[0] // ps, ps) + rows.shape[1:])
                out[pk] = leaf.at[page_ids].set(rows.astype(leaf.dtype))
        return out
    axis = 1 if stacked else 0
    return jax.tree_util.tree_map(
        lambda full, part: _lane_update(full, _batch_row(part, src_row, axis),
                                        lane, axis),
        pool_blk, single_blk)


def paged_insert(cache, single, lane, page_ids, table_row, new_len):
    """Scatter a batch=1 contiguous prefill cache into the page pools.

    ``single`` must hold exactly ``len(page_ids) * page_size`` cache rows
    (the engine sizes the admission prefill that way); ``table_row`` is the
    lane's full (max_pages,) block-table row, written to the device table
    in the same dispatch.  Traceable — the engine fuses it into admission.
    """
    new = dict(cache)
    new["pos"] = cache["pos"].at[lane].set(new_len.astype(jnp.int32))
    new["block_tables"] = cache["block_tables"].at[lane].set(table_row)
    new["head_blocks"] = [
        _scatter_block(pb, sb, lane, page_ids, stacked=False)
        for pb, sb in zip(cache["head_blocks"], single["head_blocks"])
    ]
    new["blocks"] = tuple(
        _scatter_block(pb, sb, lane, page_ids, stacked=True)
        for pb, sb in zip(cache["blocks"], single["blocks"])
    )
    new["tail_blocks"] = [
        _scatter_block(pb, sb, lane, page_ids, stacked=False)
        for pb, sb in zip(cache["tail_blocks"], single["tail_blocks"])
    ]
    return new


def paged_insert_many(cache, multi, lanes, page_ids, table_rows, new_lens,
                      k: int):
    """Scatter a batch=``k`` prefill cache into ``k`` lanes' pages — the
    stacked-admission counterpart of :func:`paged_insert` (same-bucket
    prompts share ONE prefill dispatch; each batch row lands in its own
    lane's pages).  ``page_ids``: (k, n_pages_per_lane); ``table_rows``:
    (k, max_pages); ``new_lens``: (k,).  ``k`` is static (trace key), so
    the loop unrolls.  Traceable — the engine fuses it into its batched
    paged admission."""
    new = dict(cache)
    pos, tables = cache["pos"], cache["block_tables"]
    for i in range(k):
        pos = pos.at[lanes[i]].set(new_lens[i].astype(jnp.int32))
        tables = tables.at[lanes[i]].set(table_rows[i])
    new["pos"], new["block_tables"] = pos, tables

    def scatter_group(pool_blocks, multi_blocks, stacked):
        out = []
        for pb, mb in zip(pool_blocks, multi_blocks):
            for i in range(k):
                pb = _scatter_block(pb, mb, lanes[i], page_ids[i], stacked,
                                    src_row=i)
            out.append(pb)
        return out

    new["head_blocks"] = scatter_group(cache["head_blocks"],
                                       multi["head_blocks"], stacked=False)
    new["blocks"] = tuple(scatter_group(cache["blocks"], multi["blocks"],
                                        stacked=True))
    new["tail_blocks"] = scatter_group(cache["tail_blocks"],
                                       multi["tail_blocks"], stacked=False)
    return new


# module-level jit shared across engine instances (mirrors slots._scatter_lane)
_paged_insert = jax.jit(paged_insert, donate_argnums=(0,))


def _move_pages_block(blk, src, dst, stacked: bool):
    if not any(k in blk for k in ("kp", "ckvp")):
        return blk
    if stacked:
        return {k: leaf.at[:, dst].set(leaf[:, src]) for k, leaf in blk.items()}
    return {k: leaf.at[dst].set(leaf[src]) for k, leaf in blk.items()}


def _move_pages(cache, src, dst):
    """Copy pool pages ``src -> dst`` in every layer (defrag compaction)."""
    new = dict(cache)
    new["head_blocks"] = [_move_pages_block(b, src, dst, False)
                          for b in cache["head_blocks"]]
    new["blocks"] = tuple(_move_pages_block(b, src, dst, True)
                          for b in cache["blocks"])
    new["tail_blocks"] = [_move_pages_block(b, src, dst, False)
                          for b in cache["tail_blocks"]]
    return new


_move_pages_jit = jax.jit(_move_pages, donate_argnums=(0,))


class PagedCache:
    """Engine-owned paged pool: ``n_lanes`` block-table rows over
    ``n_pages`` physical pages of ``page_size`` rows each."""

    def __init__(self, cfg: ModelConfig, n_lanes: int, cache_len: int,
                 page_size: int, n_pages: int | None = None, mesh=None):
        self.n_lanes = n_lanes
        self.cache_len = cache_len
        self.page_size = page_size
        self.max_pages = pages_for(cache_len, page_size)
        self.n_pages = (default_page_count(n_lanes, cache_len, page_size)
                        if n_pages is None else n_pages)
        shapes = model_lib.paged_cache_shapes(
            cfg, n_lanes, cache_len, page_size, self.n_pages)
        self.mesh = mesh
        self._table_sharding = None
        if mesh is not None:
            # sharded serving: commit the pools to their TP layout (KV
            # heads over "model", block tables + pos replicated — see
            # runtime/sharding.pool_specs).  Committing here, once, means
            # every later jit (admission, decode, insert, defrag moves)
            # inherits the layout through donation instead of re-deciding
            # it; the host-side PageManager stays the single block-table
            # owner and its uploads re-commit to the replicated sharding
            # so the decode program never changes between steps.
            from repro.runtime.sharding import named, pool_specs

            shardings = named(pool_specs(shapes, mesh), mesh)
            self.cache = jax.device_put(zeros_like_shapes(shapes), shardings)
            self._table_sharding = shardings["block_tables"]
        else:
            self.cache = zeros_like_shapes(shapes)
        self.manager = PageManager(self.n_pages, page_size, n_lanes,
                                   self.max_pages)

    def insert(self, single_cache, lane: int, page_ids, new_len) -> None:
        """Standalone (non-fused) insert — tests and defrag verification;
        the engine uses the traceable ``paged_insert`` inside its fused
        admission jit instead."""
        self.cache = _paged_insert(
            self.cache, single_cache, jnp.int32(lane),
            jnp.asarray(page_ids, jnp.int32),
            jnp.asarray(self.manager.block_tables[lane]),
            jnp.asarray(new_len, jnp.int32))

    def sync_tables(self) -> None:
        """Upload the host block table if growth/free/defrag changed it."""
        if self.manager.dirty:
            tables = jnp.asarray(self.manager.block_tables)
            if self._table_sharding is not None:
                # keep the upload committed-replicated: a mix of committed
                # and uncommitted table inputs would give the decode jit
                # two distinct input shardings (two compiles) for one
                # logical program
                tables = jax.device_put(tables, self._table_sharding)
            self.cache = {**self.cache, "block_tables": tables}
            self.manager.dirty = False

    def free(self, lane: int) -> int:
        """Release a lane's pages back to the pool (same step)."""
        n = self.manager.free_lane(lane)
        return n

    def copy_pages(self, src, dst) -> None:
        """Duplicate pool pages ``src -> dst`` in every layer (CoW fork:
        the source keeps its bytes for the other holders; the destination
        becomes the forking lane's private copy).  Reuses the defrag move
        kernel — a move IS a copy that leaves the source untouched."""
        self.cache = _move_pages_jit(
            self.cache, jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32))
        self.sync_tables()

    def defrag(self) -> list:
        """Compact the pool; returns the ``(src, dst)`` move pairs applied
        (the flight recorder journals them as the defrag's operands)."""
        moves = self.manager.defrag()
        if moves:
            src = jnp.asarray([s for s, _ in moves], jnp.int32)
            dst = jnp.asarray([d for _, d in moves], jnp.int32)
            self.cache = _move_pages_jit(self.cache, src, dst)
            self.sync_tables()
        return moves

    @property
    def pos(self):
        return self.cache["pos"]
