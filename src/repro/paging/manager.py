"""Host-side page-pool bookkeeping for the paged KV cache.

All state here is plain numpy / Python — the device only ever sees the
``(n_lanes, max_pages_per_lane)`` int32 block table (uploaded when it
changes, fixed shape, so the jitted decode step never retraces) and the
page pools themselves (``cache.PagedCache``).

Physical page 0 is **reserved as the trash page**: idle lanes still ride
the fixed-shape decode step, and their garbage K/V write is redirected
there (``models/attention._write_page``).  Unlike the slot cache — where a
stale lane can only scribble on itself — paged lanes write through a table
into pages that may already belong to someone else, so the redirect is a
correctness requirement, not hygiene.

Admission uses *reservations*: a lane reserves its worst-case page count
(prompt + generation budget) up front, but pages are only materialized as
the sequence actually grows.  Reservations make mid-decode pool exhaustion
impossible while still packing mixed-length traffic far tighter than the
slot cache's ``n_slots x cache_len`` worst-case allocation — short
requests reserve few pages, so more of them fit the same KV budget.
"""

from __future__ import annotations

import heapq

import numpy as np

from repro.configs.base import pages_for

TRASH_PAGE = 0


class PageManager:
    def __init__(self, n_pages: int, page_size: int, n_lanes: int,
                 max_pages_per_lane: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        if page_size < 1 or max_pages_per_lane < 1:
            raise ValueError("page_size and max_pages_per_lane must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_lanes = n_lanes
        self.max_pages_per_lane = max_pages_per_lane
        # lowest-index-first like the slot scheduler: deterministic layouts
        self._free: list[int] = list(range(1, n_pages))
        heapq.heapify(self._free)
        self.block_tables = np.zeros((n_lanes, max_pages_per_lane), np.int32)
        self.lane_pages: list[list[int]] = [[] for _ in range(n_lanes)]
        self.lengths = np.zeros((n_lanes,), np.int64)   # valid rows per lane
        self.reserved = np.zeros((n_lanes,), np.int64)  # promised page counts
        # device table out of date? (set by free/growth/defrag; admission
        # writes its row inside the fused insert jit instead)
        self.dirty = False

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    @property
    def outstanding(self) -> int:
        """Pages promised to admitted lanes but not yet materialized."""
        return int(sum(max(int(self.reserved[l]) - len(self.lane_pages[l]), 0)
                       for l in range(self.n_lanes)))

    @property
    def available(self) -> int:
        """Pages an admission may still reserve without risking mid-decode
        exhaustion of already-admitted lanes."""
        return len(self._free) - self.outstanding

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_admit(self, reserve_tokens: int) -> bool:
        return self.pages_for(reserve_tokens) <= self.available

    # -- lane lifecycle ----------------------------------------------------
    def admit(self, lane: int, reserve_tokens: int) -> None:
        """Reserve worst-case capacity for a lane about to prefill."""
        if self.lane_pages[lane]:
            raise RuntimeError(f"lane {lane} already holds pages")
        need = self.pages_for(reserve_tokens)
        if need > self.max_pages_per_lane:
            raise ValueError(
                f"request needs {need} pages but lanes hold at most "
                f"{self.max_pages_per_lane} (cache_len / page_size)")
        if need > self.available:
            raise RuntimeError(
                f"admitting {need} pages would overcommit the pool "
                f"({self.available} available of {self.n_pages - 1})")
        self.reserved[lane] = need
        self.lengths[lane] = 0

    def alloc(self, lane: int, n: int = 1) -> list[int]:
        """Materialize ``n`` pages for a lane (within its reservation)."""
        held = self.lane_pages[lane]
        if len(held) + n > self.max_pages_per_lane:
            raise RuntimeError(f"lane {lane} exceeds its block table width")
        if n > len(self._free):
            raise RuntimeError("page pool exhausted (reservation bug?)")
        got = [heapq.heappop(self._free) for _ in range(n)]
        for p in got:
            self.block_tables[lane, len(held)] = p
            held.append(p)
        return got

    def ensure(self, lane: int, tokens: int) -> list[int]:
        """Allocate pages until the lane covers ``tokens`` rows."""
        need = self.pages_for(tokens) - len(self.lane_pages[lane])
        if need <= 0:
            return []
        self.dirty = True
        return self.alloc(lane, need)

    def set_length(self, lane: int, tokens: int) -> None:
        self.lengths[lane] = tokens

    def advance(self, lanes) -> None:
        """One decode step: each active lane grew by one row."""
        for lane in lanes:
            self.lengths[lane] += 1

    def free_lane(self, lane: int) -> int:
        """Release a lane; its pages return to the pool the same step."""
        pages = self.lane_pages[lane]
        n = len(pages)
        for p in pages:
            heapq.heappush(self._free, p)
        pages.clear()
        self.block_tables[lane, :] = TRASH_PAGE
        self.lengths[lane] = 0
        self.reserved[lane] = 0
        self.dirty = True
        return n

    # -- defrag ------------------------------------------------------------
    def defrag(self) -> list[tuple[int, int]]:
        """Compact allocated pages onto the lowest physical indices.

        Returns ``(src, dst)`` moves for the device-side pool copy
        (``PagedCache.defrag`` applies them); block tables are remapped
        here.  After compaction the used set is exactly
        ``[1, pages_in_use]``, so a long-running pool's free list stays
        contiguous no matter the alloc/free history.
        """
        used = sorted(p for pages in self.lane_pages for p in pages)
        targets = set(range(1, len(used) + 1))
        vacant = sorted(targets - set(used))
        moves: list[tuple[int, int]] = []
        remap = {}
        for p in sorted(used, reverse=True):
            if p in targets:
                continue
            dst = vacant.pop(0)
            remap[p] = dst
            moves.append((p, dst))
        if not moves:
            return []
        for lane, pages in enumerate(self.lane_pages):
            for j, p in enumerate(pages):
                if p in remap:
                    pages[j] = remap[p]
                    self.block_tables[lane, j] = remap[p]
        self._free = list(range(len(used) + 1, self.n_pages))
        heapq.heapify(self._free)
        self.dirty = True
        return moves
