"""Host-side page-pool bookkeeping for the paged KV cache.

All state here is plain numpy / Python — the device only ever sees the
``(n_lanes, max_pages_per_lane)`` int32 block table (uploaded when it
changes, fixed shape, so the jitted decode step never retraces) and the
page pools themselves (``cache.PagedCache``).

Physical page 0 is **reserved as the trash page**: idle lanes still ride
the fixed-shape decode step, and their garbage K/V write is redirected
there (``models/attention._write_page``).  Unlike the slot cache — where a
stale lane can only scribble on itself — paged lanes write through a table
into pages that may already belong to someone else, so the redirect is a
correctness requirement, not hygiene.

Admission uses *reservations*: a lane reserves its worst-case page count
(prompt + generation budget) up front, but pages are only materialized as
the sequence actually grows.  Reservations make mid-decode pool exhaustion
impossible while still packing mixed-length traffic far tighter than the
slot cache's ``n_slots x cache_len`` worst-case allocation — short
requests reserve few pages, so more of them fit the same KV budget.

Pages are **refcounted** so the shared-prefix cache (``repro/prefix/``)
can alias one physical page into many lanes' block tables: a lane's own
allocation holds one reference, each adopting lane adds one, and the
prefix tree (when it publishes the page) adds one more.  A page returns
to the free list only when its count reaches zero — so freeing a lane
whose prompt pages live in the tree releases just its private tail.  A
lane must never *write* a page it shares: ``ensure_writable`` (and the
planned forks the engine takes at admission) copy-on-write forks the page
into a private copy first, leaving every other holder aliasing the
original bytes.

Tensor parallelism does not change anything in this file.  Under a device
mesh the *pools* are sharded over "model" (each device holds its KV-head
slice of every physical page — see ``cache.PagedCache``), while the block
tables stay host-authoritative here and are uploaded **replicated** across
the mesh: every device indexes its own pool shard through the same
logical page numbers, so alloc / free / COW / defrag remain single-threaded
numpy exactly as below, and prefill / decode / verify each stay one pjit
dispatch per step with no per-device bookkeeping.
"""

from __future__ import annotations

import heapq
from typing import Callable, Sequence

import numpy as np

from repro.configs.base import pages_for

TRASH_PAGE = 0


class PageManager:
    def __init__(self, n_pages: int, page_size: int, n_lanes: int,
                 max_pages_per_lane: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        if page_size < 1 or max_pages_per_lane < 1:
            raise ValueError("page_size and max_pages_per_lane must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_lanes = n_lanes
        self.max_pages_per_lane = max_pages_per_lane
        # lowest-index-first like the slot scheduler: deterministic layouts
        self._free: list[int] = list(range(1, n_pages))
        heapq.heapify(self._free)
        self.block_tables = np.zeros((n_lanes, max_pages_per_lane), np.int32)
        self.lane_pages: list[list[int]] = [[] for _ in range(n_lanes)]
        self.lengths = np.zeros((n_lanes,), np.int64)   # valid rows per lane
        self.reserved = np.zeros((n_lanes,), np.int64)  # promised page counts
        # holders per physical page: lane references + the prefix tree's
        # (page 0, the trash page, is never allocated and never counted)
        self.refcount = np.zeros((n_pages,), np.int64)
        # tree-held references (subset of refcount), for invariant checks
        self.tree_held = np.zeros((n_pages,), bool)
        # device table out of date? (set by free/growth/adopt/fork/defrag;
        # admission writes its row inside the fused insert jit instead)
        self.dirty = False
        # prefix-tree page remap hooks, called with {src: dst} after defrag
        self.remap_listeners: list[Callable[[dict[int, int]], None]] = []

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Physical pages somebody references (lanes and/or the tree)."""
        return (self.n_pages - 1) - len(self._free)

    @property
    def span(self) -> int:
        """Highest referenced physical page index (0 when pool is empty)."""
        used = np.nonzero(self.refcount)[0]
        return int(used.max()) if used.size else 0

    @property
    def outstanding(self) -> int:
        """Pages promised to admitted lanes but not yet materialized."""
        return int(sum(max(int(self.reserved[l]) - len(self.lane_pages[l]), 0)
                       for l in range(self.n_lanes)))

    @property
    def available(self) -> int:
        """Pages an admission may still reserve without risking mid-decode
        exhaustion of already-admitted lanes."""
        return len(self._free) - self.outstanding

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_admit(self, reserve_tokens: int) -> bool:
        return self.pages_for(reserve_tokens) <= self.available

    # -- lane lifecycle ----------------------------------------------------
    def admit(self, lane: int, reserve_tokens: int,
              adopt_pages: Sequence[int] = (), forks: int = 0) -> None:
        """Reserve worst-case capacity for a lane about to prefill.

        ``adopt_pages`` are shared-prefix pages the lane aliases instead of
        drawing from the pool; ``forks`` is how many of those the admission
        will copy-on-write fork (each fork draws one fresh page).  The
        capacity gate therefore checks the *pool draw*:
        ``pages_for(reserve) - len(adopt_pages) + forks``.
        """
        if self.lane_pages[lane]:
            raise RuntimeError(f"lane {lane} already holds pages")
        need = self.pages_for(reserve_tokens)
        if need > self.max_pages_per_lane:
            raise ValueError(
                f"request needs {need} pages but lanes hold at most "
                f"{self.max_pages_per_lane} (cache_len / page_size)")
        draw = need - len(adopt_pages) + forks
        if draw > self.available:
            raise RuntimeError(
                f"admitting {draw} pages would overcommit the pool "
                f"({self.available} available of {self.n_pages - 1})")
        self.reserved[lane] = need
        self.lengths[lane] = 0
        if adopt_pages:
            self.adopt(lane, adopt_pages)

    def alloc(self, lane: int, n: int = 1) -> list[int]:
        """Materialize ``n`` pages for a lane (within its reservation)."""
        held = self.lane_pages[lane]
        if len(held) + n > self.max_pages_per_lane:
            raise RuntimeError(f"lane {lane} exceeds its block table width")
        if n > len(self._free):
            raise RuntimeError("page pool exhausted (reservation bug?)")
        got = [heapq.heappop(self._free) for _ in range(n)]
        for p in got:
            self.refcount[p] = 1
            self.block_tables[lane, len(held)] = p
            held.append(p)
        return got

    def adopt(self, lane: int, pages: Sequence[int]) -> None:
        """Alias already-referenced ``pages`` into the lane's block table
        (shared-prefix seeding): ref +1 each, no pool draw."""
        held = self.lane_pages[lane]
        if len(held) + len(pages) > self.max_pages_per_lane:
            raise RuntimeError(f"lane {lane} exceeds its block table width")
        for p in pages:
            if self.refcount[p] < 1:
                raise RuntimeError(f"adopting unreferenced page {p}")
            self.refcount[p] += 1
            self.block_tables[lane, len(held)] = p
            held.append(p)
        self.dirty = True

    def ensure(self, lane: int, tokens: int) -> list[int]:
        """Allocate pages until the lane covers ``tokens`` rows."""
        need = self.pages_for(tokens) - len(self.lane_pages[lane])
        if need <= 0:
            return []
        self.dirty = True
        return self.alloc(lane, need)

    def cow_fork(self, lane: int, page_idx: int) -> tuple[int, int]:
        """Copy-on-write fork: replace the lane's shared page at
        ``page_idx`` with a fresh private page.  Returns ``(src, dst)`` —
        the caller copies the device rows (``PagedCache.copy_pages``).
        The source keeps its other holders' references untouched."""
        src = self.lane_pages[lane][page_idx]
        if self.refcount[src] <= 1:
            raise RuntimeError(f"page {src} is not shared; nothing to fork")
        if not self._free:
            raise RuntimeError("page pool exhausted (fork unaccounted?)")
        dst = heapq.heappop(self._free)
        self.refcount[dst] = 1
        self.refcount[src] -= 1
        self.lane_pages[lane][page_idx] = dst
        self.block_tables[lane, page_idx] = dst
        self.dirty = True
        return src, dst

    def ensure_writable(self, lane: int, row: int) -> "tuple[int, int] | None":
        """CoW guard before a lane writes ``row``: if the covering page is
        shared, fork it.  Returns the ``(src, dst)`` copy the caller must
        apply on device, or None (the common case: page private or not yet
        materialized)."""
        idx = row // self.page_size
        held = self.lane_pages[lane]
        if idx >= len(held) or self.refcount[held[idx]] <= 1:
            return None
        return self.cow_fork(lane, idx)

    def ensure_writable_range(self, lane: int, start: int, n: int
                              ) -> "list[tuple[int, int]]":
        """CoW guard before a lane writes rows ``start .. start + n - 1``
        (the speculative verify window): fork every shared page the range
        covers.  Returns the ``(src, dst)`` copies the caller must apply
        on device (empty in the common all-private case)."""
        if n <= 0:
            return []
        held = self.lane_pages[lane]
        moves = []
        first = start // self.page_size
        last = (start + n - 1) // self.page_size
        for idx in range(first, min(last + 1, len(held))):
            if self.refcount[held[idx]] > 1:
                moves.append(self.cow_fork(lane, idx))
        return moves

    def set_length(self, lane: int, tokens: int) -> None:
        self.lengths[lane] = tokens

    def advance(self, lanes) -> None:
        """One decode step: each active lane grew by one row."""
        for lane in lanes:
            self.lengths[lane] += 1

    def free_lane(self, lane: int) -> int:
        """Release a lane: ref -1 on every held page; pages nobody else
        holds (no other lane, not the prefix tree) return to the pool the
        same step.  Returns the number of pages actually freed."""
        pages = self.lane_pages[lane]
        n = 0
        for p in pages:
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                heapq.heappush(self._free, p)
                n += 1
        pages.clear()
        self.block_tables[lane, :] = TRASH_PAGE
        self.lengths[lane] = 0
        self.reserved[lane] = 0
        self.dirty = True
        return n

    # -- prefix-tree references -------------------------------------------
    def tree_ref(self, pages: Sequence[int]) -> None:
        """The prefix tree now references ``pages`` (publish)."""
        for p in pages:
            if self.refcount[p] < 1:
                raise RuntimeError(f"tree publishing unreferenced page {p}")
            if self.tree_held[p]:
                raise RuntimeError(f"tree already holds page {p}")
            self.refcount[p] += 1
            self.tree_held[p] = True

    def tree_unref(self, pages: Sequence[int]) -> int:
        """Tree eviction: drop the tree's reference; pages with no other
        holder return to the pool.  Returns pages actually freed."""
        n = 0
        for p in pages:
            if not self.tree_held[p]:
                raise RuntimeError(f"tree does not hold page {p}")
            self.tree_held[p] = False
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                heapq.heappush(self._free, p)
                n += 1
        return n

    # -- defrag ------------------------------------------------------------
    def defrag(self) -> list[tuple[int, int]]:
        """Compact referenced pages onto the lowest physical indices.

        Returns ``(src, dst)`` moves for the device-side pool copy
        (``PagedCache.defrag`` applies them); block tables are remapped
        here.  Shared pages move ONCE — every lane aliasing a page (and
        the prefix tree, via ``remap_listeners``) is remapped to the same
        destination, so aliasing survives compaction.  After compaction
        the used set is exactly ``[1, pages_in_use]``, so a long-running
        pool's free list stays contiguous no matter the alloc/free
        history.
        """
        used = sorted(int(p) for p in np.nonzero(self.refcount)[0])
        targets = set(range(1, len(used) + 1))
        vacant = sorted(targets - set(used))
        moves: list[tuple[int, int]] = []
        remap = {}
        for p in sorted(used, reverse=True):
            if p in targets:
                continue
            dst = vacant.pop(0)
            remap[p] = dst
            moves.append((p, dst))
        if not moves:
            return []
        for lane, pages in enumerate(self.lane_pages):
            for j, p in enumerate(pages):
                if p in remap:
                    pages[j] = remap[p]
                    self.block_tables[lane, j] = remap[p]
        for src, dst in moves:
            self.refcount[dst] = self.refcount[src]
            self.refcount[src] = 0
            self.tree_held[dst] = self.tree_held[src]
            self.tree_held[src] = False
        for listener in self.remap_listeners:
            listener(remap)
        self._free = list(range(len(used) + 1, self.n_pages))
        heapq.heapify(self._free)
        self.dirty = True
        return moves

    # -- invariants (property tests + the engine's debug mode poke this) ---
    def invariant_violations(self) -> list[str]:
        """Every bookkeeping inconsistency as a human-readable string:
        refcounts must match actual holders, nothing may be simultaneously
        free and referenced, and block tables must mirror the lane page
        lists.  Empty list = pool consistent.  Non-raising so the engine's
        ``debug_invariants`` mode can log the full set as one structured
        event before failing."""
        out: list[str] = []
        if (self.refcount < 0).any():
            out.append("negative refcount")
        holders = np.zeros_like(self.refcount)
        for pages in self.lane_pages:
            for p in pages:
                holders[p] += 1
        holders[self.tree_held] += 1
        if not (holders == self.refcount).all():
            bad = np.nonzero(holders != self.refcount)[0]
            out.append(f"refcount mismatch on pages {bad.tolist()}")
        free = set(self._free)
        if len(free) != len(self._free):
            out.append("duplicate pages on the free list")
        if TRASH_PAGE in free:
            out.append("trash page on the free list")
        referenced = set(int(p) for p in np.nonzero(self.refcount)[0])
        both = free & referenced
        if both:
            out.append(f"pages both free and referenced: {sorted(both)}")
        elif len(free) + len(referenced) != self.n_pages - 1:
            out.append("pages leaked (neither free nor referenced)")
        for lane, pages in enumerate(self.lane_pages):
            if self.block_tables[lane, :len(pages)].tolist() != pages:
                out.append(f"lane {lane} table/page-list mismatch")
        return out

    def check_invariants(self) -> None:
        """Raise on the first inconsistency ``invariant_violations`` finds
        (the property-test surface; unchanged behaviour)."""
        bad = self.invariant_violations()
        if bad:
            raise AssertionError(bad[0])
