"""Optimizers from scratch (no optax): AdamW, SGD, schedules, clipping.

State layout is a plain dict pytree so checkpointing and ZeRO-1 sharding
specs (runtime/sharding.py:zero1_specs) apply uniformly.  Adam moments are
fp32 regardless of param dtype (the paper's >=16-bit accumulation rule,
applied to the optimizer).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def make_schedule(cfg: TrainConfig):
    """step -> learning rate (fp32 scalar)."""

    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "linear":
            frac = jnp.clip(
                (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
            )
            decay = 1.0 - frac
        else:  # cosine
            frac = jnp.clip(
                (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
            )
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.learning_rate * warm * decay

    return sched


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    """Moments + fp32 MASTER weights (params themselves are stored bf16)."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        # copy=True: for fp32 params astype is a no-op returning the SAME
        # buffer — the master leaf would alias the param leaf and the jit'd
        # train step (which donates both trees) would donate one buffer twice.
        "master": jax.tree_util.tree_map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
    }


def adamw_update(params, grads, state, cfg: TrainConfig, lr=None):
    """Returns (new_params, new_state, metrics).

    The update runs entirely on the fp32 master copy; the bf16 params
    emitted for the next forward are a cast of the new master.
    """
    step = state["step"] + 1
    if lr is None:
        lr = make_schedule(cfg)(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        gf = g.astype(jnp.float32)
        m1 = b1 * m + (1 - b1) * gf
        v1 = b2 * v + (1 - b2) * gf * gf
        mhat = m1 / bc1
        vhat = v1 / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * master
        master1 = master - lr * delta
        return master1.astype(p.dtype), m1, v1, master1

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    flat_w = jax.tree_util.tree_leaves(state["master"])
    out = [upd(p, g, m, v, w) for p, g, m, v, w in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    new_w = jax.tree_util.tree_unflatten(tree, [o[3] for o in out])
    return (
        new_p,
        {"step": step, "m": new_m, "v": new_v, "master": new_w},
        {"grad_norm": gnorm, "lr": lr},
    )


# ---------------------------------------------------------------------------
# SGD (momentum)
# ---------------------------------------------------------------------------

def sgd_init(params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "mom": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def sgd_update(params, grads, state, cfg: TrainConfig, momentum=0.9, lr=None):
    step = state["step"] + 1
    if lr is None:
        lr = make_schedule(cfg)(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(p, g, m):
        m1 = momentum * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m1).astype(p.dtype), m1

    pairs = jax.tree_util.tree_map(upd, params, grads, state["mom"])
    new_p = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"step": step, "mom": new_m}, {"grad_norm": gnorm, "lr": lr}
