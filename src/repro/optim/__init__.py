from repro.optim.optimizers import (
    adamw_init,
    adamw_update,
    sgd_init,
    sgd_update,
    make_schedule,
    global_norm,
    clip_by_global_norm,
)

__all__ = [
    "adamw_init",
    "adamw_update",
    "sgd_init",
    "sgd_update",
    "make_schedule",
    "global_norm",
    "clip_by_global_norm",
]
