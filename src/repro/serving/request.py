"""Request lifecycle for the continuous-batching engine.

A request moves WAITING -> RUNNING -> FINISHED.  Single-shot admission
(prefill + first sampled token) happens inside one engine step, so a
request is RUNNING from the moment its KV cache occupies a slot; only the
paged engine's *chunked* admissions pass through PREFILLING, holding their
slot across the steps that feed the prompt in page-sized chunks.  All
bookkeeping here is host-side Python — device state lives in
``slots.SlotCache`` / ``paging.PagedCache``.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Optional

from repro.serving.sampling import SamplingParams


class RequestState(enum.Enum):
    WAITING = "waiting"      # queued, no slot yet
    PREFILLING = "prefilling"  # slot held, prompt chunks still streaming in
    RUNNING = "running"      # occupies a slot, decoding
    FINISHED = "finished"    # evicted; outputs final


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens in, sampled tokens out."""

    req_id: int
    prompt: list[int]
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_token: Optional[int] = None
    # Streaming hook: called with each sampled token as it reaches the
    # host.  The engine's lazy pulls are forced eager for streaming
    # requests (tokens surface every step instead of at sync points), so a
    # callback trades a little decode-dispatch overlap for latency.
    on_token: Optional[Callable[[int], None]] = dataclasses.field(
        default=None, repr=False)

    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    output_tokens: list[int] = dataclasses.field(default_factory=list)
    # chunked admission progress: prompt tokens already prefilled
    prefill_done: int = 0

    # wall-clock timeline (engine-stamped)
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        if len(self.output_tokens) >= self.max_new_tokens:
            return True
        return (self.eos_token is not None and self.output_tokens
                and self.output_tokens[-1] == self.eos_token)

    def append_token(self, tok: int) -> None:
        if self.first_token_time is None:
            self.first_token_time = time.perf_counter()
        self.output_tokens.append(tok)
        if self.on_token is not None:
            self.on_token(tok)

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (submit -> first sampled token)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.submit_time
