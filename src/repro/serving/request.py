"""Request lifecycle for the continuous-batching engine.

A request moves WAITING -> RUNNING -> FINISHED.  There is no separate
PREFILL state: admission (prefill + first sampled token) happens inside one
engine step, so a request is RUNNING from the moment its KV cache occupies a
slot.  All bookkeeping here is host-side Python — device state lives in
``slots.SlotCache``.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Optional

from repro.serving.sampling import SamplingParams


class RequestState(enum.Enum):
    WAITING = "waiting"      # queued, no slot yet
    RUNNING = "running"      # occupies a slot, decoding
    FINISHED = "finished"    # evicted; outputs final


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens in, sampled tokens out."""

    req_id: int
    prompt: list[int]
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_token: Optional[int] = None

    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    output_tokens: list[int] = dataclasses.field(default_factory=list)

    # wall-clock timeline (engine-stamped)
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        if len(self.output_tokens) >= self.max_new_tokens:
            return True
        return (self.eos_token is not None and self.output_tokens
                and self.output_tokens[-1] == self.eos_token)

    def append_token(self, tok: int) -> None:
        if self.first_token_time is None:
            self.first_token_time = time.perf_counter()
        self.output_tokens.append(tok)

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (submit -> first sampled token)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.submit_time
