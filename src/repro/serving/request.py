"""Request lifecycle for the continuous-batching engine.

A request moves WAITING -> RUNNING -> FINISHED.  Single-shot admission
(prefill + first sampled token) happens inside one engine step, so a
request is RUNNING from the moment its KV cache occupies a slot; only the
paged engine's *chunked* admissions pass through PREFILLING, holding their
slot across the steps that feed the prompt in page-sized chunks.  All
bookkeeping here is host-side Python — device state lives in
``slots.SlotCache`` / ``paging.PagedCache``.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Optional, Sequence

from repro.serving.sampling import SamplingParams


def default_detokenizer(token_ids: Sequence[int]) -> str:
    """Fallback detokenizer: renders each token id as ``<id>``.  The repo
    carries no vocabulary, so this keeps the text-streaming path (and the
    ``detokenize=True`` API surface) fully exercisable; real deployments
    pass their tokenizer's ``decode`` callable instead."""
    return "".join(f"<{int(t)}>" for t in token_ids)


class RequestState(enum.Enum):
    WAITING = "waiting"      # queued, no slot yet
    PREFILLING = "prefilling"  # slot held, prompt chunks still streaming in
    RUNNING = "running"      # occupies a slot, decoding
    FINISHED = "finished"    # evicted; outputs final


@dataclasses.dataclass
class RequestCost:
    """Per-request resource attribution, accumulated by the engine.

    Device-time shares are host-measured around each dispatch and split
    evenly across the requests riding it (batched decode/verify), so the
    per-phase totals sum to engine dispatch time.  Without
    ``fence_spans`` async dispatch means these measure *enqueue* +
    any sync the step forced; with ``ObsConfig(fence_spans=True)`` they
    bracket device work.  ``page_steps`` integrates pages held per decode
    step (paged engines) — the request's KV-memory x time footprint.
    """

    prefill_s: float = 0.0
    decode_s: float = 0.0
    verify_s: float = 0.0
    dispatches: int = 0
    page_steps: int = 0

    def as_dict(self) -> dict:
        return {"prefill_s": self.prefill_s, "decode_s": self.decode_s,
                "verify_s": self.verify_s, "dispatches": self.dispatches,
                "page_steps": self.page_steps}


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens in, sampled tokens out."""

    req_id: int
    prompt: list[int]
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_token: Optional[int] = None
    # Admission rank for priority-aware policies (higher = sooner); the
    # default FIFO admission ignores it.  See ``policies.PriorityAdmission``.
    priority: int = 0
    # Streaming hook: called with each sampled token as it reaches the
    # host.  The engine's lazy pulls are forced eager for streaming
    # requests (tokens surface every step instead of at sync points), so a
    # callback trades a little decode-dispatch overlap for latency.
    on_token: Optional[Callable[[int], None]] = dataclasses.field(
        default=None, repr=False)
    # Text-streaming hook: called with each NEW text fragment whenever a
    # token reaches the host.  Deltas are computed by re-decoding the whole
    # output through ``detokenizer`` (incremental-safe for tokenizers whose
    # decode of a prefix is a prefix of the decode — e.g. BPE byte-level),
    # so multi-token characters surface only once complete.  Forces eager
    # host pulls exactly like ``on_token``.
    on_text: Optional[Callable[[str], None]] = dataclasses.field(
        default=None, repr=False)
    # Pluggable ``decode(token_ids) -> str`` used by ``on_text`` / ``text``;
    # defaults to the vocabulary-free ``default_detokenizer``.
    detokenizer: Optional[Callable[[Sequence[int]], str]] = dataclasses.field(
        default=None, repr=False)

    # SLO deadline (seconds from submit); resolved from
    # ``sampling.deadline_s`` at add_request unless passed explicitly.
    deadline_s: Optional[float] = None
    # stamped by the scheduler when the deadline already expired in queue
    # (the request was doomed before it ever held a slot)
    late_at_admission: bool = False
    # engine-stamped terminal reason that overrides the eos/length
    # inference (e.g. "deadline" for requests shed at ingress)
    finish_reason_override: Optional[str] = None

    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    # resource attribution (see RequestCost)
    cost: RequestCost = dataclasses.field(default_factory=RequestCost,
                                          repr=False)
    output_tokens: list[int] = dataclasses.field(default_factory=list)
    # text already emitted through ``on_text`` (delta bookkeeping)
    emitted_text: str = dataclasses.field(default="", repr=False)
    # chunked admission progress: prompt tokens already prefilled
    prefill_done: int = 0

    # wall-clock timeline (engine-stamped)
    submit_time: float = 0.0
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        if len(self.output_tokens) >= self.max_new_tokens:
            return True
        return (self.eos_token is not None and self.output_tokens
                and self.output_tokens[-1] == self.eos_token)

    def append_token(self, tok: int) -> None:
        if self.first_token_time is None:
            self.first_token_time = time.perf_counter()
        self.output_tokens.append(tok)
        if self.on_token is not None:
            self.on_token(tok)
        if self.on_text is not None:
            full = self.decode_text()
            delta = full[len(self.emitted_text):]
            if delta:
                self.on_text(delta)
            self.emitted_text = full

    def decode_text(self) -> str:
        """The output so far through the request's detokenizer."""
        detok = self.detokenizer or default_detokenizer
        return detok(self.output_tokens)

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (submit -> first sampled token)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.submit_time

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Time spent WAITING (submit -> admitted into a lane)."""
        if self.admit_time is None:
            return None
        return self.admit_time - self.submit_time

    @property
    def deadline_hit(self) -> Optional[bool]:
        """Did the request finish inside its deadline?  ``None`` while in
        flight or when no deadline was set (no-deadline requests always
        count toward goodput, but report no hit/miss)."""
        if self.deadline_s is None or self.latency_s is None:
            return None
        return self.latency_s <= self.deadline_s

    @property
    def finish_reason(self) -> Optional[str]:
        """Why generation stopped: ``"eos"``, ``"length"`` or an engine
        override like ``"deadline"`` (None while still in flight)."""
        if self.state is not RequestState.FINISHED:
            return None
        if self.finish_reason_override is not None:
            return self.finish_reason_override
        if (self.eos_token is not None and self.output_tokens
                and self.output_tokens[-1] == self.eos_token):
            return "eos"
        return "length"
