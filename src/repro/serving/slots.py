"""Fixed-shape slot-based KV cache for continuous batching.

One device-resident cache pytree is allocated once for ``n_slots`` lanes at
a fixed ``cache_len`` (built from ``models/kvcache.py`` shapes, so every
block kind — attn / MLA / recurrent state — and the int8 byte-size variant
work unchanged).  Requests come and go by *scattering into a lane* of that
fixed tree, so the jitted decode step never sees a new shape and never
retraces:

* ``insert(single_cache, slot)`` — write a freshly prefilled batch=1 cache
  into lane ``slot`` (one fused ``dynamic_update_slice`` per leaf).
* ``free(slot)`` — release the lane; its ``pos`` is reset to 0.

The batch axis is leaf-dependent: scanned ``blocks`` / ``cross_kv`` leaves
are stacked ``(n_periods, B, ...)`` (axis 1), everything else is ``(B,
...)`` (axis 0); the axis map is derived from the cache's top-level keys.

Free lanes still ride through ``decode_step`` (fixed shapes), but their
``pos`` no longer drifts on garbage tokens: the engine passes a live-lane
mask and the jitted step pins idle lanes' ``pos`` to 0 (see
``model.decode_step``'s ``active`` argument).  Garbage *writes* from idle
lanes remain lane-local here (``dynamic_update_slice`` clamps, and row 0
is rewritten by the next insert) — only the paged cache, where pages are
shared, needs the additional trash-page redirect.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.kvcache import zeros_like_shapes

# top-level cache keys whose leaves are stacked over scan periods, putting
# the batch/lane dim at axis 1 instead of 0
_PERIOD_STACKED = ("blocks", "cross_kv")


def batch_axes(cache) -> dict:
    """Pytree of ints (same structure as ``cache``): each leaf's lane axis."""
    return {
        key: jax.tree_util.tree_map(
            lambda _leaf, ax=(1 if key in _PERIOD_STACKED else 0): ax, sub
        )
        for key, sub in cache.items()
    }


def scatter_lane(cache, single, slot, axes_flat):
    """Write the batch=1 ``single`` tree into lane ``slot`` of ``cache``
    (one ``dynamic_update_slice`` per leaf). Traceable — the engine inlines
    it into the fused admission step; ``_scatter_lane`` below is the
    standalone jitted form."""
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    single_leaves = treedef.flatten_up_to(single)

    def one(full, part, ax):
        starts = tuple(
            jnp.asarray(slot, jnp.int32) if i == ax else 0
            for i in range(full.ndim)
        )
        return jax.lax.dynamic_update_slice(full, part.astype(full.dtype), starts)

    return treedef.unflatten(
        [one(c, s, ax) for c, s, ax in zip(leaves, single_leaves, axes_flat)])


# module-level jit (axes static) so the trace cache is shared across
# SlotCache/engine instances — re-instantiating an engine must not recompile
_scatter_lane = jax.jit(scatter_lane, donate_argnums=(0,), static_argnums=(3,))


def scatter_lanes(cache, multi, slots, axes_flat, k: int):
    """Write rows ``0..k`` of the batch=``k`` ``multi`` tree into lanes
    ``slots[i]`` of ``cache`` — the stacked-admission counterpart of
    ``scatter_lane`` (``k`` ``dynamic_update_slice``s per leaf; ``k`` is
    static, so each stack width traces once per cache shape).  Traceable:
    the engine fuses it into its batched admission dispatch."""
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    multi_leaves = treedef.flatten_up_to(multi)

    def one(full, part, ax):
        for i in range(k):
            row = jax.lax.dynamic_slice_in_dim(part, i, 1, axis=ax)
            starts = tuple(
                jnp.asarray(slots[i], jnp.int32) if j == ax else 0
                for j in range(full.ndim)
            )
            full = jax.lax.dynamic_update_slice(full, row.astype(full.dtype),
                                                starts)
        return full

    return treedef.unflatten(
        [one(c, s, ax) for c, s, ax in zip(leaves, multi_leaves, axes_flat)])


class SlotCache:
    """Engine-owned cache pool: ``n_slots`` lanes of length ``cache_len``."""

    def __init__(self, cfg: ModelConfig, n_slots: int, cache_len: int,
                 cross_len: int = 0):
        self.n_slots = n_slots
        self.cache_len = cache_len
        shapes = model_lib.cache_shapes(cfg, n_slots, cache_len, cross_len)
        self.cache = zeros_like_shapes(shapes)
        self._axes_flat = tuple(jax.tree_util.tree_leaves(batch_axes(self.cache)))

    def insert(self, single_cache, slot: int) -> None:
        """Scatter a batch=1 prefill cache into lane ``slot``."""
        self.cache = _scatter_lane(self.cache, single_cache, jnp.int32(slot),
                                   self._axes_flat)

    def free(self, slot: int) -> None:
        """Release a lane (resets its write position)."""
        self.cache = {**self.cache, "pos": self.cache["pos"].at[slot].set(0)}

    @property
    def pos(self):
        return self.cache["pos"]
