"""Continuous-batching serving engine.

One ``ServingEngine`` owns a fixed pool of ``n_slots`` KV-cache lanes and
runs an iteration-level loop: every ``step()``

1. **admits** up to ``max_prefills_per_step`` FIFO-queued requests into
   free lanes — each admission is a batch=1 prefill (optionally padded to a
   prefill bucket so jit traces stay bounded) whose cache is scattered into
   the lane, and whose last-position logits yield the request's *first*
   token (the TTFT token); in paged mode, long prompts instead stream in as
   page-sized **chunked prefills** interleaved with decode steps, so one
   big admission can no longer stall in-flight decodes;
2. **decodes** one token for every occupied lane in a single jitted
   ``decode_step`` over the whole pool — fixed shapes, zero retraces —
   sampling per-lane (greedy / temperature / top-k);
3. **evicts** finished lanes (length budget or EOS) immediately, so the
   next step can refill them instead of burning compute on dead lanes.

WHICH requests admit, WHEN a lane evicts, WHEN the paged pool compacts
and HOW cached prefixes are reused are pluggable
``policies.EnginePolicies`` (admission / eviction / defrag / prefix):
the defaults reproduce FIFO + budget-or-EOS and add threshold-triggered
defrag; ``BucketBatchedAdmission`` stacks same-bucket prompts into one
batched prefill dispatch (slot AND paged modes — paged groups scatter
per-lane pages); ``PriorityAdmission`` ranks by ``Request.priority`` with
starvation-free aging.  New scheduling scenarios are new policy classes,
not engine surgery.

With ``EngineConfig.prefix_cache`` (paged, chunkable stacks) admissions
consult the shared-prefix radix tree (``repro/prefix/``): the longest
page-aligned cached prefix is aliased into the lane's block table
(refcounted pages, copy-on-write on the boundary page for full-prompt
hits) and only the uncached suffix runs through the chunk step; completed
prefills publish their full pages back, and the tree LRU-evicts under
pool pressure inside the admission gate.  Scheduling stays
output-invisible: greedy tokens with the cache ON are bitwise the cache-
OFF (and solo ``serve_batch``) streams.

Two cache modes (``EngineConfig.cache_mode``):

* ``"slot"``  — ``slots.SlotCache``: every lane preallocates ``cache_len``
  rows.  Simple, but a pool serving mixed-length traffic wastes most of
  its KV HBM on short requests.
* ``"paged"`` — ``paging.PagedCache``: KV lives in a global page pool
  (int8 byte-size pages supported) indexed by per-lane block tables;
  admission *reserves* a request's worst case but pages materialize only
  as the sequence grows, and eviction returns them the same step.  Same
  budget, strictly more concurrent requests on mixed lengths.  Scheduling
  stays output-invisible: greedy tokens equal the solo ``serve_batch``
  stream in both modes.

This is what keeps a byte-size integer GEMM accelerator fed: the decode
GEMMs always run at the full pool batch, prefill is interleaved instead of
lock-stepped, and a long request never stalls the batch (the failure mode
of the static ``serve_batch`` baseline).

The model side is the ordinary ``launch/steps.py`` builders, so the whole
quantized ``gemm_backend`` pipeline (Pallas SPOGA kernels, int8 KV cache,
parametric quant modes) serves every engine step unchanged.

Supported: decoder-only token-input stacks (any cache kind, including MLA
and recurrent state).  Prefill buckets require attention-family caches —
recurrent state integrates right-padding — so bucketed padding is rejected
for rglru/mlstm/slstm patterns at construction time.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    DEFAULT_PAGE_SIZE,
    KV_CACHE_HEADROOM,
    ModelConfig,
    default_cache_len,
    pages_for,
)
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.obs import DISABLED, Observability
from repro.paging import (
    PagedCache,
    chunkable,
    chunkable_with_state,
    make_chunk_step,
    paged_insert,
    paged_insert_many,
    stack_kinds,
)
from repro.prefix import PrefixCache
from repro.serving.metrics import EngineMetrics
from repro.serving.policies import EnginePolicies
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, request_key, sample_tokens
from repro.serving.scheduler import Scheduler
from repro.serving.slots import SlotCache
from repro.spec.config import SpecConfig
from repro.spec.verify import jitted_verify

RECURRENT_KINDS = frozenset({"rglru", "mlstm", "slstm"})
# effective kinds whose KV lands in page pools (models/kvcache.py); a
# window-bearing local_attn keeps its per-lane ring in both modes
PAGED_KINDS = frozenset({"attn", "mla", "moe", "dense_ffn_layer"})

_ZERO_KEY = np.zeros((2,), np.uint32)

_sample_jit = jax.jit(sample_tokens)


def _roundup(n: int, m: int) -> int:
    return pages_for(n, m) * m


def _with_mesh(mesh, fn):
    """Dispatch ``fn`` inside ``with mesh:`` so the trace-time sharding
    constraints (``runtime/sharding.sp_enter`` and friends) activate and
    pjit partitions the step across the mesh.  Identity when ``mesh`` is
    None — the unsharded engine pays nothing.  The mesh context is part of
    pjit's cache key, so meshed and unmeshed engines sharing one lru-cached
    jit object still get distinct compiled programs."""
    if mesh is None:
        return fn

    @functools.wraps(fn)
    def run(*args, **kwargs):
        with mesh:
            return fn(*args, **kwargs)

    return run


# jit wrappers are cached per (cfg, cache_len[, mesh]) so spinning up a new
# engine (benchmark sweeps, tests) reuses compiled traces instead of
# re-jitting — ``make_*_step`` returns a fresh closure per call, which
# defeats jax's own cache if wrapped naively per instance.  ``mesh`` (a
# hashable jax.sharding.Mesh, or None) keys the cache too so sharded and
# unsharded engines never swap wrappers.
@functools.lru_cache(maxsize=None)
def _jitted_admit(cfg: ModelConfig, cache_len: int, mesh=None):
    """Fused admission: prefill + first-token sample + lane scatter in ONE
    dispatch (the batch=1 cache never materializes as a standalone output).
    Single prefills are the engine's per-request overhead; at small scale
    dispatch latency rivals compute, so fusion matters."""
    from repro.serving.slots import scatter_lane

    prefill = make_prefill_step(cfg, cache_len, with_lengths=True)

    def admit(pool, params, tokens, lengths, slot, temp, topk, greedy, key,
              axes_flat):
        logits, single = prefill(params, {"tokens": tokens}, lengths)
        tok = sample_tokens(logits, temp, topk, greedy, key)
        return tok, scatter_lane(pool, single, slot, axes_flat)

    return _with_mesh(mesh, jax.jit(admit, donate_argnums=(0,),
                                    static_argnums=(9,)))


@functools.lru_cache(maxsize=None)
def _jitted_admit_group(cfg: ModelConfig, cache_len: int, k: int, mesh=None):
    """Stacked admission (slot mode): ``k`` same-bucket prompts prefill as
    ONE batch=``k`` dispatch — prefill + per-lane first-token sample + lane
    scatter fused, amortizing the per-admission dispatch cost that
    ``BucketBatchedAdmission`` targets under bursty arrivals.  Prefill is
    batch-parallel (rows attend only within themselves; padding is masked
    by ``lengths``), so the stacked tokens are bitwise the k solo ones."""
    from repro.serving.slots import scatter_lanes

    prefill = make_prefill_step(cfg, cache_len, with_lengths=True)

    def admit(pool, params, tokens, lengths, slots, temps, topk, greedy,
              keys, axes_flat):
        logits, multi = prefill(params, {"tokens": tokens}, lengths)
        toks = sample_tokens(logits, temps, topk, greedy, keys)
        return toks, scatter_lanes(pool, multi, slots, axes_flat, k)

    return _with_mesh(mesh, jax.jit(admit, donate_argnums=(0,),
                                    static_argnums=(9,)))


@functools.lru_cache(maxsize=None)
def _jitted_admit_paged(cfg: ModelConfig, single_len: int, mesh=None):
    """Paged fused admission: the batch=1 prefill allocates only
    ``single_len`` rows (the bucket rounded up to whole pages, not the full
    ``cache_len``) and its cache is scattered straight into the lane's
    pages + per-lane leaves, with the block-table row written in the same
    dispatch."""
    prefill = make_prefill_step(cfg, single_len, with_lengths=True)

    def admit(pool, params, tokens, lengths, lane, page_ids, table_row,
              temp, topk, greedy, key):
        logits, single = prefill(params, {"tokens": tokens}, lengths)
        tok = sample_tokens(logits, temp, topk, greedy, key)
        return tok, paged_insert(pool, single, lane, page_ids, table_row,
                                 lengths[0])

    return _with_mesh(mesh, jax.jit(admit, donate_argnums=(0,)))


@functools.lru_cache(maxsize=None)
def _jitted_admit_paged_group(cfg: ModelConfig, single_len: int, k: int,
                              mesh=None):
    """Stacked admission (paged mode): ``k`` same-bucket prompts prefill as
    ONE batch=``k`` dispatch whose cache rows scatter into each lane's own
    pages (``paged_insert_many``), with every block-table row written in
    the same dispatch.  Prefill is batch-parallel and the per-lane scatter
    is the same graph as ``k`` solo inserts, so the stacked tokens are
    bitwise the k solo ones — the PR 4 slot-mode argument, carried to
    pages."""
    prefill = make_prefill_step(cfg, single_len, with_lengths=True)

    def admit(pool, params, tokens, lengths, lanes, page_ids, table_rows,
              temps, topk, greedy, keys):
        logits, multi = prefill(params, {"tokens": tokens}, lengths)
        toks = sample_tokens(logits, temps, topk, greedy, keys)
        return toks, paged_insert_many(pool, multi, lanes, page_ids,
                                       table_rows, lengths, k)

    return _with_mesh(mesh, jax.jit(admit, donate_argnums=(0,)))


@functools.lru_cache(maxsize=None)
def _jitted_decode_sample(cfg: ModelConfig, mesh=None):
    """Fused decode+sample: one jit dispatch per engine step.

    ``any_stochastic`` is static so the all-greedy trace (the default, and
    every exact-match path) lowers to a pure argmax — without it every step
    would pay sample_tokens' full-vocab sort + categorical just to discard
    the result in the greedy ``where``."""
    decode = make_serve_step(cfg, with_active=True)

    def step(params, tokens, cache, active, temps, topk, greedy, keys,
             any_stochastic: bool):
        logits, cache = decode(params, tokens, cache, active)
        if any_stochastic:
            toks = sample_tokens(logits, temps, topk, greedy, keys)
        else:
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return toks, cache

    return _with_mesh(mesh, jax.jit(step, donate_argnums=(2,),
                                    static_argnums=(8,)))


@functools.lru_cache(maxsize=None)
def _jitted_chunk_step(cfg: ModelConfig, chunk_len: int, mesh=None):
    """One chunked-prefill step (see ``paging.prefill.make_chunk_step``),
    donating the pool so chunk writes are in-place."""
    return _with_mesh(mesh, jax.jit(make_chunk_step(cfg, chunk_len),
                                    donate_argnums=(1,)))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape/policy knobs (model behaviour stays in ``ModelConfig``)."""

    n_slots: int = 4
    cache_len: int = 256
    max_prefills_per_step: int = 1
    # Prompt lengths are padded up to the smallest bucket >= len(prompt) so
    # the jitted prefill traces at most len(buckets) shapes. None/() = exact
    # lengths (one trace per distinct prompt length).
    prefill_buckets: Optional[tuple[int, ...]] = None
    eos_token: Optional[int] = None
    # "slot" (per-lane cache_len preallocation) | "paged" (global page pool
    # + block tables; see repro/paging/)
    cache_mode: str = "slot"
    page_size: int = DEFAULT_PAGE_SIZE
    # pool size in pages; None = the slot-equivalent KV budget
    # (configs.default_page_count)
    n_pages: Optional[int] = None
    # paged mode: prompts longer than this admit in page-aligned chunks of
    # this many tokens, interleaved with decode steps. None = one-shot
    # admission. Must be a multiple of page_size.
    prefill_chunk: Optional[int] = None
    # paged mode: shared-prefix KV cache (repro/prefix/) — admissions look
    # up the longest page-aligned cached prefix, alias its pages and
    # prefill only the uncached suffix.  Requires a chunkable stack
    # (attn/MLA/dense): the suffix resumes through the chunk step.
    prefix_cache: bool = False
    # speculative decoding (repro/spec/): draft k tokens per lane, verify
    # them in ONE batched dispatch, greedy-accept in-jit.  Requires a
    # chunkable stack (the verify window reuses the chunked-prefill
    # row-independence contract).  None / enabled=False = plain decode.
    spec: Optional[SpecConfig] = None

    @staticmethod
    def for_workload(prompt_len: int, gen_tokens: int, n_slots: int = 4,
                     **kw) -> "EngineConfig":
        """Cache sized by the shared serving policy (prompt + gen + headroom)."""
        return EngineConfig(
            n_slots=n_slots,
            cache_len=default_cache_len(prompt_len, gen_tokens),
            **kw,
        )


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 policies: Optional[EnginePolicies] = None,
                 obs: Optional[Observability] = None,
                 mesh=None):
        if cfg.is_encoder_decoder or cfg.frontend is not None:
            raise ValueError(
                "ServingEngine handles decoder-only token-input models; "
                "enc-dec / frontend archs serve via launch.serve.serve_batch")
        buckets = tuple(sorted(engine_cfg.prefill_buckets or ()))
        if buckets and RECURRENT_KINDS & set(cfg.block_pattern):
            raise ValueError(
                f"prefill buckets pad prompts, but {sorted(RECURRENT_KINDS & set(cfg.block_pattern))} "
                "state integrates padded tokens; use exact-length prefill "
                "(prefill_buckets=None) for recurrent stacks")
        if buckets and buckets[-1] > engine_cfg.cache_len:
            raise ValueError("largest prefill bucket exceeds cache_len")
        if engine_cfg.cache_mode not in ("slot", "paged"):
            raise ValueError(f"cache_mode must be 'slot' or 'paged', got "
                             f"{engine_cfg.cache_mode!r}")
        self.cfg = cfg
        self.params = params
        self.engine_cfg = engine_cfg
        self.buckets = buckets
        self.paged = engine_cfg.cache_mode == "paged"
        # tensor-parallel serving (repro/shard/): every jitted dispatch
        # below runs under ``with mesh:`` so trace-time sharding
        # constraints activate; params arrive pre-committed (api/llm.py)
        # and the paged pool commits its own layout in PagedCache
        self.mesh = mesh

        self.policies = policies if policies is not None else EnginePolicies()
        # observability bundle (repro/obs/): the DISABLED singleton's null
        # sinks make every tracer/event/profiler call below a no-op, so the
        # hot path is instrumented unconditionally at zero disabled cost
        self.obs = obs if obs is not None else DISABLED

        n = engine_cfg.n_slots
        self.scheduler = Scheduler(n, engine_cfg.max_prefills_per_step,
                                   admission=self.policies.admission)
        self.metrics = EngineMetrics()

        # flight recorder (repro/obs/recorder.py): None when disarmed;
        # every hook below guards on that, and all of them sit on
        # per-request host paths — no device syncs, no jaxpr changes.
        # Armed, the decision clock tapes its readings so a replay can
        # script time-dependent decisions (deadline sheds/preemptions).
        self._recorder = getattr(self.obs, "recorder", None)
        self.set_clock(self._recorder.wrap_clock()
                       if self._recorder is not None else time.perf_counter)
        if self._recorder is not None:
            self._recorder.record_engine(engine_cfg)

        # whole-stack effective kinds (lead + periods + tail) from the one
        # layout-owning helper; a windowless local_attn block caches like
        # full attention (models/kvcache.py), so it pages too
        kinds = stack_kinds(cfg)
        self._has_ring = ("local_attn" in kinds and cfg.sliding_window is not None)
        self._has_paged_kinds = (
            bool(kinds & PAGED_KINDS)
            or ("local_attn" in kinds and cfg.sliding_window is None))

        if self.paged:
            ps = engine_cfg.page_size
            if self._has_ring and engine_cfg.cache_len % ps:
                raise ValueError(
                    "paged serving of local-attention stacks needs "
                    "cache_len to be a multiple of page_size (the per-lane "
                    "ring insert must match the pool's ring length)")
            if engine_cfg.prefill_chunk is not None:
                if engine_cfg.prefill_chunk % ps:
                    raise ValueError("prefill_chunk must be a multiple of "
                                     "page_size (chunks are page-aligned)")
                if not chunkable_with_state(cfg):
                    raise ValueError(
                        f"{cfg.name}: chunked prefill needs row-independent "
                        "kinds (attn/MLA/dense) or state-carrying recurrent "
                        "cells (rglru/mlstm/slstm); use prefill_chunk=None")
            self.store = PagedCache(cfg, n, engine_cfg.cache_len, ps,
                                    engine_cfg.n_pages, mesh=mesh)
            self.metrics.set_gauge("pages_total", self.store.n_pages)
            self.metrics.set_gauge("page_size", ps)
            # chunk length for BOTH long-prompt chunking and shared-prefix
            # suffix prefill; the prefix cache falls back to one page per
            # chunk (trivially page-aligned) when prefill_chunk is unset
            self._chunk_len = engine_cfg.prefill_chunk
            if engine_cfg.prefix_cache:
                if not self._has_paged_kinds:
                    raise ValueError(
                        f"{cfg.name}: prefix_cache needs attention-family KV "
                        "pages to share; this stack keeps all state per-lane")
                if not chunkable(cfg):
                    raise ValueError(
                        f"{cfg.name}: prefix_cache resumes the uncached "
                        "suffix through the chunked-prefill step, which "
                        "needs a strictly row-independent stack "
                        "(attn/MLA/dense); "
                        f"got {sorted(stack_kinds(cfg))}")
                self._chunk_len = engine_cfg.prefill_chunk or ps
                # full-prompt hits CoW-fork the boundary page and resume at
                # the final prompt token — int8 pools included: every
                # admission on an int8 + prefix pool is forced through the
                # chunk step (``_should_chunk_len``), so cold and warm runs
                # attend the same dequantized pages and stay graph-identical
                self.prefix: Optional[PrefixCache] = PrefixCache(
                    self.store.manager, ps, allow_fork=True)
            else:
                self.prefix = None
            self._chunk_fn = (
                _jitted_chunk_step(cfg, self._chunk_len, mesh)
                if self._chunk_len is not None else None)
        else:
            if engine_cfg.prefill_chunk is not None:
                raise ValueError("chunked prefill requires cache_mode='paged'")
            if engine_cfg.prefix_cache:
                raise ValueError("prefix_cache requires cache_mode='paged' "
                                 "(shared pages live in the page pool)")
            self.store = SlotCache(cfg, n, engine_cfg.cache_len)
            self.prefix = None
            self._chunk_len = None

        self._admit_fn = (None if self.paged
                          else _jitted_admit(cfg, engine_cfg.cache_len, mesh))
        self._decode_sample = _jitted_decode_sample(cfg, mesh)

        # speculative decoding (repro/spec/): verify jit + drafter.  The
        # verify window needs every row-independent property the chunked
        # prefill relies on, so the same ``chunkable`` gate applies.
        spec = engine_cfg.spec
        self._spec = spec if (spec is not None and spec.enabled) else None
        if self._spec is not None:
            if not chunkable(cfg):
                raise ValueError(
                    f"{cfg.name}: speculative decoding needs a stack of "
                    "strictly row-independent kinds (attn/MLA/dense) — the "
                    "k-token verify window reuses the chunked-prefill "
                    f"contract; got {sorted(stack_kinds(cfg))}")
            from repro.spec import make_drafter

            self._verify_fn = _with_mesh(mesh,
                                         jitted_verify(cfg, self._spec.width))
            self._drafter = make_drafter(
                self._spec, cfg, n, engine_cfg.cache_len,
                tree=self.prefix.tree if self.prefix is not None else None)
        else:
            self._verify_fn = None
            self._drafter = None

        # prefix-aware admission orders the queue by adopted-page signature;
        # the policy is engine-agnostic, so the engine hands it the lookup
        if hasattr(self.policies.admission, "bind"):
            self.policies.admission.bind(self._admission_prefix_sig)

        # per-lane state. ``_tokens`` may be a DEVICE array: between sync
        # points sampled tokens feed the next decode device-to-device (see
        # ``step``); the rest are host arrays passed to the fused step.
        self._tokens = np.zeros((n,), np.int32)
        self._temps = np.ones((n,), np.float32)
        self._topk = np.zeros((n,), np.int32)
        self._greedy = np.ones((n,), bool)
        self._keys = np.zeros((n, 2), np.uint32)
        # decode steps whose tokens haven't been pulled to host yet:
        # (device (n,) tokens, {slot: request} snapshot at that step)
        self._pending: list = []
        # per-request memoized prefix plans: req_id -> (tree epoch, plan)
        self._plan_cache: dict[int, tuple] = {}
        self._next_id = 0
        self._step_idx = 0

    # ------------------------------------------------------------------
    # Decision clock
    # ------------------------------------------------------------------
    def set_clock(self, clock) -> None:
        """Install the decision clock: every wall-time reading that can
        change a scheduling decision (submit stamps, admission lateness,
        deadline shedding/preemption) goes through it.  Recording wraps
        ``time.perf_counter`` to tape each reading; replay installs a
        ``ReplayClock`` that scripts the tape back.  Metric timestamps
        (TTFT, latency, dispatch timers) intentionally stay on real
        time — they measure the run, they don't steer it."""
        self._clock = clock
        self.scheduler.clock = clock
        if hasattr(self.policies.eviction, "bind"):
            self.policies.eviction.bind(clock, lambda: self.scheduler.waiting)

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def add_request(self, prompt: Sequence[int], max_new_tokens: int,
                    sampling: Optional[SamplingParams] = None,
                    eos_token: Optional[int] = None,
                    on_token=None, on_text=None, detokenizer=None,
                    priority: int = 0,
                    deadline_s: Optional[float] = None) -> Request:
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # speculative decoding writes up to k rows past the accepted
        # position (the verify window's overshoot), so the budget check and
        # the paged reservations below all carry the extra rows
        need = len(prompt) + max_new_tokens + self._spec_overshoot
        if need > self.engine_cfg.cache_len + 1:
            raise ValueError(
                f"request needs {need} cache positions but cache_len="
                f"{self.engine_cfg.cache_len}; size the engine with "
                f"default_cache_len(prompt_len, gen) [headroom={KV_CACHE_HEADROOM}]")
        if self.paged and self._has_paged_kinds:
            # reject requests the pool can NEVER reserve — otherwise the
            # head-of-line admission gate would veto them forever and the
            # engine would spin (run) or hang (stream) without an error
            pages = pages_for(self._worst_case_rows(len(prompt), max_new_tokens),
                              self.engine_cfg.page_size)
            usable = self.store.n_pages - 1  # page 0 is the trash page
            if pages > usable:
                raise ValueError(
                    f"request reserves {pages} pages but the pool only has "
                    f"{usable} usable pages; raise n_pages (or lower "
                    f"page_size / the request's budget)")
        sampling = sampling or SamplingParams()
        if deadline_s is None:
            deadline_s = sampling.deadline_s
        req = Request(
            req_id=self._next_id,
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            sampling=sampling,
            eos_token=self.engine_cfg.eos_token if eos_token is None else eos_token,
            on_token=on_token,
            on_text=on_text,
            detokenizer=detokenizer,
            priority=priority,
            deadline_s=deadline_s,
            submit_time=self._clock(),
        )
        self._next_id += 1
        self.scheduler.submit(req)
        self.obs.events.emit("queued", req.req_id, prompt_len=req.prompt_len,
                             max_new_tokens=max_new_tokens,
                             priority=priority,
                             **({"deadline_s": deadline_s}
                                if deadline_s is not None else {}))
        if self._recorder is not None:
            self._recorder.record_arrival(req, self._step_idx)
        return req

    def _bucket_len(self, prompt_len: int) -> int:
        for b in self.buckets:
            if b >= prompt_len:
                return b
        return prompt_len

    def _lane_key(self, req: Request) -> np.ndarray:
        if req.sampling.greedy:
            return _ZERO_KEY
        k = request_key(req.sampling.seed, req.req_id, len(req.output_tokens))
        return np.asarray(k, np.uint32)

    def _arm_lane(self, req: Request, slot: int, tok: int) -> None:
        """First token sampled: point the lane's decode inputs at it."""
        s = req.sampling
        self._plan_cache.pop(req.req_id, None)  # admitted: plan consumed
        req.append_token(tok)  # stamps TTFT
        self.metrics.inc("prefills")
        self.obs.events.emit("first_token", req.req_id, slot=slot,
                             ttft_s=req.ttft_s)
        if self._drafter is not None:
            self._drafter.admit(slot, req.prompt)
        self._tokens = jnp.asarray(self._tokens).at[slot].set(tok)
        self._temps[slot] = s.temperature
        self._topk[slot] = s.top_k
        self._greedy[slot] = s.greedy
        self._keys[slot] = self._lane_key(req)

    def _admit(self, req: Request, slot: int) -> None:
        padded_len = self._bucket_len(req.prompt_len)
        tokens = np.zeros((1, padded_len), np.int32)
        tokens[0, :req.prompt_len] = req.prompt
        s = req.sampling
        common = (
            np.asarray([s.temperature], np.float32),
            np.asarray([s.top_k], np.int32),
            np.asarray([s.greedy]),
            self._lane_key(req)[None],
        )
        reserved = None
        if self.paged:
            # reserve/alloc BEFORE the admitted event so it journals the
            # page assignment (the operands a replay diff reports)
            reserved = self._paged_reserve(req, slot, padded_len)
            self.obs.events.emit("admitted", req.req_id, slot=slot,
                                 mode="cold",
                                 pages=[int(p) for p in reserved[1]],
                                 queue_wait_s=req.queue_wait_s)
        else:
            self.obs.events.emit("admitted", req.req_id, slot=slot,
                                 mode="cold", queue_wait_s=req.queue_wait_s)
        t0 = time.perf_counter()
        with self.obs.tracer.span("prefill", lane=slot, req=req.req_id,
                                  slot=slot, tokens=padded_len) as sp:
            if self.paged:
                tok_dev, self.store.cache = self._paged_admit(
                    req, slot, tokens, padded_len, common, reserved=reserved)
                self._record_miss(req)
                self._maybe_publish(req, slot)
            else:
                tok_dev, self.store.cache = self._admit_fn(
                    self.store.cache, self.params, tokens,
                    np.asarray([req.prompt_len], np.int32), jnp.int32(slot),
                    *common, self.store._axes_flat,
                )
            sp.fence(tok_dev)
        req.cost.prefill_s += time.perf_counter() - t0
        req.cost.dispatches += 1
        self.metrics.inc("prefill_dispatches")
        self._arm_lane(req, slot, int(np.asarray(tok_dev)[0]))

    def _admit_group(self, group: list[tuple[Request, int]]) -> None:
        """Stacked admission: same-bucket requests prefill as one batch=k
        dispatch (slot mode only; the admission policy can only form >1
        groups when the engine offers them — see ``step``)."""
        k = len(group)
        padded_len = self._bucket_len(group[0][0].prompt_len)
        tokens = np.zeros((k, padded_len), np.int32)
        lengths = np.zeros((k,), np.int32)
        temps = np.ones((k,), np.float32)
        topk = np.zeros((k,), np.int32)
        greedy = np.ones((k,), bool)
        keys = np.zeros((k, 2), np.uint32)
        for i, (req, _) in enumerate(group):
            tokens[i, :req.prompt_len] = req.prompt
            lengths[i] = req.prompt_len
            s = req.sampling
            temps[i], topk[i], greedy[i] = s.temperature, s.top_k, s.greedy
            keys[i] = self._lane_key(req)
        slots = np.asarray([slot for _, slot in group], np.int32)
        for req, slot in group:
            self.obs.events.emit("admitted", req.req_id, slot=slot,
                                 mode="stacked", group=k,
                                 queue_wait_s=req.queue_wait_s)
        admit_fn = _jitted_admit_group(self.cfg, self.engine_cfg.cache_len, k,
                                       self.mesh)
        t0 = time.perf_counter()
        with self.obs.tracer.span("prefill_stacked", lanes=slots.tolist(),
                                  k=k, tokens=padded_len) as sp:
            toks_dev, self.store.cache = admit_fn(
                self.store.cache, self.params, tokens, lengths, slots,
                temps, topk, greedy, keys, self.store._axes_flat)
            sp.fence(toks_dev)
        share = (time.perf_counter() - t0) / k
        self.metrics.inc("prefill_dispatches")
        self.metrics.inc("stacked_prefills", k)
        toks = np.asarray(toks_dev)
        for i, (req, slot) in enumerate(group):
            req.cost.prefill_s += share
            req.cost.dispatches += 1
            self._arm_lane(req, slot, int(toks[i]))

    def _admit_group_paged(self, group: list[tuple[Request, int]]) -> None:
        """Stacked paged admission: same-bucket requests prefill as one
        batch=k dispatch whose rows scatter into per-lane pages.  Every
        member already passed the tallied reservation gate against one
        pool snapshot, so the sequential reservations below cannot
        overcommit.  Chunked / prefix-seeded admissions never reach here
        (sentinel buckets keep them single-file)."""
        mgr = self.store.manager
        k = len(group)
        padded_len = self._bucket_len(group[0][0].prompt_len)
        single_len = self._single_len(padded_len)
        npg = (single_len // self.engine_cfg.page_size
               if self._has_paged_kinds else 0)
        tokens = np.zeros((k, padded_len), np.int32)
        lengths = np.zeros((k,), np.int32)
        temps = np.ones((k,), np.float32)
        topk = np.zeros((k,), np.int32)
        greedy = np.ones((k,), bool)
        keys = np.zeros((k, 2), np.uint32)
        page_ids = np.zeros((k, npg), np.int32)
        table_rows = np.zeros((k, self.store.max_pages), np.int32)
        for i, (req, slot) in enumerate(group):
            mgr.admit(slot, self._reserve_tokens(req)
                      if self._has_paged_kinds else 0)
            if npg:
                page_ids[i] = mgr.alloc(slot, npg)
            mgr.set_length(slot, req.prompt_len)
            tokens[i, :req.prompt_len] = req.prompt
            lengths[i] = req.prompt_len
            s = req.sampling
            temps[i], topk[i], greedy[i] = s.temperature, s.top_k, s.greedy
            keys[i] = self._lane_key(req)
            table_rows[i] = mgr.block_tables[slot]
        lanes = np.asarray([slot for _, slot in group], np.int32)
        for i, (req, slot) in enumerate(group):
            self.obs.events.emit("admitted", req.req_id, slot=slot,
                                 mode="stacked", group=k,
                                 pages=[int(p) for p in page_ids[i]],
                                 queue_wait_s=req.queue_wait_s)
        admit_fn = _jitted_admit_paged_group(self.cfg, single_len, k,
                                             self.mesh)
        t0 = time.perf_counter()
        with self.obs.tracer.span("prefill_stacked", lanes=lanes.tolist(),
                                  k=k, tokens=padded_len) as sp:
            toks_dev, self.store.cache = admit_fn(
                self.store.cache, self.params, tokens, lengths, lanes,
                page_ids, table_rows, temps, topk, greedy, keys)
            sp.fence(toks_dev)
        share = (time.perf_counter() - t0) / k
        self.metrics.inc("prefill_dispatches")
        self.metrics.inc("stacked_prefills", k)
        toks = np.asarray(toks_dev)
        for i, (req, slot) in enumerate(group):
            req.cost.prefill_s += share
            req.cost.dispatches += 1
            self._record_miss(req)
            self._maybe_publish(req, slot)
            self._arm_lane(req, slot, int(toks[i]))

    # -- paged admission ------------------------------------------------
    def _single_len(self, padded_len: int) -> int:
        """Cache rows the batch=1 admission prefill allocates: the bucket
        rounded to whole pages — except local-attn-ring stacks, whose ring
        length must match the pool's (cache_len is page-aligned there)."""
        if self._has_ring:
            return self.engine_cfg.cache_len
        return _roundup(padded_len, self.engine_cfg.page_size)

    def _should_chunk_len(self, prompt_len: int) -> bool:
        c = self.engine_cfg.prefill_chunk
        force = self.prefix is not None and self.cfg.kv_cache_dtype == "int8"
        if force:
            # int8 pools attend *dequantized* pages on the chunk path but
            # raw bf16 K/V on the one-shot prefill path; forcing EVERY
            # admission (cold or warm, any length) through the chunk step
            # makes cold and warm runs graph-identical, which is what lets
            # full-prompt prefix hits stay dequant-consistent on int8
            # pools (see prefix/cache.py)
            c = self._chunk_len
        if not self.paged or c is None or (prompt_len <= c and not force):
            return False
        # the padded final chunk must stay inside the lane's block table
        return _roundup(prompt_len, c) <= self.store.max_pages * self.engine_cfg.page_size

    def _should_chunk(self, req: Request) -> bool:
        return self._should_chunk_len(req.prompt_len)

    @property
    def _spec_overshoot(self) -> int:
        """Extra cache rows the verify window may write past the accepted
        position (rejected drafts' K/V, overwritten next step)."""
        return self._spec.k if self._spec is not None else 0

    def _admit_rows(self, prompt_len: int) -> int:
        """Cache rows the admission itself touches (chunk padding or the
        page-rounded prefill bucket)."""
        if self._should_chunk_len(prompt_len):
            return _roundup(prompt_len, self._chunk_len)
        return self._single_len(self._bucket_len(prompt_len))

    def _worst_case_rows(self, prompt_len: int, max_new_tokens: int) -> int:
        """Rows a request reserves: its admission footprint or prompt +
        generation budget (+ the speculative overshoot), whichever is
        larger (capped at the block-table capacity, which ``add_request``'s
        cache_len check already bounds)."""
        worst = max(self._admit_rows(prompt_len),
                    prompt_len + max_new_tokens + self._spec_overshoot)
        return min(worst, self.store.max_pages * self.engine_cfg.page_size)

    def _reserve_tokens(self, req: Request) -> int:
        return self._worst_case_rows(req.prompt_len, req.max_new_tokens)

    # -- shared-prefix planning -----------------------------------------
    def _prefix_rows(self, req: Request, plan) -> int:
        """Rows a prefix-seeded lane reserves: the suffix-chunk footprint
        (resume + whole chunks, incl. the padded tail) or prompt +
        generation budget, whichever is larger."""
        c = self._chunk_len
        suffix = plan.resume + _roundup(req.prompt_len - plan.resume, c)
        return max(suffix,
                   req.prompt_len + req.max_new_tokens + self._spec_overshoot)

    def _prefix_plan(self, req: Request):
        """The admission's prefix decision (None = admit cold).  Plans
        whose reservation could never fit a lane's block table fall back
        to the cold path, which ``add_request`` already validated.

        Memoized per (request, tree epoch): bucket_of, the capacity gate
        and the dispatch itself all consult the SAME plan object for one
        scheduling round, and the tree is only re-walked after a
        structural change (publish / evict / remap)."""
        if self.prefix is None:
            return None
        hit = self._plan_cache.get(req.req_id)
        if hit is not None and hit[0] == self.prefix.epoch:
            return hit[1]
        plan = self.policies.prefix.plan(self.prefix, req)
        if plan is not None:
            pages = pages_for(self._prefix_rows(req, plan),
                              self.engine_cfg.page_size)
            if pages > self.store.max_pages or pages > self.store.n_pages - 1:
                plan = None
        self._plan_cache[req.req_id] = (self.prefix.epoch, plan)
        return plan

    def _prefix_draw(self, req: Request, plan) -> int:
        """Pages a prefix-seeded admission draws from the free pool."""
        pages = pages_for(self._prefix_rows(req, plan),
                          self.engine_cfg.page_size)
        return pages - len(plan.pages) + (1 if plan.fork_index is not None else 0)

    def _admit_gate(self):
        """Capacity gate for one admission *dispatch*: stateful so a
        stacked group's reservations are tallied against a single pool
        snapshot (two jointly-unfittable requests can never both pass),
        and prefix-aware — a cached prefix discounts the draw, and under
        pressure the prefix tree LRU-evicts pages no lane is using (never
        pages a candidate in this very dispatch is about to adopt)."""
        if not (self.paged and self._has_paged_kinds):
            return lambda req: True
        tally = [0]
        protected: list = []

        def gate(req: Request) -> bool:
            mgr = self.store.manager
            plan = self._prefix_plan(req)
            if plan is None:
                need = mgr.pages_for(self._reserve_tokens(req))
            else:
                need = self._prefix_draw(req, plan)
                protected.extend(plan.nodes)
            deficit = need - (mgr.available - tally[0])
            # evict only when it can actually close the gap — a request the
            # pool cannot fit even with an empty tree must not drain the
            # cache for nothing while it waits head-of-line
            if (deficit > 0 and self.prefix is not None
                    and deficit <= self.prefix.evictable_pages):
                freed = self.prefix.evict_for(deficit, protect=protected)
                if freed:
                    self.metrics.inc("prefix_evicted_pages", freed)
                    self.metrics.set_gauge("prefix_tree_pages",
                                           self.prefix.cached_pages)
                    self.obs.events.emit("prefix_evict", pages=freed,
                                         deficit=int(deficit))
            if need <= mgr.available - tally[0]:
                tally[0] += need
                return True
            self.obs.events.emit(
                "rejected", req.req_id, reason="page_capacity",
                need_pages=int(need),
                available=int(mgr.available - tally[0]))
            return False

        return gate

    def _admit_bucket(self, req: Request) -> int:
        """Bucket key for stacked admission grouping.  Chunked and
        prefix-seeded admissions are single-file (per-lane chunk streams /
        adopted tables don't stack), so they get a unique sentinel bucket
        no other request can match."""
        if self.paged and (self._should_chunk(req)
                           or self._prefix_plan(req) is not None):
            return -(req.req_id + 1)
        return self._bucket_len(req.prompt_len)

    def _paged_reserve(self, req: Request, slot: int, padded_len: int):
        """Pool-side bookkeeping for a cold paged admission: reserve the
        worst case, allocate the prefill's pages, stamp the prompt
        length.  Returns ``(single_len, page_ids)`` — the page assignment
        the admitted event journals."""
        mgr = self.store.manager
        single_len = self._single_len(padded_len)
        n_pages = single_len // self.engine_cfg.page_size if self._has_paged_kinds else 0
        mgr.admit(slot, self._reserve_tokens(req) if self._has_paged_kinds else 0)
        page_ids = mgr.alloc(slot, n_pages) if n_pages else []
        mgr.set_length(slot, req.prompt_len)
        return single_len, page_ids

    def _paged_admit(self, req: Request, slot: int, tokens, padded_len,
                     common, reserved=None):
        mgr = self.store.manager
        single_len, page_ids = (reserved if reserved is not None
                                else self._paged_reserve(req, slot, padded_len))
        admit_fn = _jitted_admit_paged(self.cfg, single_len, self.mesh)
        return admit_fn(
            self.store.cache, self.params, tokens,
            np.asarray([req.prompt_len], np.int32), jnp.int32(slot),
            np.asarray(page_ids, np.int32),
            np.asarray(mgr.block_tables[slot]),
            *common,
        )

    def _admission_prefix_sig(self, req: Request):
        """Adopted-page signature for prefix-aware admission ordering: two
        waiting requests with the same signature would alias the same
        cached pages, so admitting them back-to-back keeps those pages
        hot.  None = cold admission (no cached prefix)."""
        if self.prefix is None:
            return None
        plan = self._prefix_plan(req)
        return tuple(plan.pages) if plan is not None else None

    # -- shared-prefix bookkeeping ---------------------------------------
    def _record_miss(self, req: Request) -> None:
        if self.prefix is not None:
            self.metrics.inc("prefix_misses")

    def _maybe_publish(self, req: Request, slot: int) -> None:
        """After a prefill completes, enter the prompt's full pages into
        the prefix tree so later prompts can alias them.  Only
        prefill-written rows publish — never decode-written ones, whose
        dispatch graph differs (the bitwise cold-vs-warm contract)."""
        if self.prefix is None or not self.policies.prefix.should_publish(req):
            return
        self.prefix.publish(req.prompt, self.store.manager.lane_pages[slot])
        self.metrics.set_gauge("prefix_tree_pages", self.prefix.cached_pages)

    def _cow(self, slot: int, move) -> None:
        """Apply a copy-on-write fork on device (``move`` = (src, dst))."""
        self.store.copy_pages([move[0]], [move[1]])
        self.metrics.inc("prefix_cow_forks")
        req = self.scheduler.request_in(slot)
        self.obs.events.emit("cow_fork",
                             req.req_id if req is not None else None,
                             slot=slot, src=int(move[0]), dst=int(move[1]))

    # -- chunked prefill -------------------------------------------------
    def _begin_chunked(self, req: Request, slot: int,
                       finished: list[Request]) -> None:
        mgr = self.store.manager
        # pure-recurrent chunked stacks keep all state per-lane: reserve no
        # pages (mirrors _paged_admit), or the pool gate would veto chunked
        # admissions that touch no pool rows at all
        mgr.admit(slot, self._reserve_tokens(req)
                  if self._has_paged_kinds else 0)
        self.obs.events.emit("admitted", req.req_id, slot=slot,
                             mode="chunked",
                             reserved=int(self._reserve_tokens(req))
                             if self._has_paged_kinds else 0,
                             queue_wait_s=req.queue_wait_s)
        self.scheduler.begin_chunked(slot)
        req.prefill_done = 0
        self._record_miss(req)
        self._process_chunk(req, slot, finished)

    def _begin_prefix(self, req: Request, slot: int, plan,
                      finished: list[Request]) -> None:
        """Prefix-seeded admission: alias the cached pages into the lane's
        block table, CoW-fork the boundary page if the plan resumes inside
        one (full-prompt hit), then stream ONLY the uncached suffix through
        the chunk step — a fully-cached prompt recomputes a single token."""
        mgr = self.store.manager
        mgr.admit(slot, self._prefix_rows(req, plan),
                  adopt_pages=plan.pages,
                  forks=0 if plan.fork_index is None else 1)
        self.obs.events.emit("admitted", req.req_id, slot=slot, mode="prefix",
                             cached_tokens=plan.resume,
                             cached_pages=len(plan.pages),
                             pages=[int(p) for p in plan.pages],
                             fork=plan.fork_index is not None,
                             fork_index=plan.fork_index,
                             queue_wait_s=req.queue_wait_s)
        if plan.fork_index is not None:
            self._cow(slot, mgr.cow_fork(slot, plan.fork_index))
        self.prefix.tree.touch(plan.nodes)
        self.metrics.inc("prefix_hits")
        self.metrics.inc("prefix_hit_tokens", plan.resume)
        self.scheduler.begin_chunked(slot)
        req.prefill_done = plan.resume
        self._process_chunk(req, slot, finished)

    def _process_chunk(self, req: Request, slot: int,
                       finished: list[Request]) -> None:
        """Feed one prompt chunk; the final chunk samples the first token
        and promotes the lane into the decode batch.  Chunks are
        page-aligned except a prefix plan's first (resume) chunk, which may
        start mid-page right after a CoW fork."""
        mgr = self.store.manager
        c = self._chunk_len
        start = req.prefill_done
        n = min(c, req.prompt_len - start)
        if self.prefix is not None:
            # CoW guard: the write range must never touch a shared page
            # (structurally only possible at `start`, and the planned fork
            # already privatized it — this keeps the invariant literal)
            move = mgr.ensure_writable(slot, start)
            if move is not None:
                self._cow(slot, move)
        if self._has_paged_kinds:
            mgr.ensure(slot, start + c)  # the padded tail lands in pages
        self.store.sync_tables()
        tokens = np.zeros((1, c), np.int32)
        tokens[0, :n] = req.prompt[start:start + n]
        t0 = time.perf_counter()
        with self.obs.tracer.span("chunk", lane=slot, req=req.req_id,
                                  slot=slot, start=start, n=n) as sp:
            logits, self.store.cache = self._chunk_fn(
                self.params, self.store.cache, tokens, jnp.int32(slot),
                np.asarray([start], np.int32), np.asarray([n], np.int32))
            sp.fence(logits)
        req.cost.prefill_s += time.perf_counter() - t0
        req.cost.dispatches += 1
        req.prefill_done = start + n
        self.metrics.inc("chunk_steps")
        self.metrics.inc("prefill_dispatches")
        self.obs.events.emit("chunk", req.req_id, slot=slot, start=start, n=n,
                             done=req.prefill_done >= req.prompt_len)
        if req.prefill_done >= req.prompt_len:
            s = req.sampling
            tok_dev = _sample_jit(
                logits, np.asarray([s.temperature], np.float32),
                np.asarray([s.top_k], np.int32), np.asarray([s.greedy]),
                self._lane_key(req)[None])
            mgr.set_length(slot, req.prompt_len)
            self.scheduler.promote(slot)
            self._maybe_publish(req, slot)
            self._arm_lane(req, slot, int(np.asarray(tok_dev)[0]))
            if self._should_evict(req):  # max_new_tokens == 1 (or instant EOS)
                self._evict(slot, finished)

    # ------------------------------------------------------------------
    # The engine loop
    # ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """One scheduler iteration: interleave admissions (or prompt
        chunks) with a batched decode over all occupied lanes. Returns
        requests finished this step."""
        obs = self.obs
        obs.profiler.step_begin()
        with obs.tracer.span("step", idx=self._step_idx + 1):
            finished = self._step_inner()
        obs.profiler.step_end()
        if obs.debug_invariants and self.paged and self._has_paged_kinds:
            bad = self.store.manager.invariant_violations()
            if bad:
                obs.events.emit("invariant_violation", step=self._step_idx,
                                violations=bad)
                raise AssertionError(
                    f"page-pool invariants violated at step {self._step_idx}: "
                    + "; ".join(bad))
        self.metrics.touch()
        return finished

    def _step_inner(self) -> list[Request]:
        self.metrics.begin()
        self._step_idx += 1
        self.metrics.inc("steps")
        finished: list[Request] = []
        self._shed_late(finished)
        budget = self.engine_cfg.max_prefills_per_step

        t0 = time.perf_counter()
        did_prefill = False
        # in-flight chunked admissions continue first (finish what's started)
        for slot, req in sorted(self.scheduler.chunking.items()):
            if budget <= 0:
                break
            self._process_chunk(req, slot, finished)
            budget -= 1
            did_prefill = True

        # admit one *dispatch* at a time: the per-dispatch capacity gate
        # tallies every member's page reservation against one pool
        # snapshot, so two jointly-unfittable requests can never both
        # pass.  The admission policy may stack several same-bucket
        # requests into one dispatch in BOTH cache modes (paged groups
        # scatter per-lane pages); chunked and prefix-seeded admissions
        # stay single-file via sentinel buckets.
        while budget > 0:
            group = self.scheduler.schedule_group(
                admit_ok=self._admit_gate(),
                bucket_of=self._admit_bucket,
                max_group=self.scheduler.free_slots)
            if not group:
                break
            budget -= 1
            did_prefill = True
            if len(group) > 1:
                if self.paged:
                    self._admit_group_paged(group)
                else:
                    self._admit_group(group)
                for req, slot in group:
                    if self._should_evict(req):
                        self._evict(slot, finished)
                continue
            req, slot = group[0]
            plan = self._prefix_plan(req) if self.paged else None
            if plan is not None:
                self._begin_prefix(req, slot, plan, finished)
            elif self._should_chunk(req):
                self._begin_chunked(req, slot, finished)
            else:
                self._admit(req, slot)
                if self._should_evict(req):  # max_new_tokens == 1 / instant EOS
                    self._evict(slot, finished)
        if did_prefill:
            jax.block_until_ready(self.store.cache["pos"])
            self.metrics.inc("prefill_s", time.perf_counter() - t0)

        occupancy = len(self.scheduler.running) + len(self.scheduler.chunking)
        self.metrics.max_gauge("peak_running", occupancy)

        if self.scheduler.running and self._spec is not None and self._spec_ready():
            t0 = time.perf_counter()
            spec_reqs = list(self.scheduler.running.values())
            self._spec_decode(finished)
            dt = time.perf_counter() - t0
            self.metrics.inc("decode_s", dt)
            share = dt / max(len(spec_reqs), 1)
            for req in spec_reqs:
                req.cost.verify_s += share
                req.cost.dispatches += 1
        elif self.scheduler.running:
            if self._spec is not None:
                # spec configured but this batch can't speculate (a
                # non-greedy lane) — the round falls back to plain decode
                self.obs.events.emit("spec_fallback",
                                     reason="non_greedy_lane",
                                     batch=len(self.scheduler.running))
            t0 = time.perf_counter()
            running = self.scheduler.running
            if self.paged and self._has_paged_kinds:
                mgr = self.store.manager
                for slot in running:
                    row = int(mgr.lengths[slot])
                    if self.prefix is not None:
                        # a lane's first write into a shared page forks it
                        # (structurally the admission fork already covers
                        # this; the guard keeps the invariant unconditional)
                        move = mgr.ensure_writable(slot, row)
                        if move is not None:
                            self._cow(slot, move)
                    mgr.ensure(slot, row + 1)
                    # KV footprint integral: pages held x decode steps
                    running[slot].cost.page_steps += len(mgr.lane_pages[slot])
                self.store.sync_tables()
                self.metrics.max_gauge("peak_pages_used", mgr.pages_in_use)
            active = np.zeros((self.engine_cfg.n_slots,), bool)
            active[list(running)] = True
            with self.obs.tracer.span("decode", lanes=list(running),
                                      batch=len(running)) as sp:
                toks, self.store.cache = self._decode_sample(
                    self.params, self._tokens, self.store.cache, active,
                    self._temps, self._topk, self._greedy, self._keys,
                    not bool(self._greedy.all()))
                sp.fence(toks)
            if self.paged:
                self.store.manager.advance(running)
            # feed the sampled tokens into the next decode device-to-device;
            # pull them to host lazily (only when scheduling needs them),
            # so all-greedy stretches pipeline like the static loop does
            self._tokens = toks
            decoded = dict(running)  # eviction below mutates the live dict
            self._pending.append((toks, decoded))
            self.metrics.inc("decode_steps")
            if self._needs_sync():
                self._flush(finished)
            dt = time.perf_counter() - t0
            self.metrics.inc("decode_s", dt)
            share = dt / max(len(decoded), 1)
            for req in decoded.values():
                req.cost.decode_s += share
                req.cost.dispatches += 1

        # policy-triggered pool compaction: evictions above may have left
        # holes; compacting now keeps the free list contiguous for the next
        # admissions (ROADMAP PR 3 follow-up: defrag existed, untriggered)
        if (self.paged and self._has_paged_kinds
                and self.policies.defrag.should_defrag(self.store.manager)):
            with self.obs.tracer.span("defrag") as sp:
                moves = self.store.defrag()
                sp.set(pages_moved=len(moves))
            if moves:
                self.metrics.inc("defrag_count")
                self.metrics.inc("defrag_pages_moved", len(moves))
                self.obs.events.emit("defrag", pages_moved=len(moves),
                                     moves=[[int(s), int(d)] for s, d in moves],
                                     step=self._step_idx)
        return finished

    # ------------------------------------------------------------------
    # Speculative decoding (repro/spec/)
    # ------------------------------------------------------------------
    def _spec_ready(self) -> bool:
        """Speculate only when every running lane is greedy — the fused
        accept rule is exact for argmax; a mixed batch falls back to plain
        decode wholesale (no per-lane mode split inside one dispatch)."""
        return all(r.sampling.greedy for r in self.scheduler.running.values())

    def _spec_decode(self, finished: list[Request]) -> None:
        """One draft-verify round over every running lane.

        Host-synchronous by design: the drafters read each lane's full
        token history and the accept length gates eviction, so pending
        plain-decode tokens are flushed first and this step's tokens land
        on the host immediately.  The verify dispatch itself stays
        traced-once — the window is always ``k + 1`` wide; per-lane draft
        counts and acceptance lengths are data (``n_draft`` mask, in-jit
        cumprod), never shapes.
        """
        spec = self._spec
        if self._pending:
            self._flush(finished)
        running = dict(self.scheduler.running)
        if not running:
            return
        # np.array (not asarray): a device array materializes as a read-only
        # view, and the accept loop below writes per-lane feed tokens
        self._tokens = np.array(self._tokens)
        n = self.engine_cfg.n_slots
        w = spec.width
        slots = sorted(running)
        histories = [running[s].prompt + running[s].output_tokens for s in slots]
        proposals = self._drafter.propose(slots, histories)

        toks = np.zeros((n, w), np.int32)
        n_draft = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        for slot, hist, props in zip(slots, histories, proposals):
            req = running[slot]
            # never draft past the lane's generation budget: the verify
            # row for draft j emits token j+1, so at most budget-1 drafts
            allow = max(0, min(spec.k,
                               req.max_new_tokens - len(req.output_tokens) - 1))
            props = [int(t) for t in props[:allow]]
            toks[slot, 0] = hist[-1]
            if props:
                toks[slot, 1:1 + len(props)] = props
            n_draft[slot] = len(props)
            active[slot] = True
            self.metrics.inc("spec_proposed", len(props))

        mgr = self.store.manager if self.paged else None
        base_row = {}
        if self.paged and self._has_paged_kinds:
            for slot in slots:
                row = int(mgr.lengths[slot])
                base_row[slot] = row
                if self.prefix is not None:
                    # the whole verify window must be privately writable
                    for move in mgr.ensure_writable_range(slot, row, w):
                        self._cow(slot, move)
                mgr.ensure(slot, row + w)
                running[slot].cost.page_steps += len(mgr.lane_pages[slot])
            self.store.sync_tables()
            self.metrics.max_gauge("peak_pages_used", mgr.pages_in_use)

        with self.obs.tracer.span("verify", batch=len(slots), width=w,
                                  lanes=slots) as sp:
            self.store.cache, targets, accepted = self._verify_fn(
                self.params, self.store.cache, toks, n_draft, active)
            sp.fence(targets, accepted)
        self.metrics.inc("verify_dispatches")
        self.metrics.inc("decode_steps")
        targets = np.asarray(targets)
        accepted = np.asarray(accepted)
        # journal the verify round's operands before the per-lane accept
        # loop below emits its own (eviction) events
        self.obs.events.emit(
            "spec_verify", lanes=[int(s) for s in slots],
            n_draft=[int(n_draft[s]) for s in slots],
            accepted=[int(accepted[s]) for s in slots])

        for slot in slots:
            req = running[slot]
            a = int(accepted[slot])
            # emit accepted drafts + the bonus/correction row, stopping at
            # EOS / budget exactly like the per-step plain-decode loop
            emitted = 0
            for j in range(a + 1):
                req.append_token(int(targets[slot, j]))
                emitted += 1
                if self._should_evict(req):
                    break
            self.metrics.inc("spec_accepted", min(emitted, a))
            self.metrics.observe("accept_len", min(emitted, a))
            if self.paged and self._has_paged_kinds:
                # rollback = block-table truncate: rejected rows' pages
                # stay reserved to the lane and are overwritten in place
                mgr.set_length(slot, base_row[slot] + emitted)
            self._tokens[slot] = req.output_tokens[-1]
            if self._should_evict(req):
                self._evict(slot, finished)

    def _shed_late(self, finished: list[Request]) -> None:
        """Deadline admission pre-pass: a request whose deadline already
        passed while it sat in the queue can only produce dead tokens, so
        shed it at ingress — before it burns a prefill dispatch and a lane
        another request could use.  Only policies exposing ``shed`` (e.g.
        ``DeadlineAdmission``) trigger this; FIFO et al. cost nothing."""
        shed = getattr(self.policies.admission, "shed", None)
        if shed is None or not self.scheduler.waiting:
            return
        now = self._clock()
        idxs = shed(self.scheduler.waiting, now)
        if not idxs:
            return
        for req in self.scheduler.drop(idxs):
            req.finish_reason_override = "deadline"
            self._plan_cache.pop(req.req_id, None)
            self.metrics.inc("deadline_shed")
            self.metrics.record_finished(req)
            self.obs.events.emit(
                "rejected", req.req_id, reason="deadline",
                waited_s=now - req.submit_time,
                deadline_s=req.deadline_s)
            if self._recorder is not None:
                self._recorder.record_finish(req)
            finished.append(req)

    def _should_evict(self, req: Request) -> bool:
        return self.policies.eviction.should_evict(req)

    def _needs_sync(self) -> bool:
        """Must the pending token arrays reach the host NOW?  Yes iff some
        running lane's next scheduling decision depends on token values
        (EOS armed), its PRNG key must advance (stochastic sampling), it
        streams tokens or text to a callback, or it reaches its length
        budget at this step (eviction due).  An eviction policy that
        inspects token values asks for per-step syncs wholesale."""
        if getattr(self.policies.eviction, "wants_step_sync", False):
            return True
        counts: dict[int, int] = {}
        for _, mapping in self._pending:
            for req in mapping.values():
                counts[req.req_id] = counts.get(req.req_id, 0) + 1
        for req in self.scheduler.running.values():
            if (req.eos_token is not None or not req.sampling.greedy
                    or req.on_token is not None or req.on_text is not None):
                return True
            if len(req.output_tokens) + counts.get(req.req_id, 0) >= req.max_new_tokens:
                return True
        return False

    def _flush(self, finished: list[Request]) -> None:
        """Materialize pending decode tokens, then evict completed lanes."""
        for toks_dev, mapping in self._pending:
            toks = np.asarray(toks_dev)
            for slot, req in mapping.items():
                req.append_token(int(toks[slot]))
        self._pending.clear()
        for slot, req in list(self.scheduler.running.items()):
            self._keys[slot] = self._lane_key(req)
            if self._should_evict(req):
                self._evict(slot, finished)

    def _evict(self, slot: int, finished: list[Request]) -> None:
        req = self.scheduler.release(slot)
        self.store.free(slot)
        if self._drafter is not None:
            self._drafter.release(slot)
        self._greedy[slot] = True  # free lanes sample nothing
        reason_of = getattr(self.policies.eviction, "evict_reason", None)
        reason = reason_of(req) if reason_of is not None else req.finish_reason
        if reason == "deadline" and not req.done:
            # SLO preemption (DeadlinePreemption): the lane was taken back
            # from a request that already missed its deadline so queued
            # on-time work can have it
            req.finish_reason_override = "deadline"
            self.metrics.inc("deadline_preempt")
            self.obs.events.emit(
                "evicted", req.req_id, slot=slot, reason="deadline",
                n_tokens=len(req.output_tokens),
                deadline_s=req.deadline_s)
        self.metrics.record_finished(req)
        extra = {}
        if req.deadline_s is not None:
            extra["deadline_s"] = req.deadline_s
            extra["deadline_hit"] = req.deadline_hit
        self.obs.events.emit(
            "finished", req.req_id, slot=slot,
            n_tokens=len(req.output_tokens),
            reason=reason,
            latency_s=req.latency_s, **extra)
        if self._recorder is not None:
            self._recorder.record_finish(req)
        finished.append(req)

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def run(self, arrivals=None, max_steps: int = 100_000,
            on_token=None) -> EngineMetrics:
        """Drive steps until idle.  ``arrivals``: optional list of
        ``(step_idx, prompt, max_new_tokens[, SamplingParams])`` tuples —
        requests injected when the engine reaches that step, simulating
        staggered traffic deterministically.  ``on_token(req, tok)``, if
        given, streams every arrival's tokens as they reach the host."""
        pending = sorted(arrivals or [], key=lambda a: a[0])
        i = 0
        steps_this_run = 0
        while (i < len(pending) or self.has_work) and steps_this_run < max_steps:
            while i < len(pending) and pending[i][0] <= self._step_idx:
                arr = pending[i]
                req = self.add_request(arr[1], arr[2],
                                       sampling=arr[3] if len(arr) > 3 else None)
                if on_token is not None:
                    req.on_token = functools.partial(on_token, req)
                i += 1
            if not self.has_work:
                # idle gap before the next arrival: jump to it
                self._step_idx = pending[i][0]
                continue
            self.step()
            steps_this_run += 1
        if self._pending:  # max_steps bail-out with tokens still in flight
            self._flush([])
        return self.metrics

    def stream(self, prompt: Sequence[int], max_new_tokens: int,
               sampling: Optional[SamplingParams] = None,
               eos_token: Optional[int] = None) -> Iterator[int]:
        """Submit a request and yield its tokens as the engine produces
        them, driving ``step()`` in between.  Other queued requests advance
        normally — this is the single-caller convenience over the
        ``on_token`` callback hook."""
        emitted: list[int] = []
        req = self.add_request(prompt, max_new_tokens, sampling=sampling,
                               eos_token=eos_token, on_token=emitted.append)
        i = 0
        while True:
            while i < len(emitted):
                yield emitted[i]
                i += 1
            if req.state is RequestState.FINISHED or not self.has_work:
                break
            self.step()
        yield from emitted[i:]
