"""Continuous-batching serving engine.

One ``ServingEngine`` owns a fixed pool of ``n_slots`` KV-cache lanes
(``slots.SlotCache``) and runs an iteration-level loop: every ``step()``

1. **admits** up to ``max_prefills_per_step`` FIFO-queued requests into
   free lanes — each admission is a batch=1 prefill (optionally padded to a
   prefill bucket so jit traces stay bounded) whose cache is scattered into
   the lane, and whose last-position logits yield the request's *first*
   token (the TTFT token);
2. **decodes** one token for every occupied lane in a single jitted
   ``decode_step`` over the whole pool — fixed shapes, zero retraces —
   sampling per-lane (greedy / temperature / top-k);
3. **evicts** finished lanes (length budget or EOS) immediately, so the
   next step can refill them instead of burning compute on dead lanes.

This is what keeps a byte-size integer GEMM accelerator fed: the decode
GEMMs always run at the full pool batch, prefill is interleaved instead of
lock-stepped, and a long request never stalls the batch (the failure mode
of the static ``serve_batch`` baseline).

The model side is the ordinary ``launch/steps.py`` builders, so the whole
quantized ``gemm_backend`` pipeline (Pallas SPOGA kernels, int8 KV cache,
parametric quant modes) serves every engine step unchanged.

Supported: decoder-only token-input stacks (any cache kind, including MLA
and recurrent state).  Prefill buckets require attention-family caches —
recurrent state integrates right-padding — so bucketed padding is rejected
for rglru/mlstm/slstm patterns at construction time.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KV_CACHE_HEADROOM, ModelConfig, default_cache_len
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.serving.metrics import EngineMetrics
from repro.serving.request import Request
from repro.serving.sampling import SamplingParams, request_key, sample_tokens
from repro.serving.scheduler import FIFOScheduler
from repro.serving.slots import SlotCache

RECURRENT_KINDS = frozenset({"rglru", "mlstm", "slstm"})

_ZERO_KEY = np.zeros((2,), np.uint32)


# jit wrappers are cached per (cfg, cache_len) so spinning up a new engine
# (benchmark sweeps, tests) reuses compiled traces instead of re-jitting —
# ``make_*_step`` returns a fresh closure per call, which defeats jax's own
# cache if wrapped naively per instance.
@functools.lru_cache(maxsize=None)
def _jitted_admit(cfg: ModelConfig, cache_len: int):
    """Fused admission: prefill + first-token sample + lane scatter in ONE
    dispatch (the batch=1 cache never materializes as a standalone output).
    Single prefills are the engine's per-request overhead; at small scale
    dispatch latency rivals compute, so fusion matters."""
    from repro.serving.slots import scatter_lane

    prefill = make_prefill_step(cfg, cache_len, with_lengths=True)

    def admit(pool, params, tokens, lengths, slot, temp, topk, greedy, key,
              axes_flat):
        logits, single = prefill(params, {"tokens": tokens}, lengths)
        tok = sample_tokens(logits, temp, topk, greedy, key)
        return tok, scatter_lane(pool, single, slot, axes_flat)

    return jax.jit(admit, donate_argnums=(0,), static_argnums=(9,))


@functools.lru_cache(maxsize=None)
def _jitted_decode_sample(cfg: ModelConfig):
    """Fused decode+sample: one jit dispatch per engine step.

    ``any_stochastic`` is static so the all-greedy trace (the default, and
    every exact-match path) lowers to a pure argmax — without it every step
    would pay sample_tokens' full-vocab sort + categorical just to discard
    the result in the greedy ``where``."""
    decode = make_serve_step(cfg)

    def step(params, tokens, cache, temps, topk, greedy, keys,
             any_stochastic: bool):
        logits, cache = decode(params, tokens, cache)
        if any_stochastic:
            toks = sample_tokens(logits, temps, topk, greedy, keys)
        else:
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return toks, cache

    return jax.jit(step, donate_argnums=(2,), static_argnums=(7,))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape/policy knobs (model behaviour stays in ``ModelConfig``)."""

    n_slots: int = 4
    cache_len: int = 256
    max_prefills_per_step: int = 1
    # Prompt lengths are padded up to the smallest bucket >= len(prompt) so
    # the jitted prefill traces at most len(buckets) shapes. None/() = exact
    # lengths (one trace per distinct prompt length).
    prefill_buckets: Optional[tuple[int, ...]] = None
    eos_token: Optional[int] = None

    @staticmethod
    def for_workload(prompt_len: int, gen_tokens: int, n_slots: int = 4,
                     **kw) -> "EngineConfig":
        """Cache sized by the shared serving policy (prompt + gen + headroom)."""
        return EngineConfig(
            n_slots=n_slots,
            cache_len=default_cache_len(prompt_len, gen_tokens),
            **kw,
        )


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig):
        if cfg.is_encoder_decoder or cfg.frontend is not None:
            raise ValueError(
                "ServingEngine handles decoder-only token-input models; "
                "enc-dec / frontend archs serve via launch.serve.serve_batch")
        buckets = tuple(sorted(engine_cfg.prefill_buckets or ()))
        if buckets and RECURRENT_KINDS & set(cfg.block_pattern):
            raise ValueError(
                f"prefill buckets pad prompts, but {sorted(RECURRENT_KINDS & set(cfg.block_pattern))} "
                "state integrates padded tokens; use exact-length prefill "
                "(prefill_buckets=None) for recurrent stacks")
        if buckets and buckets[-1] > engine_cfg.cache_len:
            raise ValueError("largest prefill bucket exceeds cache_len")
        self.cfg = cfg
        self.params = params
        self.engine_cfg = engine_cfg
        self.buckets = buckets

        n = engine_cfg.n_slots
        self.scheduler = FIFOScheduler(n, engine_cfg.max_prefills_per_step)
        self.slots = SlotCache(cfg, n, engine_cfg.cache_len)
        self.metrics = EngineMetrics()

        self._admit_fn = _jitted_admit(cfg, engine_cfg.cache_len)
        self._decode_sample = _jitted_decode_sample(cfg)

        # per-lane state. ``_tokens`` may be a DEVICE array: between sync
        # points sampled tokens feed the next decode device-to-device (see
        # ``step``); the rest are host arrays passed to the fused step.
        self._tokens = np.zeros((n,), np.int32)
        self._temps = np.ones((n,), np.float32)
        self._topk = np.zeros((n,), np.int32)
        self._greedy = np.ones((n,), bool)
        self._keys = np.zeros((n, 2), np.uint32)
        # decode steps whose tokens haven't been pulled to host yet:
        # (device (n,) tokens, {slot: request} snapshot at that step)
        self._pending: list = []
        self._next_id = 0
        self._step_idx = 0

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def add_request(self, prompt: Sequence[int], max_new_tokens: int,
                    sampling: Optional[SamplingParams] = None,
                    eos_token: Optional[int] = None) -> Request:
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        need = len(prompt) + max_new_tokens
        if need > self.engine_cfg.cache_len + 1:
            raise ValueError(
                f"request needs {need} cache positions but cache_len="
                f"{self.engine_cfg.cache_len}; size the engine with "
                f"default_cache_len(prompt_len, gen) [headroom={KV_CACHE_HEADROOM}]")
        req = Request(
            req_id=self._next_id,
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            sampling=sampling or SamplingParams(),
            eos_token=self.engine_cfg.eos_token if eos_token is None else eos_token,
            submit_time=time.perf_counter(),
        )
        self._next_id += 1
        self.scheduler.submit(req)
        return req

    def _bucket_len(self, prompt_len: int) -> int:
        for b in self.buckets:
            if b >= prompt_len:
                return b
        return prompt_len

    def _lane_key(self, req: Request) -> np.ndarray:
        if req.sampling.greedy:
            return _ZERO_KEY
        k = request_key(req.sampling.seed, req.req_id, len(req.output_tokens))
        return np.asarray(k, np.uint32)

    def _admit(self, req: Request, slot: int) -> None:
        padded_len = self._bucket_len(req.prompt_len)
        tokens = np.zeros((1, padded_len), np.int32)
        tokens[0, :req.prompt_len] = req.prompt
        s = req.sampling
        tok_dev, self.slots.cache = self._admit_fn(
            self.slots.cache, self.params, tokens,
            np.asarray([req.prompt_len], np.int32), jnp.int32(slot),
            np.asarray([s.temperature], np.float32),
            np.asarray([s.top_k], np.int32),
            np.asarray([s.greedy]),
            self._lane_key(req)[None],
            self.slots._axes_flat,
        )
        tok = int(np.asarray(tok_dev)[0])
        req.append_token(tok)  # stamps TTFT
        self.metrics.prefills += 1
        self._tokens = jnp.asarray(self._tokens).at[slot].set(tok)
        self._temps[slot] = s.temperature
        self._topk[slot] = s.top_k
        self._greedy[slot] = s.greedy
        self._keys[slot] = self._lane_key(req)

    # ------------------------------------------------------------------
    # The engine loop
    # ------------------------------------------------------------------
    def step(self) -> list[Request]:
        """One scheduler iteration: interleave admissions with a batched
        decode over all occupied lanes. Returns requests finished this step."""
        self.metrics.begin()
        self._step_idx += 1
        self.metrics.steps += 1
        finished: list[Request] = []

        admitted = self.scheduler.schedule()
        if admitted:
            t0 = time.perf_counter()
            for req, slot in admitted:
                self._admit(req, slot)
                if req.done:  # max_new_tokens == 1 (or instant EOS)
                    self._evict(slot, finished)
            jax.block_until_ready(self.slots.cache["pos"])
            self.metrics.prefill_s += time.perf_counter() - t0

        if self.scheduler.running:
            t0 = time.perf_counter()
            toks, self.slots.cache = self._decode_sample(
                self.params, self._tokens, self.slots.cache,
                self._temps, self._topk, self._greedy, self._keys,
                not bool(self._greedy.all()))
            # feed the sampled tokens into the next decode device-to-device;
            # pull them to host lazily (only when scheduling needs them),
            # so all-greedy stretches pipeline like the static loop does
            self._tokens = toks
            self._pending.append((toks, dict(self.scheduler.running)))
            self.metrics.decode_steps += 1
            if self._needs_sync():
                self._flush(finished)
            self.metrics.decode_s += time.perf_counter() - t0
        return finished

    def _needs_sync(self) -> bool:
        """Must the pending token arrays reach the host NOW?  Yes iff some
        running lane's next scheduling decision depends on token values
        (EOS armed), its PRNG key must advance (stochastic sampling), or it
        reaches its length budget at this step (eviction due)."""
        counts: dict[int, int] = {}
        for _, mapping in self._pending:
            for req in mapping.values():
                counts[req.req_id] = counts.get(req.req_id, 0) + 1
        for req in self.scheduler.running.values():
            if req.eos_token is not None or not req.sampling.greedy:
                return True
            if len(req.output_tokens) + counts.get(req.req_id, 0) >= req.max_new_tokens:
                return True
        return False

    def _flush(self, finished: list[Request]) -> None:
        """Materialize pending decode tokens, then evict completed lanes."""
        for toks_dev, mapping in self._pending:
            toks = np.asarray(toks_dev)
            for slot, req in mapping.items():
                req.append_token(int(toks[slot]))
        self._pending.clear()
        for slot, req in list(self.scheduler.running.items()):
            self._keys[slot] = self._lane_key(req)
            if req.done:
                self._evict(slot, finished)

    def _evict(self, slot: int, finished: list[Request]) -> None:
        req = self.scheduler.release(slot)
        self.slots.free(slot)
        self._greedy[slot] = True  # free lanes sample nothing
        self.metrics.record_finished(req)
        finished.append(req)

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def run(self, arrivals=None, max_steps: int = 100_000) -> EngineMetrics:
        """Drive steps until idle.  ``arrivals``: optional list of
        ``(step_idx, prompt, max_new_tokens[, SamplingParams])`` tuples —
        requests injected when the engine reaches that step, simulating
        staggered traffic deterministically."""
        pending = sorted(arrivals or [], key=lambda a: a[0])
        i = 0
        steps_this_run = 0
        while (i < len(pending) or self.has_work) and steps_this_run < max_steps:
            while i < len(pending) and pending[i][0] <= self._step_idx:
                arr = pending[i]
                self.add_request(arr[1], arr[2],
                                 sampling=arr[3] if len(arr) > 3 else None)
                i += 1
            if not self.has_work:
                # idle gap before the next arrival: jump to it
                self._step_idx = pending[i][0]
                continue
            self.step()
            steps_this_run += 1
        if self._pending:  # max_steps bail-out with tokens still in flight
            self._flush([])
        return self.metrics
