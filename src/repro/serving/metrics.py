"""Engine-level serving metrics, refactored onto the ``repro.obs``
metrics registry.

``EngineMetrics`` used to be a 30-field dataclass of means and counters
that engine code poked directly (``metrics.prefills += 1``).  It is now a
facade over an ``obs.MetricsRegistry``: engine code *emits* events —

    metrics.inc("prefills")            # counters / time accumulators
    metrics.set_gauge("pages_total", n)
    metrics.max_gauge("peak_running", occupancy)
    metrics.observe("accept_len", a)   # histograms

— and summaries are *derived*.  ``report()`` keeps every legacy key
bit-for-bit and adds exact p50/p95/p99 percentiles for TTFT, decode
per-token latency, queue wait and speculative acceptance length, computed
from the registry's log-bucketed histograms (which retain raw samples).

Backward compatibility: every legacy field name still reads (and writes)
through attribute access, so ``metrics.prefix_hits`` in tests and
benchmarks keeps working.  Direct *assignment* from external code is a
deprecation shim — it warns and forwards to the registry — because the
event-style API is the supported surface.

Wall-clock accounting is robust to empty runs: ``begin()`` stamps the
start once, every engine step ``touch()``-es the end, and
``record_finished`` advances it — so a run that finishes zero requests no
longer reports a wall time derived from a falsy ``end_time`` (the old
behaviour made ``wall_s`` grow forever after the run ended).
"""

from __future__ import annotations

import time
import warnings

from repro.obs.metrics import MetricsRegistry
from repro.serving.request import Request

# integer event counters (legacy dataclass fields, now registry counters)
_COUNTERS = (
    "steps", "prefills", "prefill_dispatches", "stacked_prefills",
    "decode_steps", "chunk_steps", "defrag_count", "defrag_pages_moved",
    "prefix_hits", "prefix_misses", "prefix_hit_tokens", "prefix_cow_forks",
    "prefix_evicted_pages", "spec_proposed", "spec_accepted",
    "verify_dispatches",
    # SLO accounting: per-request deadline outcome (stamped at finish) and
    # tokens from deadline-respecting requests (the goodput numerator —
    # no-deadline requests always count; a missed deadline zeroes the
    # request's contribution)
    "deadline_hits", "deadline_misses", "deadline_late_admissions",
    "goodput_tokens",
    # requests dropped at ingress by DeadlineAdmission (already late in
    # queue; they finish with reason="deadline" without holding a lane)
    "deadline_shed",
    # running lanes preempted by DeadlinePreemption (deadline already
    # missed while queued work could still hit its own)
    "deadline_preempt",
)
# float time accumulators (counters that add seconds)
_TIMERS = ("prefill_s", "decode_s")
# last-value / running-max gauges
_GAUGES = ("peak_running", "pages_total", "page_size", "peak_pages_used",
           "prefix_tree_pages", "start_time", "end_time")
_FIELDS = frozenset(_COUNTERS + _TIMERS + _GAUGES)

# request-derived latency histograms (seconds unless noted)
_HISTOGRAMS = (
    "ttft_s",        # submit -> first sampled token
    "latency_s",     # submit -> finished
    "per_token_s",   # decode-only: (latency - ttft) / (n_tokens - 1)
    "queue_wait_s",  # submit -> admitted into a lane
    "accept_len",    # accepted drafts per speculative verify round (count)
    # per-request cost attribution (from Request.cost, observed at finish)
    "cost_prefill_s",   # prefill/chunk dispatch time attributed to the req
    "cost_decode_s",    # share of batched decode dispatch time
    "cost_verify_s",    # share of batched spec draft+verify time
    "cost_page_steps",  # sum over decode steps of pages held (paged only)
)


def _mean(xs):
    xs = [x for x in xs if x is not None]
    return sum(xs) / len(xs) if xs else 0.0


class EngineMetrics:
    """Accumulated over an engine run; ``report()`` emits the summary."""

    def __init__(self):
        d = self.__dict__
        d["registry"] = MetricsRegistry()
        d["finished"] = []
        for name in _COUNTERS + _TIMERS:
            self.registry.counter(name)
        for name in _GAUGES:
            self.registry.gauge(name)
        for name in _HISTOGRAMS:
            self.registry.histogram(name)

    # -- attribute facade (legacy field names) -----------------------------
    def __getattr__(self, name):
        # only reached when ``name`` is not an instance attribute
        reg = self.__dict__["registry"]
        if name in _COUNTERS or name in _TIMERS:
            return reg.counter(name).value
        if name in _GAUGES:
            return reg.gauge(name).value
        raise AttributeError(f"EngineMetrics has no attribute {name!r}")

    def __setattr__(self, name, value):
        if name in _FIELDS:
            warnings.warn(
                f"direct assignment to EngineMetrics.{name} is deprecated; "
                "use inc()/set_gauge()/max_gauge()/observe()",
                DeprecationWarning, stacklevel=2)
            self._force(name, value)
        else:
            self.__dict__[name] = value

    def _force(self, name, value):
        """Set a metric to an absolute value (shim + internal stamps)."""
        reg = self.registry
        if name in _COUNTERS or name in _TIMERS:
            reg.counter(name).value = value
        else:
            reg.gauge(name).set(value)

    # -- the event-style emission API (what engine code calls) ------------
    def inc(self, name: str, n=1) -> None:
        self.registry.inc(name, n)

    def set_gauge(self, name: str, value) -> None:
        self.registry.set(name, value)

    def max_gauge(self, name: str, value) -> None:
        self.registry.set_max(name, value)

    def observe(self, name: str, value) -> None:
        self.registry.observe(name, value)

    # -- run lifecycle -----------------------------------------------------
    def begin(self) -> None:
        if not self.start_time:
            self._force("start_time", time.perf_counter())

    def touch(self) -> None:
        """Advance the run's end stamp (each engine step calls this, so an
        empty run — zero finished requests — still reports the true
        wall time instead of a clock that keeps running)."""
        self._force("end_time", time.perf_counter())

    def record_finished(self, req: Request) -> None:
        req.finish_time = time.perf_counter()
        self._force("end_time", req.finish_time)
        self.finished.append(req)
        if req.ttft_s is not None:
            self.observe("ttft_s", req.ttft_s)
        if req.latency_s is not None:
            self.observe("latency_s", req.latency_s)
            n = len(req.output_tokens)
            if n > 1 and req.ttft_s is not None:
                self.observe("per_token_s", (req.latency_s - req.ttft_s) / (n - 1))
        if req.queue_wait_s is not None:
            self.observe("queue_wait_s", req.queue_wait_s)
        # SLO outcome + goodput: no-deadline requests always count
        hit = req.deadline_hit
        if hit is not None:
            self.inc("deadline_hits" if hit else "deadline_misses")
            if getattr(req, "late_at_admission", False):
                self.inc("deadline_late_admissions")
        if hit is not False:
            self.inc("goodput_tokens", len(req.output_tokens))
        cost = getattr(req, "cost", None)
        if cost is not None and cost.dispatches:
            self.observe("cost_prefill_s", cost.prefill_s)
            self.observe("cost_decode_s", cost.decode_s)
            if cost.verify_s:
                self.observe("cost_verify_s", cost.verify_s)
            if cost.page_steps:
                self.observe("cost_page_steps", cost.page_steps)

    # -- summary -----------------------------------------------------------
    @property
    def wall_s(self) -> float:
        start = self.start_time
        if not start:
            return 0.0
        # mid-run report (no touch yet): live reading; afterwards the last
        # step / finish stamp bounds the run even with nothing finished
        end = self.end_time or time.perf_counter()
        return max(end - start, 1e-9)

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.output_tokens) for r in self.finished)

    def _pct(self, name: str, q: float, digits: int = 6) -> float:
        return round(self.registry.histogram(name).percentile(q), digits)

    def report(self) -> dict:
        """Machine-readable summary (also what ``BENCH_serve.json`` stores).
        Every pre-observability key is preserved; the ``*_p50/_p95/_p99``
        keys are exact percentiles over finished requests (and, for
        ``accept_len``, over speculative verify rounds)."""
        reqs = self.finished
        wall = self.wall_s
        return {
            "requests": len(reqs),
            "generated_tokens": self.generated_tokens,
            "prompt_tokens": sum(r.prompt_len for r in reqs),
            "wall_s": round(wall, 4),
            "tokens_per_s": round(self.generated_tokens / max(wall, 1e-9), 2),
            "steps": self.steps,
            "prefills": self.prefills,
            "prefill_dispatches": self.prefill_dispatches,
            "stacked_prefills": self.stacked_prefills,
            "decode_steps": self.decode_steps,
            "prefill_s": round(self.prefill_s, 4),
            "decode_s": round(self.decode_s, 4),
            "ttft_mean_s": round(_mean([r.ttft_s for r in reqs]), 4),
            "ttft_max_s": round(max([r.ttft_s or 0.0 for r in reqs], default=0.0), 4),
            "ttft_p50_s": self._pct("ttft_s", 50),
            "ttft_p95_s": self._pct("ttft_s", 95),
            "ttft_p99_s": self._pct("ttft_s", 99),
            "latency_mean_s": round(_mean([r.latency_s for r in reqs]), 4),
            "latency_max_s": round(
                max([r.latency_s or 0.0 for r in reqs], default=0.0), 4),
            "per_token_p50_s": self._pct("per_token_s", 50),
            "per_token_p95_s": self._pct("per_token_s", 95),
            "per_token_p99_s": self._pct("per_token_s", 99),
            "queue_wait_p50_s": self._pct("queue_wait_s", 50),
            "queue_wait_p95_s": self._pct("queue_wait_s", 95),
            "queue_wait_p99_s": self._pct("queue_wait_s", 99),
            "peak_running": self.peak_running,
            "chunk_steps": self.chunk_steps,
            "pages_total": self.pages_total,
            "page_size": self.page_size,
            "peak_pages_used": self.peak_pages_used,
            "defrag_count": self.defrag_count,
            "defrag_pages_moved": self.defrag_pages_moved,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_cow_forks": self.prefix_cow_forks,
            "prefix_evicted_pages": self.prefix_evicted_pages,
            "prefix_tree_pages": self.prefix_tree_pages,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
            "verify_dispatches": self.verify_dispatches,
            "acceptance_rate": round(
                self.spec_accepted / self.spec_proposed, 4)
            if self.spec_proposed else 0.0,
            "accept_len_p50": self._pct("accept_len", 50, 2),
            "accept_len_p95": self._pct("accept_len", 95, 2),
            "accept_len_p99": self._pct("accept_len", 99, 2),
            "deadline_hits": self.deadline_hits,
            "deadline_misses": self.deadline_misses,
            "deadline_shed": self.deadline_shed,
            "deadline_preempt": self.deadline_preempt,
            "deadline_hit_rate": round(
                self.deadline_hits / (self.deadline_hits
                                      + self.deadline_misses), 4)
            if (self.deadline_hits + self.deadline_misses) else None,
            "goodput_tokens": self.goodput_tokens,
            "goodput_tokens_per_s": round(
                self.goodput_tokens / max(wall, 1e-9), 2),
            "cost_prefill_p99_s": self._pct("cost_prefill_s", 99),
            "cost_decode_p99_s": self._pct("cost_decode_s", 99),
            "cost_verify_p99_s": self._pct("cost_verify_s", 99),
        }

    def format_report(self) -> str:
        r = self.report()
        return (
            f"[engine] {r['requests']} requests, {r['generated_tokens']} tokens "
            f"in {r['wall_s']:.2f}s = {r['tokens_per_s']:.1f} tok/s | "
            f"{r['prefills']} prefills + {r['decode_steps']} decode steps | "
            f"TTFT mean {r['ttft_mean_s']*1e3:.0f}ms "
            f"p99 {r['ttft_p99_s']*1e3:.0f}ms max {r['ttft_max_s']*1e3:.0f}ms | "
            f"latency mean {r['latency_mean_s']:.2f}s"
        )
