"""Engine-level serving metrics: throughput, TTFT, per-request latency."""

from __future__ import annotations

import dataclasses
import time

from repro.serving.request import Request


def _mean(xs):
    xs = [x for x in xs if x is not None]
    return sum(xs) / len(xs) if xs else 0.0


@dataclasses.dataclass
class EngineMetrics:
    """Accumulated over an engine run; ``report()`` emits the summary."""

    start_time: float = 0.0
    end_time: float = 0.0
    steps: int = 0
    prefills: int = 0
    # prefill *dispatches*: a stacked (same-bucket) admission counts once
    # here but once per request in ``prefills`` — the gap is what batched
    # admission amortizes.  Chunked admissions count one dispatch per
    # chunk (they can exceed ``prefills``), so the amortization ratio is
    # only meaningful for unchunked (slot-mode) serving.
    prefill_dispatches: int = 0
    stacked_prefills: int = 0   # requests admitted via a >=2-wide stack
    decode_steps: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    finished: list = dataclasses.field(default_factory=list)
    # concurrency: most lanes simultaneously holding a request (running +
    # mid-chunk) — the headline the paged cache improves at a fixed KV
    # budget, since short requests no longer pin worst-case lanes
    peak_running: int = 0
    # paged-cache accounting (0 when serving from the slot cache)
    chunk_steps: int = 0
    pages_total: int = 0
    page_size: int = 0
    peak_pages_used: int = 0
    # pool compactions triggered by the engine's DefragPolicy
    defrag_count: int = 0
    defrag_pages_moved: int = 0
    # shared-prefix cache (repro/prefix/; all 0 when the cache is off):
    # admissions that adopted cached pages / admitted cold, prompt tokens
    # whose prefill was skipped, CoW page forks, pages LRU-evicted from the
    # tree under pool pressure, and the tree's current page footprint
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_hit_tokens: int = 0
    prefix_cow_forks: int = 0
    prefix_evicted_pages: int = 0
    prefix_tree_pages: int = 0
    # speculative decoding (repro/spec/; all 0 when spec is off): drafted
    # tokens dispatched for verification, drafts accepted, and verify
    # dispatches (each verify also counts once in ``decode_steps`` — the
    # tok/s win is generated_tokens growing faster than decode_steps)
    spec_proposed: int = 0
    spec_accepted: int = 0
    verify_dispatches: int = 0

    def begin(self) -> None:
        if not self.start_time:
            self.start_time = time.perf_counter()

    def record_finished(self, req: Request) -> None:
        req.finish_time = time.perf_counter()
        self.end_time = req.finish_time
        self.finished.append(req)

    # -- summary -----------------------------------------------------------
    @property
    def wall_s(self) -> float:
        end = self.end_time or time.perf_counter()
        return max(end - self.start_time, 1e-9)

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.output_tokens) for r in self.finished)

    def report(self) -> dict:
        """Machine-readable summary (also what ``BENCH_serve.json`` stores)."""
        reqs = self.finished
        return {
            "requests": len(reqs),
            "generated_tokens": self.generated_tokens,
            "prompt_tokens": sum(r.prompt_len for r in reqs),
            "wall_s": round(self.wall_s, 4),
            "tokens_per_s": round(self.generated_tokens / self.wall_s, 2),
            "steps": self.steps,
            "prefills": self.prefills,
            "prefill_dispatches": self.prefill_dispatches,
            "stacked_prefills": self.stacked_prefills,
            "decode_steps": self.decode_steps,
            "prefill_s": round(self.prefill_s, 4),
            "decode_s": round(self.decode_s, 4),
            "ttft_mean_s": round(_mean([r.ttft_s for r in reqs]), 4),
            "ttft_max_s": round(max([r.ttft_s or 0.0 for r in reqs], default=0.0), 4),
            "latency_mean_s": round(_mean([r.latency_s for r in reqs]), 4),
            "latency_max_s": round(
                max([r.latency_s or 0.0 for r in reqs], default=0.0), 4),
            "peak_running": self.peak_running,
            "chunk_steps": self.chunk_steps,
            "pages_total": self.pages_total,
            "page_size": self.page_size,
            "peak_pages_used": self.peak_pages_used,
            "defrag_count": self.defrag_count,
            "defrag_pages_moved": self.defrag_pages_moved,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_cow_forks": self.prefix_cow_forks,
            "prefix_evicted_pages": self.prefix_evicted_pages,
            "prefix_tree_pages": self.prefix_tree_pages,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
            "verify_dispatches": self.verify_dispatches,
            "acceptance_rate": round(
                self.spec_accepted / self.spec_proposed, 4)
            if self.spec_proposed else 0.0,
        }

    def format_report(self) -> str:
        r = self.report()
        return (
            f"[engine] {r['requests']} requests, {r['generated_tokens']} tokens "
            f"in {r['wall_s']:.2f}s = {r['tokens_per_s']:.1f} tok/s | "
            f"{r['prefills']} prefills + {r['decode_steps']} decode steps | "
            f"TTFT mean {r['ttft_mean_s']*1e3:.0f}ms max {r['ttft_max_s']*1e3:.0f}ms | "
            f"latency mean {r['latency_mean_s']:.2f}s"
        )
