"""Pluggable engine policies: admission, eviction, defrag.

The engine used to hard-code its scheduling decisions — FIFO head-of-line
admission in ``scheduler.py``, ``req.done`` eviction checks and (since the
paged cache landed) *no* defrag trigger at all in ``engine.py``.  Every new
serving scenario (priority tiers, preemption, prefix sharing, latency-SLO
eviction) meant engine surgery.  This module turns each decision into a
small policy object behind a ``Protocol``, so scenario growth is a new
policy class:

* ``AdmissionPolicy`` — which waiting requests become the next prefill
  *dispatch*.  The default ``FIFOAdmission`` admits the FIFO head, one
  request per dispatch (exactly the old behaviour).
  ``BucketBatchedAdmission`` stacks same-bucket prompts into ONE batched
  prefill dispatch, amortizing admission cost under bursty arrivals.
  ``DeadlineAdmission`` additionally *sheds* requests whose deadline
  already expired in queue (``rejected(reason="deadline")``), so doomed
  work never occupies a lane.
* ``EvictionPolicy`` — when a running request leaves its lane.  The
  default ``BudgetOrEOSEviction`` evicts on length budget or EOS
  (``Request.done``).
* ``DefragPolicy`` — when the paged engine compacts its page pool.
  ``PagedCache.defrag()`` existed with nothing triggering it; the default
  ``ThresholdDefrag`` fires when the pool's fragmentation ratio crosses a
  threshold, and the engine reports a ``defrag_count`` metric.
* ``PrefixPolicy`` — how the shared-prefix cache (``repro/prefix/``)
  participates in admission: whether a prompt's cached prefix is adopted
  and whether a finished prefill publishes its pages.  The default
  ``SharedPrefix`` matches and publishes everything; ``NoPrefixReuse``
  keeps the subsystem inert.

Policies are *output-invisible* by construction where the exact-match
serving tests demand it: admission stacking only changes how prefills are
dispatched (prefill is batch-parallel), eviction defaults reproduce
``req.done``, and defrag only moves pages (the block tables are remapped
in the same step).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Protocol, Sequence, runtime_checkable

from repro.serving.request import Request


@runtime_checkable
class AdmissionPolicy(Protocol):
    def next_group(self, waiting: Sequence[Request], max_group: int,
                   admit_ok: Callable[[Request], bool],
                   bucket_of: Callable[[Request], int]) -> list[int]:
        """Indices into ``waiting`` forming the next admission *dispatch*.

        ``max_group`` is the engine's hard cap (free slots; 1 when the
        cache mode cannot stack).  ``admit_ok`` is the capacity gate
        (paged reservations).  ``bucket_of`` maps a request to its padded
        prefill length — only same-bucket requests can share a dispatch.
        Return ``[]`` to admit nothing this step.
        """
        ...


@runtime_checkable
class EvictionPolicy(Protocol):
    # Policies that decide on *token values* (not just counts) must set
    # this True so the engine syncs pending device tokens every step
    # instead of at its lazy sync points.
    wants_step_sync: bool

    def should_evict(self, req: Request) -> bool:
        """True when a running request must leave its lane now."""
        ...


@runtime_checkable
class DefragPolicy(Protocol):
    def should_defrag(self, manager) -> bool:
        """True when the paged pool should compact (``manager`` is the
        engine's ``paging.PageManager``)."""
        ...


@runtime_checkable
class PrefixPolicy(Protocol):
    def plan(self, cache, req: Request):
        """The prefix-cache decision for an admission: a
        ``prefix.PrefixPlan`` to adopt, or None to admit cold.  ``cache``
        is the engine's ``prefix.PrefixCache``."""
        ...

    def should_publish(self, req: Request) -> bool:
        """Should this request's prompt pages enter the tree after its
        prefill completes?"""
        ...


# ---------------------------------------------------------------------------
# Default implementations
# ---------------------------------------------------------------------------

class FIFOAdmission:
    """Head-of-line FIFO, one request per prefill dispatch (the engine's
    historical behaviour).  A vetoed head blocks later arrivals on purpose:
    skipping ahead to smaller requests would starve large ones forever."""

    def next_group(self, waiting, max_group, admit_ok, bucket_of):
        if waiting and admit_ok(waiting[0]):
            return [0]
        return []


class BucketBatchedAdmission:
    """FIFO head plus any later waiting requests that round to the SAME
    prefill bucket, stacked into one batched prefill dispatch.

    Prefill is batch-parallel (each row attends only within itself, and
    right-padding is masked by per-sequence lengths), so stacking changes
    dispatch count, not outputs.  Head-of-line fairness is preserved: the
    head always admits first, and only its bucket-mates jump the queue —
    they would have padded to the identical shape anyway, so admitting
    them now amortizes the dispatch instead of re-paying it next step.

    ``max_group`` caps the stack (None = whatever the engine allows, i.e.
    the free-slot count).
    """

    def __init__(self, max_group: Optional[int] = None):
        if max_group is not None and max_group < 1:
            raise ValueError("max_group must be >= 1")
        self.max_group = max_group

    def next_group(self, waiting, max_group, admit_ok, bucket_of):
        if not waiting or not admit_ok(waiting[0]):
            return []
        cap = max_group if self.max_group is None else min(max_group,
                                                           self.max_group)
        head_bucket = bucket_of(waiting[0])
        group = [0]
        for i in range(1, len(waiting)):
            if len(group) >= cap:
                break
            if bucket_of(waiting[i]) == head_bucket and admit_ok(waiting[i]):
                group.append(i)
        return group


class DeadlineAdmission:
    """FIFO admission that sheds already-late requests at ingress.

    A request whose deadline expired while it sat in the queue cannot
    count toward goodput no matter how it is served — admitting it burns
    a prefill dispatch and a lane that an on-time request could have used
    (the ``late_at_admission`` pathology the SLO metrics record).  The
    engine calls ``shed`` once per step *before* admission; dropped
    requests finish immediately with reason ``"deadline"`` and a
    ``rejected`` event, and everything still inside its deadline admits
    in plain FIFO order.  No-deadline requests are never shed.

    ``slack_s`` optionally sheds requests that are not yet late but are
    guaranteed to be (e.g. known prefill floor); the default 0.0 sheds
    only requests already past their deadline, which keeps the policy
    strictly work-conserving.
    """

    def __init__(self, slack_s: float = 0.0):
        if slack_s < 0.0:
            raise ValueError("slack_s must be >= 0")
        self.slack_s = slack_s

    def next_group(self, waiting, max_group, admit_ok, bucket_of):
        if waiting and admit_ok(waiting[0]):
            return [0]
        return []

    def shed(self, waiting, now: float) -> list[int]:
        """Indices of waiting requests already past their deadline."""
        return [i for i, r in enumerate(waiting)
                if r.deadline_s is not None
                and now - r.submit_time > r.deadline_s - self.slack_s]


class PrefixAwareAdmission:
    """Admit requests sharing a hot radix-tree prefix back-to-back.

    After each admission the policy remembers the admitted request's
    adopted-page signature (the cached pages its prefix plan aliases);
    the next poll prefers a waiting request with the SAME signature, so
    a burst of shared-prefix requests admits consecutively while the
    trunk pages are warm (and before pool pressure could evict them)
    instead of interleaving with cold prompts in arrival order.

    Starvation-bounded: a skipped head accrues patience, and after
    ``patience`` consecutive skip-aheads the policy degrades to plain
    FIFO until the head admits.  The engine injects the signature lookup
    via ``bind`` (policies stay engine-agnostic); unbound, or with no
    prefix cache, this IS FIFO.  One request per dispatch; ordering only
    — which requests admit and what they compute is unchanged, so the
    bitwise serving contract is untouched.
    """

    def __init__(self, patience: int = 4):
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.patience = patience
        self._sig_of = None
        self._last_sig = None
        self._skips = 0

    def bind(self, sig_of) -> None:
        """``sig_of(req) -> hashable | None``: the request's adopted-page
        signature (None = cold)."""
        self._sig_of = sig_of

    def next_group(self, waiting, max_group, admit_ok, bucket_of):
        if not waiting:
            return []

        def admit(i):
            if not admit_ok(waiting[i]):
                return []
            self._last_sig = (self._sig_of(waiting[i])
                              if self._sig_of is not None else None)
            self._skips = 0 if i == 0 else self._skips + 1
            return [i]

        if (self._sig_of is None or self._last_sig is None
                or self._skips >= self.patience):
            return admit(0)
        for i in range(len(waiting)):
            if self._sig_of(waiting[i]) == self._last_sig:
                return admit(i)
        return admit(0)


class PriorityAdmission:
    """Highest effective priority first, starvation-free through aging.

    Each request carries a ``Request.priority`` (higher = sooner); its
    *effective* priority grows by one level every ``aging_steps`` scheduler
    polls it spends waiting, so a low-priority request can be delayed but
    never starved — eventually it outranks fresh high-priority arrivals.
    The chosen head is then head-of-line for the capacity gate exactly
    like FIFO: if the pool cannot reserve it, nothing skips past it (a
    skip-ahead would re-starve large requests, the failure FIFO's gate
    already guards against).  Ties break by queue order (FIFO within a
    priority level).  One request per dispatch.
    """

    def __init__(self, aging_steps: int = 8):
        if aging_steps < 1:
            raise ValueError("aging_steps must be >= 1")
        self.aging_steps = aging_steps
        self._poll = 0
        self._first_poll: dict[int, int] = {}

    def _effective(self, req: Request) -> int:
        waited = self._poll - self._first_poll[req.req_id]
        return req.priority + waited // self.aging_steps

    def next_group(self, waiting, max_group, admit_ok, bucket_of):
        if not waiting:
            return []
        self._poll += 1
        live = set()
        for r in waiting:
            self._first_poll.setdefault(r.req_id, self._poll)
            live.add(r.req_id)
        for rid in [r for r in self._first_poll if r not in live]:
            del self._first_poll[rid]
        head = min(range(len(waiting)),
                   key=lambda i: (-self._effective(waiting[i]), i))
        return [head] if admit_ok(waiting[head]) else []


class BudgetOrEOSEviction:
    """Evict when the request hits its token budget or emits EOS — the
    ``Request.done`` rule the engine always applied."""

    wants_step_sync = False

    def should_evict(self, req: Request) -> bool:
        return req.done

    def evict_reason(self, req: Request) -> str:
        """Why ``should_evict`` fired — recorded on the scheduler event
        log's ``finished`` event.  Custom eviction policies may expose the
        same hook (e.g. ``"slo_deadline"``); the engine falls back to the
        budget/EOS distinction when they don't."""
        if (req.eos_token is not None and req.output_tokens
                and req.output_tokens[-1] == req.eos_token):
            return "eos"
        return "length"


class DeadlinePreemption(BudgetOrEOSEviction):
    """SLO-aware eviction: preempt lanes that already missed their
    deadline when queued work can still hit its own.

    ``DeadlineAdmission`` sheds late requests at *ingress*; this is the
    eviction-side half (the carried ROADMAP follow-up).  A running
    request past its deadline can only produce dead (non-goodput) tokens
    — but evicting it is only a win when some waiting request could
    actually use the lane and still make its deadline (no-deadline
    requests always qualify).  With nothing eligible waiting, the doomed
    request keeps running: a late answer beats an idle lane.

    Preempted requests finish with reason ``"deadline"``, an
    ``evicted(reason="deadline")`` journal event, and a
    ``deadline_preempt`` counter bump.  The deadline check reads the
    engine's *decision clock* (``bind``), so preemptions are taped by the
    flight recorder and replay bitwise like every other decision.
    ``wants_step_sync=True``: the decision is re-evaluated on wall time
    every step, so pending tokens must reach the host every step.
    """

    wants_step_sync = True

    def __init__(self):
        self._clock = time.perf_counter
        self._waiting = lambda: ()

    def bind(self, clock, waiting) -> None:
        """Engine hook (``set_clock``): the decision clock and a live view
        of the waiting queue."""
        self._clock = clock
        self._waiting = waiting

    def should_evict(self, req: Request) -> bool:
        if req.done:
            return True
        if req.deadline_s is None:
            return False
        now = self._clock()
        if now - req.submit_time <= req.deadline_s:
            return False
        # already missed: preempt iff a waiting request can still hit
        for w in self._waiting():
            if (w.deadline_s is None
                    or now - w.submit_time <= w.deadline_s):
                return True
        return False

    def evict_reason(self, req: Request) -> str:
        if not req.done:
            return "deadline"
        return super().evict_reason(req)


class NeverDefrag:
    """Disable automatic compaction (the pre-policy behaviour)."""

    def should_defrag(self, manager) -> bool:
        return False


class ThresholdDefrag:
    """Compact when the pool's fragmentation ratio crosses ``threshold``.

    Fragmentation is ``1 - pages_in_use / span`` where ``span`` is the
    highest allocated physical page index: a freshly compacted pool (used
    set exactly ``[1, pages_in_use]``) scores 0.0, and holes left by
    evictions push the ratio toward 1.  ``min_pages`` avoids churning a
    nearly-empty pool where compaction buys nothing.  Both counts come
    from page refcounts, so prefix-tree-held pages (referenced by no lane)
    are neither skipped by compaction nor misread as holes.
    """

    def __init__(self, threshold: float = 0.5, min_pages: int = 2):
        if not 0.0 <= threshold < 1.0:
            raise ValueError("threshold must be in [0, 1)")
        self.threshold = threshold
        self.min_pages = min_pages

    def should_defrag(self, manager) -> bool:
        used = manager.pages_in_use
        if used < self.min_pages:
            return False
        span = manager.span
        if span <= 0:
            return False
        return (1.0 - used / span) > self.threshold


class SharedPrefix:
    """Default prefix policy: adopt any cached prefix of at least
    ``min_pages`` pages, publish every completed prefill.  A higher
    ``min_pages`` skips marginal one-page matches whose adoption
    bookkeeping outweighs the recompute they save."""

    def __init__(self, min_pages: int = 1):
        if min_pages < 1:
            raise ValueError("min_pages must be >= 1")
        self.min_pages = min_pages

    def plan(self, cache, req: Request):
        plan = cache.plan(req.prompt)
        if plan is not None and len(plan.pages) >= self.min_pages:
            return plan
        return None

    def should_publish(self, req: Request) -> bool:
        return True


class NoPrefixReuse:
    """Prefix subsystem present but inert: match nothing, publish nothing
    (e.g. to A/B the cache's overhead on a workload with no sharing)."""

    def plan(self, cache, req: Request):
        return None

    def should_publish(self, req: Request) -> bool:
        return False


@dataclasses.dataclass
class EnginePolicies:
    """The engine's pluggable decision points, with defaults reproducing
    (and, for defrag, completing) the historical behaviour.  ``prefix``
    only engages when the engine is built with ``prefix_cache=True``."""

    admission: AdmissionPolicy = dataclasses.field(default_factory=FIFOAdmission)
    eviction: EvictionPolicy = dataclasses.field(default_factory=BudgetOrEOSEviction)
    defrag: DefragPolicy = dataclasses.field(default_factory=ThresholdDefrag)
    prefix: PrefixPolicy = dataclasses.field(default_factory=SharedPrefix)
