"""Iteration-level FIFO scheduler (Orca-style continuous batching).

Each engine step asks ``schedule()`` which waiting requests to prefill into
free slots *this* iteration; everything already in a slot takes one batched
decode step.  Admission is FIFO and bounded by ``max_prefills_per_step`` so
a burst of arrivals cannot starve in-flight decodes (prefill is the
expensive phase; interleaving it one-or-few at a time keeps decode lanes
hot — the dataflow-utilization argument the SPOGA/SCONNA accelerators make
at the GEMM level, applied at the batch level).

Slots are handed out lowest-index-first purely for determinism: a given
workload always produces the same lane assignment, which the exact-match
serving tests rely on.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Optional

from repro.serving.request import Request, RequestState


class FIFOScheduler:
    def __init__(self, n_slots: int, max_prefills_per_step: int = 1):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_prefills_per_step = max(1, max_prefills_per_step)
        self.waiting: deque[Request] = deque()
        self._free: list[int] = list(range(n_slots))
        heapq.heapify(self._free)
        self.running: dict[int, Request] = {}

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.state is RequestState.WAITING
        self.waiting.append(req)

    # -- per-step decisions ------------------------------------------------
    def schedule(self) -> list[tuple[Request, int]]:
        """Admit up to ``max_prefills_per_step`` waiting requests into free
        slots. Returns (request, slot) pairs to prefill this iteration."""
        admitted = []
        while (self.waiting and self._free
               and len(admitted) < self.max_prefills_per_step):
            req = self.waiting.popleft()
            slot = heapq.heappop(self._free)
            req.state = RequestState.RUNNING
            req.slot = slot
            self.running[slot] = req
            admitted.append((req, slot))
        return admitted

    def release(self, slot: int) -> Request:
        """Evict the finished request in ``slot``; the lane is reusable."""
        req = self.running.pop(slot)
        req.state = RequestState.FINISHED
        req.slot = None
        heapq.heappush(self._free, slot)
        return req

    # -- introspection -----------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def request_in(self, slot: int) -> Optional[Request]:
        return self.running.get(slot)
