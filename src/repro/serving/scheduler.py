"""Iteration-level scheduler (Orca-style continuous batching).

Each engine step asks the scheduler which waiting requests to prefill into
free slots *this* iteration; everything already in a slot takes one batched
decode step.  WHICH requests admit — and whether several share one stacked
prefill dispatch — is delegated to an ``policies.AdmissionPolicy``; the
scheduler itself only owns the mechanical state (queue, slot pool, the
running / chunking maps).  The default policy is head-of-line FIFO bounded
by ``max_prefills_per_step`` so a burst of arrivals cannot starve in-flight
decodes (prefill is the expensive phase; interleaving it one-or-few at a
time keeps decode lanes hot — the dataflow-utilization argument the
SPOGA/SCONNA accelerators make at the GEMM level, applied at the batch
level).

Two extensions for the paged engine:

* ``admit_ok`` — a capacity gate the engine supplies in paged mode: the
  FIFO head only admits when the page pool can *reserve* its worst case.
  The gate is head-of-line on purpose — skipping ahead to smaller requests
  would starve large ones forever.
* chunked admissions — a long prompt occupies its slot in a ``chunking``
  state while the engine feeds it page-sized prefill chunks between decode
  steps (``begin_chunked`` / ``promote``).  Chunking lanes are excluded
  from the decode batch but still hold their slot and pages.

Slots are handed out lowest-index-first purely for determinism: a given
workload always produces the same lane assignment, which the exact-match
serving tests rely on.
"""

from __future__ import annotations

import heapq
import time
import warnings
from collections import deque
from typing import Callable, Optional

from repro.serving.policies import AdmissionPolicy, FIFOAdmission
from repro.serving.request import Request, RequestState


class Scheduler:
    def __init__(self, n_slots: int, max_prefills_per_step: int = 1,
                 admission: Optional[AdmissionPolicy] = None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_prefills_per_step = max(1, max_prefills_per_step)
        self.admission = admission if admission is not None else FIFOAdmission()
        self.waiting: deque[Request] = deque()
        self._free: list[int] = list(range(n_slots))
        heapq.heapify(self._free)
        self.running: dict[int, Request] = {}
        self.chunking: dict[int, Request] = {}
        # the decision clock: admission stamps/deadline checks read time
        # through here so the flight recorder can tape the readings and a
        # replay can script them back (engine.set_clock swaps it)
        self.clock: Callable[[], float] = time.perf_counter

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.state is RequestState.WAITING
        self.waiting.append(req)

    # -- per-step decisions ------------------------------------------------
    def schedule_group(self, admit_ok: Optional[Callable[[Request], bool]] = None,
                       bucket_of: Optional[Callable[[Request], int]] = None,
                       max_group: int = 1) -> list[tuple[Request, int]]:
        """Ask the admission policy for the next prefill *dispatch*: one or
        more waiting requests (same bucket when stacked) admitted into free
        slots together.  ``admit_ok`` is the capacity gate; ``bucket_of``
        maps a request to its padded prefill length.  Returns (request,
        slot) pairs, FIFO-ordered, lowest free slot first."""
        if not self.waiting or not self._free:
            return []
        idxs = self.admission.next_group(
            self.waiting, max(1, min(max_group, len(self._free))),
            admit_ok or (lambda r: True),
            bucket_of or (lambda r: r.prompt_len))
        if not idxs:
            return []
        idxs = sorted(set(idxs))
        reqs = [self.waiting[i] for i in idxs]
        for i in reversed(idxs):
            del self.waiting[i]
        out = []
        now = self.clock()
        for req in reqs:
            slot = heapq.heappop(self._free)
            req.state = RequestState.RUNNING
            req.slot = slot
            req.admit_time = now  # queue-wait metric: submit -> here
            if (req.deadline_s is not None
                    and now - req.submit_time > req.deadline_s):
                # SLO already blown in queue: the lane is spent on a
                # request that cannot count toward goodput
                req.late_at_admission = True
            self.running[slot] = req
            out.append((req, slot))
        return out

    def schedule(self, limit: Optional[int] = None,
                 admit_ok: Optional[Callable[[Request], bool]] = None
                 ) -> list[tuple[Request, int]]:
        """Legacy single-request admission loop: up to ``limit`` (default
        ``max_prefills_per_step``) FIFO heads into free slots, one per
        entry.  The engine now drives ``schedule_group``; this stays for
        callers and tests of the pre-policy surface."""
        limit = self.max_prefills_per_step if limit is None else limit
        admitted: list[tuple[Request, int]] = []
        while len(admitted) < limit:
            group = self.schedule_group(admit_ok=admit_ok, max_group=1)
            if not group:
                break
            admitted.extend(group)
        return admitted

    def drop(self, idxs: list[int]) -> list[Request]:
        """Remove waiting requests by index (deadline shedding): they
        finish without ever holding a slot.  Returns the dropped requests
        in queue order; the engine stamps reason/metrics/events."""
        idxs = sorted(set(idxs))
        dropped = [self.waiting[i] for i in idxs]
        for i in reversed(idxs):
            del self.waiting[i]
        for req in dropped:
            req.state = RequestState.FINISHED
        return dropped

    def begin_chunked(self, slot: int) -> Request:
        """Move a just-admitted request into the chunked-prefill state."""
        req = self.running.pop(slot)
        req.state = RequestState.PREFILLING
        self.chunking[slot] = req
        return req

    def promote(self, slot: int) -> Request:
        """Final chunk done: the lane joins the decode batch."""
        req = self.chunking.pop(slot)
        req.state = RequestState.RUNNING
        self.running[slot] = req
        return req

    def release(self, slot: int) -> Request:
        """Evict the finished request in ``slot``; the lane is reusable."""
        req = self.running.pop(slot)
        req.state = RequestState.FINISHED
        req.slot = None
        heapq.heappush(self._free, slot)
        return req

    # -- introspection -----------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.chunking)

    def request_in(self, slot: int) -> Optional[Request]:
        return self.running.get(slot) or self.chunking.get(slot)


class FIFOScheduler(Scheduler):
    """Deprecated name for ``Scheduler`` with the default FIFO admission
    policy — kept so pre-``repro.api`` callers keep working unchanged."""

    def __init__(self, n_slots: int, max_prefills_per_step: int = 1):
        warnings.warn(
            "FIFOScheduler is deprecated; use Scheduler (optionally with an "
            "explicit policies.AdmissionPolicy)", DeprecationWarning,
            stacklevel=2)
        super().__init__(n_slots, max_prefills_per_step,
                         admission=FIFOAdmission())
