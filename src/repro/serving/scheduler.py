"""Iteration-level FIFO scheduler (Orca-style continuous batching).

Each engine step asks ``schedule()`` which waiting requests to prefill into
free slots *this* iteration; everything already in a slot takes one batched
decode step.  Admission is FIFO and bounded by ``max_prefills_per_step`` so
a burst of arrivals cannot starve in-flight decodes (prefill is the
expensive phase; interleaving it one-or-few at a time keeps decode lanes
hot — the dataflow-utilization argument the SPOGA/SCONNA accelerators make
at the GEMM level, applied at the batch level).

Two extensions for the paged engine:

* ``admit_ok`` — a capacity gate the engine supplies in paged mode: the
  FIFO head only admits when the page pool can *reserve* its worst case.
  The gate is head-of-line on purpose — skipping ahead to smaller requests
  would starve large ones forever.
* chunked admissions — a long prompt occupies its slot in a ``chunking``
  state while the engine feeds it page-sized prefill chunks between decode
  steps (``begin_chunked`` / ``promote``).  Chunking lanes are excluded
  from the decode batch but still hold their slot and pages.

Slots are handed out lowest-index-first purely for determinism: a given
workload always produces the same lane assignment, which the exact-match
serving tests rely on.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Callable, Optional

from repro.serving.request import Request, RequestState


class FIFOScheduler:
    def __init__(self, n_slots: int, max_prefills_per_step: int = 1):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_prefills_per_step = max(1, max_prefills_per_step)
        self.waiting: deque[Request] = deque()
        self._free: list[int] = list(range(n_slots))
        heapq.heapify(self._free)
        self.running: dict[int, Request] = {}
        self.chunking: dict[int, Request] = {}

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.state is RequestState.WAITING
        self.waiting.append(req)

    # -- per-step decisions ------------------------------------------------
    def schedule(self, limit: Optional[int] = None,
                 admit_ok: Optional[Callable[[Request], bool]] = None
                 ) -> list[tuple[Request, int]]:
        """Admit up to ``limit`` (default ``max_prefills_per_step``) waiting
        requests into free slots. Returns (request, slot) pairs to prefill
        this iteration. ``admit_ok`` vetoes the FIFO head (capacity gate);
        a vetoed head stays queued and blocks later arrivals."""
        limit = self.max_prefills_per_step if limit is None else limit
        admitted = []
        while self.waiting and self._free and len(admitted) < limit:
            req = self.waiting[0]
            if admit_ok is not None and not admit_ok(req):
                break
            self.waiting.popleft()
            slot = heapq.heappop(self._free)
            req.state = RequestState.RUNNING
            req.slot = slot
            self.running[slot] = req
            admitted.append((req, slot))
        return admitted

    def begin_chunked(self, slot: int) -> Request:
        """Move a just-admitted request into the chunked-prefill state."""
        req = self.running.pop(slot)
        req.state = RequestState.PREFILLING
        self.chunking[slot] = req
        return req

    def promote(self, slot: int) -> Request:
        """Final chunk done: the lane joins the decode batch."""
        req = self.chunking.pop(slot)
        req.state = RequestState.RUNNING
        self.running[slot] = req
        return req

    def release(self, slot: int) -> Request:
        """Evict the finished request in ``slot``; the lane is reusable."""
        req = self.running.pop(slot)
        req.state = RequestState.FINISHED
        req.slot = None
        heapq.heappush(self._free, slot)
        return req

    # -- introspection -----------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.chunking)

    def request_in(self, slot: int) -> Optional[Request]:
        return self.running.get(slot) or self.chunking.get(slot)
