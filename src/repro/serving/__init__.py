"""Continuous-batching serving engine (slot- or paged-KV cache, interleaved
prefill/decode, chunked long-prompt admission, per-lane sampling, pluggable
admission/eviction/defrag policies).  See ``engine.ServingEngine``,
``policies`` and ``repro.paging``; the high-level entry point is the
``repro.api`` facade."""

from repro.paging import PagedCache, PageManager
from repro.prefix import PrefixCache, PrefixTree
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import EngineMetrics
from repro.serving.policies import (
    AdmissionPolicy,
    BucketBatchedAdmission,
    BudgetOrEOSEviction,
    DeadlinePreemption,
    DefragPolicy,
    EnginePolicies,
    EvictionPolicy,
    FIFOAdmission,
    NeverDefrag,
    NoPrefixReuse,
    PrefixAwareAdmission,
    PrefixPolicy,
    PriorityAdmission,
    SharedPrefix,
    ThresholdDefrag,
)
from repro.serving.request import Request, RequestState, default_detokenizer
from repro.serving.sampling import SamplingParams, request_key, sample_tokens
from repro.serving.scheduler import FIFOScheduler, Scheduler
from repro.serving.slots import SlotCache

__all__ = [
    "AdmissionPolicy",
    "BucketBatchedAdmission",
    "BudgetOrEOSEviction",
    "DeadlinePreemption",
    "DefragPolicy",
    "EngineConfig",
    "EngineMetrics",
    "EnginePolicies",
    "EvictionPolicy",
    "FIFOAdmission",
    "FIFOScheduler",
    "NeverDefrag",
    "NoPrefixReuse",
    "PageManager",
    "PagedCache",
    "PrefixAwareAdmission",
    "PrefixCache",
    "PrefixPolicy",
    "PrefixTree",
    "PriorityAdmission",
    "Request",
    "RequestState",
    "SamplingParams",
    "Scheduler",
    "ServingEngine",
    "SharedPrefix",
    "SlotCache",
    "ThresholdDefrag",
    "default_detokenizer",
    "request_key",
    "sample_tokens",
]
