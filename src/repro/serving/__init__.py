"""Continuous-batching serving engine (slot- or paged-KV cache, interleaved
prefill/decode, chunked long-prompt admission, per-lane sampling).
See ``engine.ServingEngine`` and ``repro.paging``."""

from repro.paging import PagedCache, PageManager
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import EngineMetrics
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, request_key, sample_tokens
from repro.serving.scheduler import FIFOScheduler
from repro.serving.slots import SlotCache

__all__ = [
    "EngineConfig",
    "EngineMetrics",
    "FIFOScheduler",
    "PageManager",
    "PagedCache",
    "Request",
    "RequestState",
    "SamplingParams",
    "ServingEngine",
    "SlotCache",
    "request_key",
    "sample_tokens",
]
