"""Continuous-batching serving engine (slot-based KV cache, interleaved
prefill/decode, per-lane sampling).  See ``engine.ServingEngine``."""

from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.metrics import EngineMetrics
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, request_key, sample_tokens
from repro.serving.scheduler import FIFOScheduler
from repro.serving.slots import SlotCache

__all__ = [
    "EngineConfig",
    "EngineMetrics",
    "FIFOScheduler",
    "Request",
    "RequestState",
    "SamplingParams",
    "ServingEngine",
    "SlotCache",
    "request_key",
    "sample_tokens",
]
