"""Token sampling for the serving engine: greedy, temperature, top-k.

The engine decodes all slots in one jitted call, but each slot may carry a
different sampling policy, so sampling is vectorized over per-slot parameter
arrays (temperature / top_k / greedy mask) rather than dispatching per
request in Python.  ``top_k <= 0`` disables the top-k filter for that lane;
``greedy`` lanes ignore the randomness entirely, so a greedy request's
tokens are bit-identical whether or not stochastic neighbours share the
batch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

_TEMP_EPS = 1e-4


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling policy. Defaults to deterministic greedy."""

    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0          # <= 0: no top-k truncation
    seed: int = 0           # folded into the engine key per request
    # SLO deadline: seconds from submit to finish.  Purely an accounting
    # annotation (rides SamplingParams because that is the per-request
    # options object every arrival tuple already carries): the scheduler
    # stamps hit/miss at finish and only deadline-respecting requests
    # count toward goodput.  None = no deadline (always counts).
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if not self.greedy and self.temperature <= 0:
            raise ValueError("temperature must be > 0 for stochastic sampling "
                             "(use greedy=True for argmax decoding)")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be positive (None = no SLO)")


def request_key(seed: int, req_id: int, token_index: int):
    """Per-(request, position) PRNG key.  Depends only on the request's own
    seed/id and how many tokens it has produced — never on which other
    requests share the batch — so stochastic outputs are reproducible under
    any continuous-batching interleaving."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), req_id)
    return jax.random.fold_in(base, token_index)


def sample_tokens(logits, temperature, top_k, greedy, keys):
    """Sample one token per lane. All inputs batched over lanes.

    logits: (B, V) f32/bf16; temperature: (B,) f32; top_k: (B,) int32
    (<= 0 disables); greedy: (B,) bool; keys: (B, 2) uint32 — one PRNG key
    per lane (see ``request_key``; ignored for greedy lanes).
    Returns (B,) int32.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits / jnp.maximum(temperature, _TEMP_EPS)[:, None]
    # per-lane top-k with lane-varying k: threshold at the k-th largest value
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k_idx = jnp.clip(top_k - 1, 0, v - 1)
    kth = jnp.take_along_axis(sorted_desc, k_idx[:, None], axis=1)
    keep = (top_k[:, None] <= 0) | (scaled >= kth)
    masked = jnp.where(keep, scaled, -jnp.inf)

    sampled = jax.vmap(lambda k, lg: jax.random.categorical(k, lg))(keys, masked)
    return jnp.where(greedy, greedy_tok, sampled.astype(jnp.int32))
