"""``repro.api`` — the public facade over the whole serving stack.

One import gives the vLLM-style surface; everything underneath stays
reachable for power users but is no longer required wiring:

Map of the package
==================

``llm.LLM``
    The single entrypoint.  ``LLM(arch="llama3.2-1b",
    runtime=RuntimeConfig(...))`` owns param init / checkpoint restore,
    resolves the runtime config, builds engine + policies, and exposes
    ``.generate(prompts, SamplingParams) -> list[RequestOutput]``,
    ``.stream(prompt, detokenize=...)`` and (advanced) ``.engine``.

``config.RuntimeConfig``
    The one layered runtime surface, subsuming the knobs previously
    smeared across ``ModelConfig`` / ``EngineConfig`` / CLI flags:

    * ``QuantRuntime``     — quant mode + GEMM backend registry name
    * ``KVConfig``         — slot vs paged, KV dtype, page geometry
    * ``SchedulerConfig``  — slots, buckets, chunking, batched admission,
                             defrag threshold
    * ``SamplingDefaults`` — default per-request sampling policy
    * ``SpecConfig``       — speculative decoding (draft-verify greedy
                             decode; ``repro/spec/``)
    * ``ObsConfig``        — observability (``repro/obs/``): span tracing
                             (Chrome trace JSON), scheduler event log,
                             jax.profiler windows, invariant checking

    Frozen + validated; ``to_dict``/``from_dict`` round-trip; one
    ``resolve(cfg)`` step derives the legacy ``ModelConfig`` overrides and
    ``EngineConfig`` (jit-hashing behaviour unchanged);
    ``build_policies()`` yields the ``serving.EnginePolicies``.

``outputs.RequestOutput``
    Finished-generation record: prompt/output token ids, optional decoded
    text, finish reason, TTFT / latency.

``baseline.serve_batch``
    The static lockstep reference the engine is exactness-tested against
    (and the benchmark baseline); also serves enc-dec / frontend stacks.

Quickstart
==========

    from repro.api import LLM, RuntimeConfig, KVConfig, SamplingParams

    llm = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(reduced=True))
    out, = llm.generate([1, 2, 3, 4], max_new_tokens=8)
    print(out.token_ids, out.finish_reason)

    paged = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(
        reduced=True, kv=KVConfig(mode="paged", dtype="int8")))
    for piece in paged.stream([1, 2, 3, 4], detokenize=True):
        print(piece, end="")

See ``examples/api_quickstart.py`` for the runnable version.
"""

from repro.api.baseline import serve_batch
from repro.api.config import (
    KVConfig,
    MeshConfig,
    QuantRuntime,
    RuntimeConfig,
    SamplingDefaults,
    SchedulerConfig,
    auto_buckets,
    get_preset,
    list_presets,
    load_runtime,
    register_preset,
)
from repro.api.llm import LLM
from repro.api.outputs import RequestOutput
from repro.obs import ObsConfig, Observability
from repro.serving.policies import (
    AdmissionPolicy,
    BucketBatchedAdmission,
    BudgetOrEOSEviction,
    DeadlinePreemption,
    DeadlineAdmission,
    DefragPolicy,
    EnginePolicies,
    EvictionPolicy,
    FIFOAdmission,
    NeverDefrag,
    PrefixAwareAdmission,
    PrefixPolicy,
    PriorityAdmission,
    NoPrefixReuse,
    SharedPrefix,
    ThresholdDefrag,
)
from repro.serving.sampling import SamplingParams
from repro.spec.config import SpecConfig

__all__ = [
    "AdmissionPolicy",
    "BucketBatchedAdmission",
    "BudgetOrEOSEviction",
    "DeadlinePreemption",
    "DeadlineAdmission",
    "DefragPolicy",
    "EnginePolicies",
    "EvictionPolicy",
    "FIFOAdmission",
    "KVConfig",
    "LLM",
    "MeshConfig",
    "NeverDefrag",
    "NoPrefixReuse",
    "ObsConfig",
    "Observability",
    "PrefixAwareAdmission",
    "PrefixPolicy",
    "PriorityAdmission",
    "QuantRuntime",
    "RequestOutput",
    "RuntimeConfig",
    "SamplingDefaults",
    "SamplingParams",
    "SchedulerConfig",
    "SharedPrefix",
    "SpecConfig",
    "ThresholdDefrag",
    "auto_buckets",
    "get_preset",
    "list_presets",
    "load_runtime",
    "register_preset",
    "serve_batch",
]
