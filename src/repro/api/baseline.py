"""Static-batch lockstep baseline (moved here from ``launch/serve.py`` so
the CLI and benchmarks consume everything through ``repro.api``).

``serve_batch`` prefills a whole rectangular batch together and decodes
``gen_tokens`` greedy steps in lockstep.  It is kept for two reasons: it is
the reference implementation the continuous-batching engine is exactness-
tested against, and it is the baseline ``benchmarks/serve_bench.py`` beats.
It also remains the serving path for encoder-decoder / frontend stacks the
engine does not admit.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.launch.steps import make_prefill_step, make_serve_step


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg, cache_len: int):
    """jit wrappers keyed by (cfg, cache_len) — ``make_*_step`` returns a new
    closure per call, so without this every ``serve_batch`` call recompiles."""
    return (jax.jit(make_prefill_step(cfg, cache_len)),
            jax.jit(make_serve_step(cfg), donate_argnums=(2,)))


def serve_batch(cfg, params, batch, *, cache_len: int, gen_tokens: int):
    """Static-batch lockstep baseline: every sequence prefills together and
    decodes ``gen_tokens`` steps together (greedy). Returns (B, gen)."""
    prefill_fn, step_fn = _jitted_steps(cfg, cache_len)
    t0 = time.time()
    logits, cache = prefill_fn(params, batch)
    prefill_s = time.time() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen_tokens - 1):
        logits, cache = step_fn(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    return jnp.stack(out, axis=1), {"prefill_s": prefill_s, "decode_s": decode_s}
