"""The ``LLM`` facade: one entrypoint over configs, params, checkpointing
and the continuous-batching engine.

    from repro.api import LLM, RuntimeConfig, KVConfig

    llm = LLM(arch="llama3.2-1b",
              runtime=RuntimeConfig(reduced=True, kv=KVConfig(mode="paged")))
    outs = llm.generate([[1, 2, 3], [4, 5]], max_new_tokens=8)
    for piece in llm.stream([1, 2, 3], detokenize=True):
        print(piece, end="")

``LLM`` owns parameter init (or checkpoint restore), resolves the layered
``RuntimeConfig`` into the legacy ``ModelConfig`` overrides + engine
config, builds the engine policies, and drives the engine for you.  The
engine is built lazily: when ``kv.cache_len`` is unset, the first
``generate``/``stream`` call sizes the cache from its own workload (the
shared ``default_cache_len`` policy) and later, larger workloads rebuild
the engine between calls (jit caches are keyed by (config, cache_len), so
rebuilds reuse compiled traces).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Sequence, Union

import jax
import numpy as np

from repro.api.config import RuntimeConfig
from repro.api.outputs import RequestOutput
from repro.configs import get_config, reduced as reduce_config
from repro.models import init_params
from repro.serving.engine import ServingEngine
from repro.serving.policies import EnginePolicies
from repro.serving.request import RequestState, default_detokenizer
from repro.serving.sampling import SamplingParams
from repro.shard import build_mesh, shard_params

Prompt = Sequence[int]


class LLM:
    """One generation endpoint: ``LLM(arch=...)`` then ``.generate`` /
    ``.stream``.  Advanced callers reach the underlying ``ServingEngine``
    via ``.engine`` (e.g. for staggered-arrival workloads)."""

    def __init__(self, arch: Optional[str] = None, *,
                 runtime: Optional[RuntimeConfig] = None,
                 config=None, params=None,
                 tokenizer: Optional[Callable[[Sequence[int]], str]] = None,
                 checkpoint_dir: Optional[str] = None,
                 policies: Optional[EnginePolicies] = None,
                 seed: int = 0):
        if (arch is None) == (config is None):
            raise ValueError("pass exactly one of arch= (registry name) or "
                             "config= (a ModelConfig)")
        self.runtime = runtime if runtime is not None else RuntimeConfig()
        base = get_config(arch) if config is None else config
        if self.runtime.reduced:
            base = reduce_config(base)
        # the single resolution step (model side): RuntimeConfig owns the
        # runtime knobs; the result is the plain frozen ModelConfig jit keys on
        self.config = self.runtime.resolve_model(base)
        if params is not None:
            self.params = params
        else:
            self.params = init_params(self.config, jax.random.PRNGKey(seed))
        if checkpoint_dir is not None:
            from repro.checkpoint.checkpoint import restore_checkpoint

            self.params = restore_checkpoint(checkpoint_dir, None, self.params)
        # sharded serving (repro/shard/): resolve the per-arch Megatron
        # PartitionSpecs into NamedShardings and commit the weights once,
        # here — every engine dispatch then sees the TP layout as a stable
        # input constraint.  mesh=None (the default config) changes nothing.
        self.mesh = build_mesh(self.runtime.mesh)
        if self.mesh is not None:
            self.params = shard_params(self.params, self.mesh, self.config)
        self.tokenizer = tokenizer or default_detokenizer
        self._policies = (policies if policies is not None
                          else self.runtime.build_policies())
        # one observability bundle per LLM: it outlives engine rebuilds
        # (spans/events accumulate across them, like metrics), and
        # ``llm.obs.save()`` writes the configured trace/event sinks
        self.obs = self.runtime.obs.build()
        if self.obs.recorder is not None:
            # flight recorder armed: stamp everything replay needs to
            # rebuild this model (repro/obs/recorder.py bundle manifest)
            self.obs.recorder.set_run_info(
                arch=arch, runtime=self.runtime, seed=seed,
                checkpoint_dir=checkpoint_dir)
        self._engine: Optional[ServingEngine] = None
        # live telemetry frontend: a stdlib HTTP server polling the engine's
        # registry (plus the numerics watchdog's, when armed) on each
        # scrape.  Pure pull — nothing on the dispatch path knows about it.
        self.metrics_server = None
        if self.runtime.obs.metrics_port is not None:
            from repro.obs.server import MetricsServer

            self.metrics_server = MetricsServer(
                self._collect_metrics,
                port=self.runtime.obs.metrics_port,
                events=lambda: self.obs.events).start()

    def _collect_metrics(self):
        """Scrape-time collector: registries + cheap derived gauges.
        Derived values read host-side counters only (no ``report()``, no
        device sync), so a scrape never perturbs the run."""
        from repro.obs import watchdog as _watchdog

        regs, derived = [], {}
        m = self._engine.metrics if self._engine is not None else None
        if m is not None:
            regs.append(m.registry)
            wall = m.wall_s
            toks = m.generated_tokens
            derived["wall_seconds"] = wall
            derived["generated_tokens"] = float(toks)
            derived["tokens_per_second"] = toks / max(wall, 1e-9)
            derived["goodput_tokens_per_second"] = (
                m.goodput_tokens / max(wall, 1e-9))
            derived["requests_finished"] = float(len(m.finished))
        wreg = _watchdog.peek_registry()
        if wreg is not None:
            regs.append(wreg)
        return regs, derived

    def close(self) -> None:
        """Stop the metrics server (if any) and close event/trace sinks.
        Idempotent; the LLM stays usable for generate/stream afterwards
        minus the closed sinks."""
        if self.metrics_server is not None:
            self.metrics_server.close()
            self.metrics_server = None
        self.obs.close()

    @staticmethod
    def replay(bundle_path: str, runtime_transform=None,
               max_steps: int = 100_000):
        """Replay a flight-recorder bundle (``ObsConfig.record_path`` /
        ``serve --record DIR``): rebuild the recorded engine, re-feed the
        recorded arrivals on their step schedule, and compare token
        streams + decision journal bitwise.  Returns a
        ``repro.obs.replay.ReplayResult``; ``runtime_transform`` perturbs
        the rebuilt ``RuntimeConfig`` on purpose so the divergence differ
        can name the first decision that changes."""
        from repro.obs.replay import replay_bundle

        return replay_bundle(bundle_path, runtime_transform=runtime_transform,
                             max_steps=max_steps)

    # -- engine lifecycle --------------------------------------------------
    def _ensure_engine(self, prompt_len: int, gen_tokens: int) -> ServingEngine:
        need = prompt_len + gen_tokens
        if self._engine is not None:
            if (need <= self._engine.engine_cfg.cache_len + 1
                    or self.runtime.kv.cache_len is not None):
                # fits — or the user pinned cache_len, in which case
                # add_request raises its own sizing error
                return self._engine
            if self._engine.has_work:
                raise RuntimeError(
                    "cannot grow the KV cache while requests are in flight; "
                    "drain the engine first or set kv.cache_len up front")
        ecfg = self.runtime.resolve_engine(self.config, prompt_len, gen_tokens)
        old = self._engine
        if old is not None:
            # grow monotonically so earlier workloads keep fitting
            ecfg = dataclasses.replace(
                ecfg, cache_len=max(ecfg.cache_len, old.engine_cfg.cache_len))
        self._engine = ServingEngine(self.config, self.params, ecfg,
                                     policies=self._policies, obs=self.obs,
                                     mesh=self.mesh)
        if old is not None:
            # metrics accumulate across rebuilds: carry the old object over
            # (held references stay live) with the new pool geometry stamped
            carried = old.metrics
            carried.set_gauge("pages_total", self._engine.metrics.pages_total)
            carried.set_gauge("page_size", self._engine.metrics.page_size)
            self._engine.metrics = carried
        return self._engine

    def build_engine(self, prompt_len: int, gen_tokens: int) -> ServingEngine:
        """Build (or reuse) the engine for a nominal workload — the hints
        size the cache when ``kv.cache_len`` is unset and anchor the
        'auto' prefill-bucket ladder to real prompt lengths.  This is what
        ``generate``/``stream`` call internally; use it directly when
        driving ``engine.run`` / ``engine.step`` yourself."""
        return self._ensure_engine(prompt_len, gen_tokens)

    @property
    def engine(self) -> ServingEngine:
        """The underlying engine (built on demand; requires ``kv.cache_len``
        to be set when no generate/stream/build_engine call has sized it
        yet — and with 'auto' buckets, prefer ``build_engine`` so the
        ladder anchors to the workload's prompt length, not cache_len)."""
        if self._engine is None:
            if self.runtime.kv.cache_len is None:
                raise RuntimeError(
                    "engine not built yet: set RuntimeConfig.kv.cache_len, "
                    "call build_engine(prompt_len, gen_tokens), or issue a "
                    "generate()/stream() call to size it from the workload")
            self._ensure_engine(0, 1)
        return self._engine

    @property
    def metrics(self):
        return self._engine.metrics if self._engine is not None else None

    # -- sampling plumbing -------------------------------------------------
    def _sampling_for(self, n: int, sampling) -> list[SamplingParams]:
        if sampling is None:
            return [self.runtime.sampling.to_params()] * n
        if isinstance(sampling, SamplingParams):
            return [sampling] * n
        sampling = list(sampling)
        if len(sampling) != n:
            raise ValueError(f"got {len(sampling)} SamplingParams for {n} prompts")
        return sampling

    # -- the public calls --------------------------------------------------
    def generate(self, prompts: Union[Prompt, Sequence[Prompt]],
                 sampling: Union[SamplingParams, Sequence[SamplingParams], None] = None,
                 max_new_tokens: Optional[int] = None,
                 detokenize: bool = False) -> list[RequestOutput]:
        """Generate for one prompt (flat token-id list) or many.  Returns
        ``RequestOutput``s in prompt order; scheduling is output-invisible,
        so each entry's greedy tokens equal a solo decode of that prompt."""
        prompts = list(prompts)
        if prompts and isinstance(prompts[0], (int, np.integer)):
            prompts = [prompts]
        if not prompts:
            return []
        gen = max_new_tokens if max_new_tokens is not None else self.runtime.max_new_tokens
        per_req = self._sampling_for(len(prompts), sampling)
        engine = self._ensure_engine(max(len(p) for p in prompts), gen)
        reqs = [engine.add_request(p, gen, sampling=s,
                                   detokenizer=self.tokenizer)
                for p, s in zip(prompts, per_req)]
        while engine.has_work:
            engine.step()
        detok = self.tokenizer if detokenize else None
        # with observability on, each output carries its scheduler timeline
        # (queued -> admitted -> chunks -> first_token -> finished events)
        return [RequestOutput.from_request(
            r, detok, timeline=self.obs.events.timeline(r.req_id) or None)
            for r in reqs]

    def stream(self, prompt: Prompt,
               sampling: Optional[SamplingParams] = None,
               max_new_tokens: Optional[int] = None,
               eos_token: Optional[int] = None,
               detokenize: bool = False) -> Iterator[Union[int, str]]:
        """Submit one request and yield its output as the engine produces
        it — token ids by default, detokenized text fragments with
        ``detokenize=True`` (the ``Request.on_text`` hook; fragments
        concatenate to the full decode).  Other queued requests advance
        normally between yields."""
        gen = max_new_tokens if max_new_tokens is not None else self.runtime.max_new_tokens
        engine = self._ensure_engine(len(prompt), gen)
        emitted: list = []
        hook = ({"on_text": emitted.append, "detokenizer": self.tokenizer}
                if detokenize else {"on_token": emitted.append})
        req = engine.add_request(prompt, gen,
                                 sampling=self._sampling_for(1, sampling)[0],
                                 eos_token=eos_token, **hook)
        i = 0
        while True:
            while i < len(emitted):
                yield emitted[i]
                i += 1
            if req.state is RequestState.FINISHED or not engine.has_work:
                break
            engine.step()
        yield from emitted[i:]
