"""Layered runtime configuration for the ``repro.api`` facade.

Before this module the runtime knobs were smeared across three surfaces:
``ModelConfig`` carried execution overrides (``quant_mode``,
``gemm_backend``, ``kv_cache_dtype``, ``paged_attn_impl``),
``serving.EngineConfig`` carried pool shape/policy
(``cache_mode``/``page_size``/``n_pages``/``prefill_chunk``/buckets), and
the CLIs re-spelled both as flags.  ``RuntimeConfig`` subsumes all of them
into four explicit, frozen sub-configs:

* ``QuantRuntime``     — GEMM execution: quant mode + backend registry name.
* ``KVConfig``         — KV cache: slot vs paged, dtype (bf16 / byte-size
                         int8), page geometry, paged-attention impl.
* ``SchedulerConfig``  — admission: slots, buckets, chunking, stacked
                         (batched) prefill admission, defrag threshold.
* ``SamplingDefaults`` — the default per-request sampling policy.

``resolve()`` is the single resolution step: it derives the legacy
``ModelConfig`` overrides (via ``ModelConfig.with_``, so the model config
stays the one frozen, hashable object jit keys on — jit-hashing behaviour
is unchanged) plus the ``EngineConfig`` the engine consumes.
``to_dict``/``from_dict`` round-trip through plain JSON-serializable dicts.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Optional, Tuple, Union

from repro.backends.spec import QUANT_MODES, parse_quant_mode
from repro.configs.base import (
    DEFAULT_PAGE_SIZE,
    ModelConfig,
    default_cache_len,
)
from repro.obs.config import ObsConfig
from repro.serving.engine import RECURRENT_KINDS, EngineConfig
from repro.serving.policies import (
    BucketBatchedAdmission,
    BudgetOrEOSEviction,
    DeadlineAdmission,
    DeadlinePreemption,
    EnginePolicies,
    FIFOAdmission,
    NeverDefrag,
    PrefixAwareAdmission,
    PriorityAdmission,
    SharedPrefix,
    ThresholdDefrag,
)
from repro.serving.sampling import SamplingParams
from repro.spec.config import SpecConfig

_PAGED_ATTN_IMPLS = (None, "jnp", "pallas", "pallas_interpret")


@dataclasses.dataclass(frozen=True)
class QuantRuntime:
    """GEMM execution mode (the paper's byte-size integer pipelines)."""

    # "bf16" | "int8_spoga" | parametric "w<bits>a<bits>[_s<slices>]"
    mode: str = "bf16"
    # GEMM backend registry name (None = auto-select by platform/family)
    gemm_backend: Optional[str] = None

    def __post_init__(self):
        if self.mode not in QUANT_MODES:
            try:
                parse_quant_mode(self.mode)
            except ValueError:
                raise ValueError(
                    f"QuantRuntime.mode must be in {QUANT_MODES} or a "
                    f"parametric 'w<bits>a<bits>[_s<slice>]' string, got "
                    f"{self.mode!r}") from None
        if self.gemm_backend is not None:
            from repro.backends import get_backend, list_backends

            try:
                get_backend(self.gemm_backend)
            except KeyError:
                raise ValueError(
                    f"unknown gemm_backend {self.gemm_backend!r}; known: "
                    f"{list_backends()}") from None


@dataclasses.dataclass(frozen=True)
class KVConfig:
    """KV-cache storage: slot vs paged pool, dtype, page geometry."""

    mode: str = "slot"               # "slot" | "paged"
    dtype: str = "bf16"              # "bf16" | "int8" (byte-size + scales)
    # total rows per lane; None = derive from the workload at resolution
    # time (default_cache_len(prompt_len, gen_tokens))
    cache_len: Optional[int] = None
    page_size: int = DEFAULT_PAGE_SIZE
    # pool size in pages; None = the slot-equivalent KV budget
    n_pages: Optional[int] = None
    # paged-attention impl: None (auto) | "jnp" | "pallas" | "pallas_interpret"
    paged_attn_impl: Optional[str] = None
    # shared-prefix KV cache (repro/prefix/): admissions alias the longest
    # page-aligned cached prefix and prefill only the uncached suffix.
    # Paged mode only; needs a chunkable (attn/MLA/dense) stack.
    prefix_cache: bool = False
    # skip matches shorter than this many pages (1 = adopt any full page)
    prefix_min_pages: int = 1

    def __post_init__(self):
        if self.mode not in ("slot", "paged"):
            raise ValueError(f"KVConfig.mode must be 'slot' or 'paged', got "
                             f"{self.mode!r}")
        if self.dtype not in ("bf16", "int8"):
            raise ValueError(f"KVConfig.dtype must be 'bf16' or 'int8', got "
                             f"{self.dtype!r}")
        if self.cache_len is not None and self.cache_len < 1:
            raise ValueError("KVConfig.cache_len must be >= 1")
        if self.page_size < 1:
            raise ValueError("KVConfig.page_size must be >= 1")
        if self.n_pages is not None:
            if self.mode != "paged":
                raise ValueError("KVConfig.n_pages requires mode='paged'")
            if self.n_pages < 2:
                raise ValueError("KVConfig.n_pages must be >= 2 "
                                 "(page 0 is the trash page)")
        if self.paged_attn_impl not in _PAGED_ATTN_IMPLS:
            raise ValueError(
                f"KVConfig.paged_attn_impl must be one of {_PAGED_ATTN_IMPLS}, "
                f"got {self.paged_attn_impl!r}")
        if self.prefix_cache and self.mode != "paged":
            raise ValueError("KVConfig.prefix_cache requires mode='paged' "
                             "(shared pages live in the page pool)")
        if self.prefix_min_pages < 1:
            raise ValueError("KVConfig.prefix_min_pages must be >= 1")


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Device mesh for sharded serving (``repro/shard/``).

    ``tp`` shards attention heads / MoE experts / GEMM operands over the
    "model" axis; ``dp`` is reserved for data-parallel engine replicas
    (currently size 1 in serving).  ``enable=True`` at ``tp=1`` builds a
    genuine 1x1 mesh — the bitwise-vs-unsharded test configuration; the
    default ``enable=None`` activates the mesh iff an axis exceeds 1.
    Axis names must stay ``("data", "model")`` to match the sharding
    rules in ``runtime/sharding.py``; they are configurable only so the
    JSON form is explicit about what the mesh means.
    """

    tp: int = 1
    dp: int = 1
    enable: Optional[bool] = None
    axes: Tuple[str, str] = ("data", "model")

    def __post_init__(self):
        if self.tp < 1:
            raise ValueError("MeshConfig.tp must be >= 1")
        if self.dp < 1:
            raise ValueError("MeshConfig.dp must be >= 1")
        object.__setattr__(self, "axes", tuple(str(a) for a in self.axes))
        if len(self.axes) != 2 or len(set(self.axes)) != 2:
            raise ValueError(f"MeshConfig.axes must be two distinct axis "
                             f"names, got {self.axes!r}")

    @property
    def enabled(self) -> bool:
        return self.enable if self.enable is not None else (
            self.tp > 1 or self.dp > 1)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission / scheduling: lanes, buckets, chunking, engine policies."""

    n_slots: int = 4
    max_prefills_per_step: int = 1
    # None = exact-length prefill; "auto" = power-of-two buckets derived at
    # resolution time (dropped for recurrent stacks, whose state integrates
    # padding); a tuple = explicit bucket lengths
    prefill_buckets: Union[None, str, Tuple[int, ...]] = None
    # paged mode: admit prompts longer than this in page-aligned chunks
    prefill_chunk: Optional[int] = None
    # stack >=2 same-bucket waiting prompts into ONE batched prefill
    # dispatch (slot AND paged modes; paged groups scatter per-lane pages,
    # chunked/prefix-seeded admissions stay single-file)
    batched_admission: bool = False
    # admission ordering: "fifo" (head-of-line) | "priority"
    # (Request.priority with starvation-free aging) | "prefix-aware"
    # (requests sharing a hot cached prefix admit back-to-back) |
    # "deadline" (FIFO that SHEDS already-late requests at ingress —
    # the SLO-aware half of PR 8's late_admissions accounting)
    admission: str = "fifo"
    # eviction policy: "budget" (token budget / EOS — the default) |
    # "deadline-preempt" (budget/EOS plus SLO preemption: lanes whose
    # request already missed its deadline yield to queued requests that
    # can still hit theirs; forces per-step token syncs)
    eviction: str = "budget"
    # paged mode: compact the pool when fragmentation (1 - used/span)
    # crosses this threshold; None disables auto-defrag
    defrag_threshold: Optional[float] = 0.5

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError("SchedulerConfig.n_slots must be >= 1")
        if self.max_prefills_per_step < 1:
            raise ValueError("SchedulerConfig.max_prefills_per_step must be >= 1")
        if self.admission not in ("fifo", "priority", "prefix-aware",
                                  "deadline"):
            raise ValueError("SchedulerConfig.admission must be 'fifo', "
                             f"'priority', 'prefix-aware' or 'deadline', got "
                             f"{self.admission!r}")
        if self.eviction not in ("budget", "deadline-preempt"):
            raise ValueError("SchedulerConfig.eviction must be 'budget' or "
                             f"'deadline-preempt', got {self.eviction!r}")
        if self.admission != "fifo" and self.batched_admission:
            raise ValueError("batched_admission stacks FIFO bucket-mates; "
                             "combine it with admission='fifo'")
        if isinstance(self.prefill_buckets, str):
            if self.prefill_buckets != "auto":
                raise ValueError("prefill_buckets must be None, 'auto' or a "
                                 f"tuple of lengths, got {self.prefill_buckets!r}")
        elif self.prefill_buckets is not None:
            object.__setattr__(self, "prefill_buckets",
                               tuple(int(b) for b in self.prefill_buckets))
            if any(b < 1 for b in self.prefill_buckets):
                raise ValueError("prefill bucket lengths must be >= 1")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError("SchedulerConfig.prefill_chunk must be >= 1")
        if self.defrag_threshold is not None and not (
                0.0 <= self.defrag_threshold < 1.0):
            raise ValueError("SchedulerConfig.defrag_threshold must be in "
                             "[0, 1) or None")


@dataclasses.dataclass(frozen=True)
class SamplingDefaults:
    """Default per-request sampling policy (overridable per call)."""

    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        # mirror SamplingParams' own validation at config-build time
        SamplingParams(**dataclasses.asdict(self))

    def to_params(self) -> SamplingParams:
        return SamplingParams(greedy=self.greedy, temperature=self.temperature,
                              top_k=self.top_k, seed=self.seed)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """The one runtime surface: everything that is not the architecture.

    ``RuntimeConfig`` OWNS the runtime knobs it subsumes — resolution
    overwrites the corresponding ``ModelConfig`` fields (quant mode, GEMM
    backend, KV dtype, paged-attention impl), so there is exactly one
    place a deployment's runtime behaviour is specified.
    """

    quant: QuantRuntime = dataclasses.field(default_factory=QuantRuntime)
    kv: KVConfig = dataclasses.field(default_factory=KVConfig)
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    sampling: SamplingDefaults = dataclasses.field(default_factory=SamplingDefaults)
    # sharded serving (repro/shard/): tensor-parallel device mesh.  The
    # default 1x1 config is disabled — the engine runs exactly the
    # unsharded path; tp>1 (or enable=True) threads the mesh through
    # params, pools and every engine dispatch.
    mesh: MeshConfig = dataclasses.field(default_factory=MeshConfig)
    # speculative decoding (repro/spec/): draft-verify greedy decode.
    # Disabled by default (SpecConfig.enabled=False); needs a chunkable
    # (attn/MLA/dense) stack — the engine validates at construction.
    spec: SpecConfig = dataclasses.field(default_factory=SpecConfig)
    # observability (repro/obs/): span tracing, scheduler event log,
    # jax.profiler windows, per-step invariant checking.  All off by
    # default — the engine's hot path sees only null sinks.
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    # default generation budget for requests that don't specify one
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    # smoke-size the architecture config (configs.reduced) before use
    reduced: bool = False

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("RuntimeConfig.max_new_tokens must be >= 1")
        s, kv = self.scheduler, self.kv
        if s.prefill_chunk is not None:
            if kv.mode != "paged":
                raise ValueError("scheduler.prefill_chunk requires "
                                 "kv.mode='paged' (chunks live in pages)")
            if s.prefill_chunk % kv.page_size:
                raise ValueError(
                    f"scheduler.prefill_chunk ({s.prefill_chunk}) must be a "
                    f"multiple of kv.page_size ({kv.page_size})")
        if isinstance(s.prefill_buckets, tuple) and kv.cache_len is not None \
                and max(s.prefill_buckets) > kv.cache_len:
            raise ValueError("largest prefill bucket exceeds kv.cache_len")

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        """Plain JSON-serializable nested dict (tuples become lists)."""
        d = dataclasses.asdict(self)
        b = d["scheduler"]["prefill_buckets"]
        if isinstance(b, tuple):
            d["scheduler"]["prefill_buckets"] = list(b)
        d["mesh"]["axes"] = list(d["mesh"]["axes"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RuntimeConfig":
        """Inverse of ``to_dict`` (also accepts partial dicts: missing keys
        take their defaults, so serialized configs survive field growth)."""
        d = copy.deepcopy(dict(d))
        sched = dict(d.pop("scheduler", {}))
        b = sched.get("prefill_buckets")
        if b is not None and not isinstance(b, str):
            sched["prefill_buckets"] = tuple(b)
        return cls(
            quant=QuantRuntime(**d.pop("quant", {})),
            kv=KVConfig(**d.pop("kv", {})),
            scheduler=SchedulerConfig(**sched),
            sampling=SamplingDefaults(**d.pop("sampling", {})),
            mesh=MeshConfig(**d.pop("mesh", {})),
            spec=SpecConfig(**d.pop("spec", {})),
            obs=ObsConfig(**d.pop("obs", {})),
            **d,
        )

    # -- resolution --------------------------------------------------------
    def resolve_model(self, cfg: ModelConfig) -> ModelConfig:
        """Apply the runtime's model-side overrides.  Returns an ordinary
        frozen ``ModelConfig`` — the object every jit keys on — so adopting
        the facade changes nothing about trace caching."""
        return cfg.with_(
            quant_mode=self.quant.mode,
            gemm_backend=self.quant.gemm_backend,
            kv_cache_dtype=self.kv.dtype,
            paged_attn_impl=self.kv.paged_attn_impl,
            # watchdog instrumentation changes the traced graph (debug
            # callbacks), so it must key the jit caches like any other
            # ModelConfig field — a toggle can never reuse a stale trace
            numerics_watchdog=self.obs.watchdog,
        )

    def resolve_engine(self, cfg: ModelConfig,
                       prompt_len: Optional[int] = None,
                       gen_tokens: Optional[int] = None) -> EngineConfig:
        """Derive the legacy ``EngineConfig``.  ``prompt_len``/``gen_tokens``
        are workload hints used when ``kv.cache_len`` is None (sized by the
        shared ``default_cache_len`` policy) and when buckets are 'auto'."""
        if self.kv.cache_len is not None:
            cache_len = self.kv.cache_len
        elif prompt_len is not None and gen_tokens is not None:
            cache_len = default_cache_len(prompt_len, gen_tokens)
        else:
            raise ValueError(
                "cannot size the KV cache: set kv.cache_len or pass "
                "prompt_len/gen_tokens workload hints to resolve_engine")
        buckets = self.scheduler.prefill_buckets
        if buckets == "auto":
            recurrent = bool(RECURRENT_KINDS & set(cfg.block_pattern))
            buckets = (None if recurrent
                       else auto_buckets(prompt_len or cache_len))
        return EngineConfig(
            n_slots=self.scheduler.n_slots,
            cache_len=cache_len,
            max_prefills_per_step=self.scheduler.max_prefills_per_step,
            prefill_buckets=buckets,
            eos_token=self.eos_token,
            cache_mode=self.kv.mode,
            page_size=self.kv.page_size,
            n_pages=self.kv.n_pages,
            prefill_chunk=self.scheduler.prefill_chunk,
            prefix_cache=self.kv.prefix_cache,
            spec=self.spec if self.spec.enabled else None,
        )

    def resolve(self, cfg: ModelConfig, prompt_len: Optional[int] = None,
                gen_tokens: Optional[int] = None
                ) -> tuple[ModelConfig, EngineConfig]:
        """The single resolution step: (ModelConfig with runtime overrides,
        EngineConfig) — everything the legacy constructors need."""
        model_cfg = self.resolve_model(cfg)
        return model_cfg, self.resolve_engine(model_cfg, prompt_len, gen_tokens)

    def build_policies(self) -> EnginePolicies:
        """Engine policy objects implied by the config: FIFO / priority /
        stacked-prefill admission, budget-or-EOS eviction, threshold
        defrag, and the shared-prefix matching policy."""
        if self.scheduler.admission == "priority":
            admission = PriorityAdmission()
        elif self.scheduler.admission == "prefix-aware":
            admission = PrefixAwareAdmission()
        elif self.scheduler.admission == "deadline":
            admission = DeadlineAdmission()
        elif self.scheduler.batched_admission:
            admission = BucketBatchedAdmission()
        else:
            admission = FIFOAdmission()
        eviction = (DeadlinePreemption()
                    if self.scheduler.eviction == "deadline-preempt"
                    else BudgetOrEOSEviction())
        return EnginePolicies(
            admission=admission,
            eviction=eviction,
            defrag=(ThresholdDefrag(self.scheduler.defrag_threshold)
                    if self.scheduler.defrag_threshold is not None
                    else NeverDefrag()),
            prefix=SharedPrefix(self.kv.prefix_min_pages),
        )


def auto_buckets(prompt_len: int) -> tuple[int, ...]:
    """Power-of-two buckets covering [1, prompt_len] — bounds the number of
    prefill traces while padding any prompt by at most 2x."""
    buckets, b = [], 8
    while b < prompt_len:
        buckets.append(b)
        b *= 2
    buckets.append(prompt_len)
    return tuple(buckets)


# ---------------------------------------------------------------------------
# Preset registry: named deployment profiles
# ---------------------------------------------------------------------------

_PRESETS: dict[str, RuntimeConfig] = {}


def register_preset(name: str, runtime: RuntimeConfig,
                    overwrite: bool = False) -> None:
    """Register a named deployment profile.  Presets are ordinary
    ``RuntimeConfig``s — validated at registration, JSON round-trippable,
    resolvable like any hand-built config."""
    if not isinstance(runtime, RuntimeConfig):
        raise TypeError(f"preset {name!r} must be a RuntimeConfig")
    if name in _PRESETS and not overwrite:
        raise ValueError(f"preset {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _PRESETS[name] = runtime


def get_preset(name: str) -> RuntimeConfig:
    try:
        return _PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown runtime preset {name!r}; known: "
                       f"{list_presets()}") from None


def list_presets() -> list[str]:
    return sorted(_PRESETS)


def load_runtime(spec: str) -> RuntimeConfig:
    """Resolve a CLI ``--runtime`` spec: a JSON file path (loaded through
    ``RuntimeConfig.from_dict``) or a registered preset name."""
    import json
    import os

    if os.path.isfile(spec):
        with open(spec) as f:
            return RuntimeConfig.from_dict(json.load(f))
    if spec in _PRESETS:
        return _PRESETS[spec]
    raise ValueError(f"--runtime {spec!r} is neither a JSON file nor a "
                     f"registered preset (known: {list_presets()})")


# Built-in profiles.  None pins cache_len: presets stay workload-sized, so
# one profile serves smoke tests and real prompt lengths alike.
register_preset("slot-throughput", RuntimeConfig(
    kv=KVConfig(mode="slot"),
    scheduler=SchedulerConfig(prefill_buckets="auto", batched_admission=True),
))
register_preset("paged-server", RuntimeConfig(
    kv=KVConfig(mode="paged", page_size=DEFAULT_PAGE_SIZE),
    scheduler=SchedulerConfig(prefill_chunk=2 * DEFAULT_PAGE_SIZE,
                              defrag_threshold=0.5),
))
register_preset("prefix-interactive", RuntimeConfig(
    kv=KVConfig(mode="paged", page_size=DEFAULT_PAGE_SIZE, prefix_cache=True),
    scheduler=SchedulerConfig(prefill_chunk=DEFAULT_PAGE_SIZE,
                              defrag_threshold=0.5),
))
register_preset("int8-byte-serving", RuntimeConfig(
    quant=QuantRuntime(mode="int8_spoga"),
    kv=KVConfig(mode="paged", dtype="int8", page_size=DEFAULT_PAGE_SIZE,
                prefix_cache=True),
    scheduler=SchedulerConfig(prefill_chunk=DEFAULT_PAGE_SIZE,
                              defrag_threshold=0.5),
))
register_preset("priority-slot", RuntimeConfig(
    kv=KVConfig(mode="slot"),
    scheduler=SchedulerConfig(prefill_buckets="auto", admission="priority"),
))
