"""Result objects returned by the ``repro.api`` facade."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.serving.request import Request


@dataclasses.dataclass
class RequestOutput:
    """One finished generation: ids in, ids (and optionally text) out."""

    request_id: int
    prompt_token_ids: list[int]
    token_ids: list[int]
    # decoded text (None unless a detokenizer was supplied or requested)
    text: Optional[str]
    finish_reason: str          # "stop" (EOS) | "length" (budget)
    ttft_s: Optional[float]     # submit -> first token
    latency_s: Optional[float]  # submit -> finished
    # the request's scheduler event timeline (queued -> admitted -> chunks
    # -> first_token -> finished dicts from ``obs.EventLog``); None when
    # observability is disabled
    timeline: Optional[list[dict]] = None
    # SLO outcome: finished within sampling.deadline_s?  None = no deadline
    deadline_hit: Optional[bool] = None
    # per-request resource attribution (``RequestCost.as_dict()``); None
    # when the engine recorded no dispatches for this request
    cost: Optional[dict] = None

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Submit -> admitted, read off the timeline (None without one)."""
        for ev in self.timeline or ():
            if ev["kind"] == "admitted":
                return ev.get("queue_wait_s")
        return None

    @classmethod
    def from_request(cls, req: Request,
                     detokenizer: Optional[Callable[[Sequence[int]], str]] = None,
                     timeline: Optional[list[dict]] = None) -> "RequestOutput":
        stopped = (req.eos_token is not None and req.output_tokens
                   and req.output_tokens[-1] == req.eos_token)
        return cls(
            request_id=req.req_id,
            prompt_token_ids=list(req.prompt),
            token_ids=list(req.output_tokens),
            text=detokenizer(req.output_tokens) if detokenizer else None,
            finish_reason="stop" if stopped else "length",
            ttft_s=req.ttft_s,
            latency_s=req.latency_s,
            timeline=timeline,
            deadline_hit=req.deadline_hit,
            cost=req.cost.as_dict() if req.cost.dispatches else None,
        )
