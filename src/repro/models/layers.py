"""Base layers: norms, embeddings, MLPs and the SPOGA quantized linear.

Functional style: ``init_*`` build param dicts, ``apply``-style functions
are pure and traceable (the dry-run lowers them with ShapeDtypeStructs).
Compute dtype is bf16 (params stored fp32, cast on use); integer modes
route through the :mod:`repro.backends` registry — quantize -> fused GEMM
-> dequant as one pipeline, with int32 accumulation (the paper's >=16-bit
accumulation requirement) and the dequantizing epilogue fused into the
kernel's single output write on the Pallas backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.backends import quantized_linear

COMPUTE_DTYPE = jnp.bfloat16
# Weights are STORED bf16 (fp32 master copies live in the optimizer state):
# FSDP all-gathers and activation-matmuls move half the bytes, and the fp32
# path stays exact inside AdamW.  Norm scales / router / Λ stay fp32.
PARAM_DTYPE = jnp.bfloat16


def truncated_normal_init(key, shape, scale=0.02, dtype=PARAM_DTYPE):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Quantized linear: dynamic quantization + registry-selected GEMM backend
# forward, straight-through backward (QAT-compatible).
# ---------------------------------------------------------------------------

def _quantized_forward(x, w, mode, backend):
    """x (..., K) fp, w (K, N) fp -> (..., N) fp via the backend pipeline."""
    return quantized_linear(x, w, mode, backend=backend, out_dtype=x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _qmatmul_ste(x, w, mode: str, backend):
    return _quantized_forward(x, w, mode, backend)


def _qmatmul_fwd(x, w, mode, backend):
    return _quantized_forward(x, w, mode, backend), (x, w)


def _qmatmul_bwd(mode, backend, res, g):
    # Straight-through: gradients as if the matmul were full-precision.
    x, w = res
    gf = g.astype(jnp.float32)
    dx = (gf @ w.astype(jnp.float32).T).astype(x.dtype)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2 = gf.reshape(-1, gf.shape[-1])
    dw = (x2.T @ g2).astype(w.dtype)
    return dx, dw


_qmatmul_ste.defvjp(_qmatmul_fwd, _qmatmul_bwd, symbolic_zeros=False)


def linear(x, w, quant_mode: str = "bf16", backend: str | None = None):
    """The single matmul entry point for every model layer.

    ``backend`` is an optional GEMM-backend registry name (from
    ``ModelConfig.gemm_backend`` / ``--gemm-backend``); ``None`` defers to
    the registry's platform auto-selection.
    """
    if quant_mode == "bf16":
        return jnp.einsum(
            "...k,kn->...n", x.astype(COMPUTE_DTYPE), w.astype(COMPUTE_DTYPE)
        )
    return _qmatmul_ste(x.astype(COMPUTE_DTYPE), w, quant_mode, backend)


def init_linear(key, d_in, d_out, scale=0.02):
    return truncated_normal_init(key, (d_in, d_out), scale)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d):
    return jnp.ones((d,), jnp.float32)


def rmsnorm(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * gamma).astype(x.dtype)


def init_layernorm(d):
    return {"gamma": jnp.ones((d,), jnp.float32), "beta": jnp.zeros((d,), jnp.float32)}


def layernorm(x, p, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["gamma"] + p["beta"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _act(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_glu_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(k1, d_model, d_ff),
        "w_up": init_linear(k2, d_model, d_ff),
        "w_down": init_linear(k3, d_ff, d_model),
    }


def glu_mlp(x, p, act="silu", quant_mode="bf16", backend=None):
    g = _act(act)(linear(x, p["w_gate"], quant_mode, backend))
    u = linear(x, p["w_up"], quant_mode, backend)
    return linear(g * u, p["w_down"], quant_mode, backend)


def init_mlp(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {"w_in": init_linear(k1, d_model, d_ff), "w_out": init_linear(k2, d_ff, d_model)}


def mlp(x, p, act="gelu", quant_mode="bf16", backend=None):
    return linear(
        _act(act)(linear(x, p["w_in"], quant_mode, backend)),
        p["w_out"], quant_mode, backend,
    )


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d_model):
    return truncated_normal_init(
        key, (vocab, d_model), scale=1.0 / (d_model ** 0.5), dtype=PARAM_DTYPE
    )


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0).astype(COMPUTE_DTYPE)


def unembed(x, table, quant_mode="bf16"):
    # Output head kept in bf16 even in quantized mode: the paper's INT8
    # accumulation rounds to 8-bit *between* layers; logits need full range.
    return jnp.einsum(
        "...d,vd->...v", x.astype(COMPUTE_DTYPE), table.astype(COMPUTE_DTYPE)
    ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10_000.0):
    """x: (B, S, H, D) ; positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Causal depthwise 1D conv (Griffin / xLSTM temporal conv)
# ---------------------------------------------------------------------------

def init_conv1d(key, d, width):
    return truncated_normal_init(key, (width, d), scale=0.1)


def causal_conv1d(x, w):
    """x: (B, S, D), w: (W, D) depthwise causal convolution."""
    width = w.shape[0]
    xf = x.astype(jnp.float32)
    pad = jnp.pad(xf, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xf)
    for i in range(width):  # width is tiny (4); unrolled adds, fusable
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out.astype(x.dtype)


def conv1d_decode(x_t, conv_state, w):
    """Single-step conv: x_t (B, D), conv_state (B, W-1, D) -> (y_t, new_state)."""
    width = w.shape[0]
    xf = x_t.astype(jnp.float32)
    hist = jnp.concatenate([conv_state, xf[:, None, :]], axis=1)  # (B, W, D)
    y = jnp.einsum("bwd,wd->bd", hist, w)
    return y.astype(x_t.dtype), hist[:, 1:, :]
