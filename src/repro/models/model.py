"""Public model API: init / forward / loss / prefill / decode for every
assigned architecture (decoder-only, enc-dec, hybrid, frontend-stub).

Parameter layout (scan-friendly):

    {"embed": (V, d),
     "head_blocks": [per-layer trees]            # leading dense layers (MoE)
     "blocks": (slot_0_tree, ..., slot_{p-1}),   # stacked over n_periods
     "tail_blocks": [per-layer trees],           # depth remainder
     "final_norm": (d,),
     "head": (V, d) (absent if tied),
     # enc-dec only:
     "enc_embed_norm", "enc_blocks", "enc_final_norm", "dec_*" mirrors}

The cross-entropy is computed CHUNKED over the sequence (scan) so the full
(B, S, V) logits tensor is never materialized — with 256k vocabs at 1M
tokens that buffer alone would exceed per-device HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import transformer as tfm
from repro.models.kvcache import (
    block_cache_shape,
    paged_block_cache_shape,
    zeros_like_shapes,
)
from repro.models.layers import (
    COMPUTE_DTYPE,
    embed,
    init_embedding,
    init_rmsnorm,
    rmsnorm,
    unembed,
)
from repro.obs import watchdog as _watchdog

LOSS_CHUNK = 512


def _watched(tag: str):
    """Wrap a ``(params, cfg, ...)`` entry point in the numerics-watchdog
    trace-time context when ``cfg.numerics_watchdog`` asks for it.

    The context is consulted by ``quantized_linear`` *while JAX traces
    the body*, so every quantized GEMM below self-labels
    (``<tag>.<site>.k<K>n<N>``) without threading a flag through the
    model call tree.  ``cfg.numerics_watchdog`` is part of every jit
    cache key, so toggling can never reuse an uninstrumented trace.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(params, cfg, *args, **kw):
            with _watchdog.watching(tag if cfg.numerics_watchdog else None):
                return fn(params, cfg, *args, **kw)
        return wrapper
    return deco


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack_period_params(keys_2d, cfg: ModelConfig):
    """init each slot across periods and stack along a leading axis."""
    pattern = cfg.block_pattern
    slots = []
    for s, kind in enumerate(pattern):
        per_period = [tfm.init_block(keys_2d[i][s], kind, cfg) for i in range(len(keys_2d))]
        slots.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_period))
    return tuple(slots)


def _init_stack(key, cfg: ModelConfig, n_layers: int):
    lead, n_periods, tail_kinds = tfm.layer_layout(cfg, n_layers)
    keys = jax.random.split(key, lead + n_periods * cfg.pattern_period + len(tail_kinds) + 1)
    out = {}
    ki = 0
    if lead:
        out["head_blocks"] = []
        for i in range(lead):
            out["head_blocks"].append(tfm.init_block(keys[ki], "dense_ffn_layer", cfg))
            ki += 1
    keys_2d = []
    for i in range(n_periods):
        keys_2d.append([keys[ki + j] for j in range(cfg.pattern_period)])
        ki += cfg.pattern_period
    out["blocks"] = _stack_period_params(keys_2d, cfg) if n_periods else ()
    out["tail_blocks"] = []
    for kind in tail_kinds:
        out["tail_blocks"].append(tfm.init_block(keys[ki], kind, cfg))
        ki += 1
    return out


def init_params(cfg: ModelConfig, key):
    k_embed, k_stack, k_head, k_enc = jax.random.split(key, 4)
    params = {"embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model)}
    params.update(_init_stack(k_stack, cfg, cfg.n_layers))
    params["final_norm"] = init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = init_embedding(k_head, cfg.vocab_size, cfg.d_model)
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.with_(block_pattern=("attn",))
        enc = _init_stack(k_enc, enc_cfg, cfg.n_encoder_layers)
        params["enc_blocks"] = enc["blocks"]
        params["enc_tail_blocks"] = enc["tail_blocks"]
        params["enc_final_norm"] = init_rmsnorm(cfg.d_model)
        # cross-attention params per decoder layer (stacked like blocks)
        kx = jax.random.split(k_enc, max(cfg.n_layers, 1))
        lead, n_periods, tail_kinds = tfm.layer_layout(cfg)
        per = []
        for i in range(n_periods):
            per.append(
                {
                    "xattn": attn_mod.init_cross_attention(kx[i], cfg),
                    "norm_x": init_rmsnorm(cfg.d_model),
                }
            )
        params["cross_blocks"] = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per) if per else ()
        )
    return params


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Backbone forward (training / full-sequence)
# ---------------------------------------------------------------------------

def _embed_input(params, cfg: ModelConfig, batch):
    if "embeds" in batch:
        x = batch["embeds"].astype(COMPUTE_DTYPE)
    else:
        x = embed(batch["tokens"], params["embed"])
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def _run_stack(x, params, cfg: ModelConfig, positions, *, causal=True):
    aux = jnp.zeros((), jnp.float32)
    for p in params.get("head_blocks", []):
        x, a, _ = tfm.apply_block(x, p, "dense_ffn_layer", cfg, positions, causal=causal)
        aux += a
    if params.get("blocks", ()):
        x, a = tfm.scan_periods(x, params["blocks"], cfg, positions, causal=causal)
        aux += a
    tail_kinds = tfm.layer_layout(cfg)[2] if params.get("tail_blocks") else ()
    for i, p in enumerate(params.get("tail_blocks", [])):
        x, a, _ = tfm.apply_block(x, p, tail_kinds[i], cfg, positions, causal=causal)
        aux += a
    return x, aux


def _run_encoder(src, params, cfg: ModelConfig):
    enc_cfg = cfg.with_(block_pattern=("attn",))
    b, s = src.shape[0], src.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = src.astype(COMPUTE_DTYPE)
    enc_params = {"blocks": params["enc_blocks"], "tail_blocks": params.get("enc_tail_blocks", [])}
    x, _ = _run_stack(x, enc_params, enc_cfg, positions)
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def _run_decoder_with_cross(x, params, cfg: ModelConfig, positions, enc_out):
    """Decoder stack with interleaved cross-attention (enc-dec models)."""
    pattern = cfg.block_pattern
    aux = jnp.zeros((), jnp.float32)

    def period_fn(carry, xs):
        from repro.runtime.sharding import constrain_activations

        h, aux = carry
        h = constrain_activations(h)
        slot_params, cross_p = xs
        for s, kind in enumerate(pattern):
            h, a, _ = tfm.apply_block(h, slot_params[s], kind, cfg, positions)
            aux = aux + a
        hx = rmsnorm(h, cross_p["norm_x"], cfg.norm_eps)
        enc_kv = attn_mod.encode_cross_kv(enc_out, cross_p["xattn"], cfg)
        h = h + attn_mod.cross_attention_block(hx, enc_kv, cross_p["xattn"], cfg)
        return (h, aux), None

    if cfg.remat:
        period_fn = jax.checkpoint(period_fn, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(
        period_fn, (x, aux), (params["blocks"], params["cross_blocks"]),
        unroll=cfg.scan_unroll,
    )
    return x, aux


def backbone(params, cfg: ModelConfig, batch):
    """-> (hidden (B,S,d), aux_loss scalar)."""
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(batch["src_embeds"], params, cfg)
        x, positions = _embed_input(params, cfg, {"tokens": batch["tgt_tokens"]})
        x, aux = _run_decoder_with_cross(x, params, cfg, positions, enc_out)
    else:
        x, positions = _embed_input(params, cfg, batch)
        x, aux = _run_stack(x, params, cfg, positions)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def _head_table(params, cfg: ModelConfig):
    return params["embed"] if cfg.tie_embeddings else params["head"]


@_watched("forward")
def forward(params, cfg: ModelConfig, batch):
    """Full logits (B, S, V) — use only for small configs/tests."""
    h, _ = backbone(params, cfg, batch)
    return unembed(h, _head_table(params, cfg))


def _chunked_ce(hidden, labels, mask, table, cfg: ModelConfig):
    """Cross-entropy via scan over sequence chunks; no (B,S,V) buffer."""
    b, s, d = hidden.shape
    c = LOSS_CHUNK if s % LOSS_CHUNK == 0 and s > LOSS_CHUNK else s
    nc = s // c
    hs = hidden.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, c).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, c).transpose(1, 0, 2)

    # remat: the scan's backward would otherwise save every chunk's logits —
    # the very (B, S, V) buffer this chunking exists to avoid.
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(acc, xs):
        hc, lc, mc = xs
        logits = unembed(hc, table).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms),
                                 unroll=cfg.scan_unroll)
    return tot / jnp.maximum(cnt, 1.0)


AUX_LOSS_WEIGHT = 0.01


@_watched("loss")
def lm_loss(params, cfg: ModelConfig, batch):
    """Next-token (or label) cross-entropy + MoE aux loss. Scalar fp32."""
    h, aux = backbone(params, cfg, batch)
    if cfg.is_encoder_decoder:
        tokens = batch["tgt_tokens"]
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
    elif "labels" in batch:
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
    else:
        tokens = batch["tokens"]
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
    ce = _chunked_ce(h, labels, mask, _head_table(params, cfg), cfg)
    return ce + AUX_LOSS_WEIGHT * aux


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def cache_shapes(cfg: ModelConfig, batch: int, cache_len: int, cross_len: int = 0):
    """ShapeDtypeStruct cache pytree mirroring the block layout."""
    lead, n_periods, tail_kinds = tfm.layer_layout(cfg)

    def one(kind):
        return block_cache_shape(tfm.effective_kind(kind, cfg), cfg, batch, cache_len)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_periods,) + s.shape, s.dtype), tree
        )

    cache = {
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "head_blocks": [one("attn") for _ in range(lead)],
        "blocks": tuple(stack(one(kind)) for kind in cfg.block_pattern) if n_periods else (),
        "tail_blocks": [one(kind) for kind in tail_kinds],
    }
    if cfg.is_encoder_decoder:
        hd = cfg.resolved_head_dim
        kv = jax.ShapeDtypeStruct(
            (n_periods, batch, cross_len, cfg.n_kv_heads, hd), COMPUTE_DTYPE
        )
        cache["cross_kv"] = {"k": kv, "v": kv}
    return cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, cross_len: int = 0):
    return zeros_like_shapes(cache_shapes(cfg, batch, cache_len, cross_len))


def paged_cache_shapes(cfg: ModelConfig, n_lanes: int, cache_len: int,
                       page_size: int, n_pages: int):
    """ShapeDtypeStruct tree for the *paged* decode cache (repro/paging/).

    Same block layout as :func:`cache_shapes`, but attention-family KV
    lives in global page pools indexed through ``block_tables`` —
    ``(n_lanes, max_pages_per_lane)`` int32, logical page ``j`` of lane
    ``b`` is physical page ``block_tables[b, j]``.  ``cache_len`` bounds a
    single lane (it sizes the table width), not the pool.
    """
    if cfg.is_encoder_decoder:
        raise ValueError("paged caches support decoder-only stacks")
    from repro.configs.base import pages_for

    lead, n_periods, tail_kinds = tfm.layer_layout(cfg)
    max_pages = pages_for(cache_len, page_size)

    def one(kind):
        return paged_block_cache_shape(
            tfm.effective_kind(kind, cfg), cfg, n_lanes, cache_len,
            n_pages, page_size)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_periods,) + s.shape, s.dtype), tree
        )

    return {
        "pos": jax.ShapeDtypeStruct((n_lanes,), jnp.int32),
        "block_tables": jax.ShapeDtypeStruct((n_lanes, max_pages), jnp.int32),
        "head_blocks": [one("dense_ffn_layer") for _ in range(lead)],
        "blocks": tuple(stack(one(kind)) for kind in cfg.block_pattern) if n_periods else (),
        "tail_blocks": [one(kind) for kind in tail_kinds],
    }


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

@_watched("prefill")
def prefill(params, cfg: ModelConfig, batch, cache_len: int, lengths=None):
    """Process the prompt, return (last-position logits (B, V), cache).

    ``lengths`` (optional, (B,) int32): true prompt lengths when the batch is
    right-padded to a common width (the serving engine's prefill buckets).
    Logits are gathered at position ``lengths - 1`` and the cache ``pos``
    starts at ``lengths``, so padded tail positions are never attended: every
    decode step writes its K/V at ``pos`` *before* attending ``kpos <= pos``,
    overwriting the stale padded row exactly when it would first become
    visible.  Exact for attention-family caches only — recurrent state
    (rglru/mlstm/slstm) integrates padded tokens, so callers must pass
    unpadded prompts (``lengths=None``) for those stacks.
    """
    for key in ("tokens", "embeds", "src_embeds"):
        if key in batch:
            b = batch[key].shape[0]
            break
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(batch["src_embeds"], params, cfg)
        cross_len = enc_out.shape[1]
        cache = init_cache(cfg, b, cache_len, cross_len)
        # precompute per-decoder-layer cross K/V once (the enc-dec prefill)
        def xkv(cross_p):
            return attn_mod.encode_cross_kv(enc_out, cross_p["xattn"], cfg)
        k, v = jax.vmap(xkv)(params["cross_blocks"])
        cache["cross_kv"] = {"k": k, "v": v}
        tgt = batch.get("tgt_tokens")
        x, positions = _embed_input(params, cfg, {"tokens": tgt})
    else:
        cache = init_cache(cfg, b, cache_len)
        x, positions = _embed_input(params, cfg, batch)
    s = x.shape[1]

    aux = jnp.zeros((), jnp.float32)
    for i, p in enumerate(params.get("head_blocks", [])):
        x, a, c = tfm.apply_block_prefill(x, p, "dense_ffn_layer", cfg, positions,
                                          cache["head_blocks"][i])
        cache["head_blocks"][i] = c
    if params.get("blocks", ()):
        if cfg.is_encoder_decoder:
            x, aux2, new_blocks = _prefill_decoder_with_cross(
                x, params, cfg, positions, cache
            )
        else:
            x, aux2, new_blocks = tfm.scan_periods_prefill(
                x, params["blocks"], cache["blocks"], cfg, positions
            )
        cache["blocks"] = new_blocks
    lead, n_periods, tail_kinds = tfm.layer_layout(cfg)
    for i, p in enumerate(params.get("tail_blocks", [])):
        x, a, c = tfm.apply_block_prefill(x, p, tail_kinds[i], cfg, positions,
                                          cache["tail_blocks"][i])
        cache["tail_blocks"][i] = c
    if lengths is None:
        cache["pos"] = jnp.full((x.shape[0],), s, jnp.int32)
        x_last = x[:, -1:, :]
    else:
        lengths = lengths.astype(jnp.int32)
        cache["pos"] = lengths
        idx = jnp.clip(lengths - 1, 0, s - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    h = rmsnorm(x_last, params["final_norm"], cfg.norm_eps)
    logits = unembed(h, _head_table(params, cfg))[:, 0, :]
    return logits, cache


def _prefill_decoder_with_cross(x, params, cfg, positions, cache):
    pattern = cfg.block_pattern

    def period_fn(carry, xs):
        h, aux = carry
        slot_params, cross_p, slot_tpl, xkv = xs
        new_cache = []
        for s, kind in enumerate(pattern):
            h, a, c = tfm.apply_block_prefill(h, slot_params[s], kind, cfg, positions,
                                              slot_tpl[s])
            aux = aux + a
            new_cache.append(c)
        hx = rmsnorm(h, cross_p["norm_x"], cfg.norm_eps)
        h = h + attn_mod.cross_attention_block(hx, (xkv["k"], xkv["v"]), cross_p["xattn"], cfg)
        return (h, aux), tuple(new_cache)

    (x, aux), new_blocks = jax.lax.scan(
        period_fn,
        (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], params["cross_blocks"], cache["blocks"], cache["cross_kv"]),
        unroll=cfg.scan_unroll,
    )
    return x, aux, new_blocks


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

@_watched("decode")
def decode_step(params, cfg: ModelConfig, tokens, cache, active=None):
    """One token for every sequence. tokens: (B,) int32 (or (B,d) embeds).

    ``active`` (optional, (B,) bool): lanes currently serving a request.
    Inactive lanes still ride the fixed-shape step (continuous batching),
    but their ``pos`` is pinned to 0 instead of advancing on garbage
    tokens — so host metrics and paged-page accounting can never observe
    a drifted position — and paged writes are redirected to the reserved
    trash page.  ``active=None`` (solo decoding) advances every lane.

    A ``block_tables`` key in ``cache`` marks a *paged* cache (see
    :func:`paged_cache_shapes`); the table is threaded to every
    attention-family block and passed through unchanged.

    Returns (logits (B, V), new cache with pos advanced)."""
    pos = cache["pos"]
    tables = cache.get("block_tables")
    if tokens.ndim == 1:
        x = embed(tokens[:, None], params["embed"])
    else:
        x = tokens[:, None, :].astype(COMPUTE_DTYPE)

    new_cache = dict(cache)
    for i, p in enumerate(params.get("head_blocks", [])):
        x, c = tfm.apply_block_decode(x, p, "dense_ffn_layer", cfg, cache["head_blocks"][i], pos,
                                      tables=tables, active=active)
        new_cache["head_blocks"] = list(new_cache.get("head_blocks", []))
        new_cache["head_blocks"][i] = c
    if params.get("blocks", ()):
        if cfg.is_encoder_decoder:
            x, nb = _decode_with_cross(x, params, cfg, cache, pos)
        else:
            x, nb = tfm.scan_periods_decode(x, params["blocks"], cache["blocks"], cfg, pos,
                                            tables=tables, active=active)
        new_cache["blocks"] = nb
    lead, n_periods, tail_kinds = tfm.layer_layout(cfg)
    for i, p in enumerate(params.get("tail_blocks", [])):
        x, c = tfm.apply_block_decode(x, p, tail_kinds[i], cfg, cache["tail_blocks"][i], pos,
                                      tables=tables, active=active)
        new_cache["tail_blocks"] = list(new_cache.get("tail_blocks", []))
        new_cache["tail_blocks"][i] = c
    new_cache["pos"] = pos + 1 if active is None else jnp.where(active, pos + 1, 0)
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(h, _head_table(params, cfg))[:, 0, :]
    return logits, new_cache


@_watched("verify")
def verify_step(params, cfg: ModelConfig, tokens, cache, active=None):
    """W tokens for every sequence in one dispatch (speculative verify).

    tokens: (B, W) int32 — the last accepted token followed by W-1 drafted
    tokens; row c is processed at absolute position ``pos + c`` (causal
    within the window, attending the full slot/paged history before it).
    All W K/V rows are written; the caller is responsible for treating
    rows past the accepted prefix as garbage (they are overwritten before
    any later query attends them).

    Unlike :func:`decode_step`, ``cache["pos"]`` is returned UNCHANGED —
    the accept length is only known after comparing logits, so the
    speculative wrapper advances pos by ``accepted + 1`` itself.

    Only ``chunkable(cfg)`` stacks are supported (attn / MLA / dense FFN;
    no MoE, recurrent, local-attn, or encoder-decoder blocks).

    Returns (logits (B, W, V), new cache with pos unchanged)."""
    pos = cache["pos"]
    tables = cache.get("block_tables")
    x = embed(tokens, params["embed"])

    new_cache = dict(cache)
    for i, p in enumerate(params.get("head_blocks", [])):
        x, c = tfm.apply_block_verify(x, p, "dense_ffn_layer", cfg, cache["head_blocks"][i], pos,
                                      tables=tables, active=active)
        new_cache["head_blocks"] = list(new_cache.get("head_blocks", []))
        new_cache["head_blocks"][i] = c
    if params.get("blocks", ()):
        x, nb = tfm.scan_periods_verify(x, params["blocks"], cache["blocks"], cfg, pos,
                                        tables=tables, active=active)
        new_cache["blocks"] = nb
    lead, n_periods, tail_kinds = tfm.layer_layout(cfg)
    for i, p in enumerate(params.get("tail_blocks", [])):
        x, c = tfm.apply_block_verify(x, p, tail_kinds[i], cfg, cache["tail_blocks"][i], pos,
                                      tables=tables, active=active)
        new_cache["tail_blocks"] = list(new_cache.get("tail_blocks", []))
        new_cache["tail_blocks"][i] = c
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(h, _head_table(params, cfg))
    return logits, new_cache


def _decode_with_cross(x_t, params, cfg, cache, pos):
    pattern = cfg.block_pattern

    def period_fn(h, xs):
        slot_params, cross_p, slot_cache, xkv = xs
        new_cache = []
        for s, kind in enumerate(pattern):
            h, c = tfm.apply_block_decode(h, slot_params[s], kind, cfg, slot_cache[s], pos)
            new_cache.append(c)
        hx = rmsnorm(h, cross_p["norm_x"], cfg.norm_eps)
        h = h + attn_mod.cross_attention_block(hx, (xkv["k"], xkv["v"]), cross_p["xattn"], cfg)
        return h, tuple(new_cache)

    x_t, new_blocks = jax.lax.scan(
        period_fn, x_t,
        (params["blocks"], params["cross_blocks"], cache["blocks"], cache["cross_kv"]),
        unroll=cfg.scan_unroll,
    )
    return x_t, new_blocks
