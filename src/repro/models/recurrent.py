"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM (mLSTM/sLSTM).

* RG-LRU trains/prefills with ``jax.lax.associative_scan`` (parallel scan
  over the linear recurrence) and decodes with an O(1) state update.
* mLSTM uses the chunkwise-parallel formulation: quadratic attention-like
  math inside fixed chunks, a sequential scan over chunk boundaries carrying
  the (C, n) matrix memory.  Gates are sigmoidal (log-space decay products),
  a documented simplification of the paper's exponential-gate stabilizer.
* sLSTM is inherently sequential (recurrent R weights); ``lax.scan``.

All projection GEMMs run under the SPOGA quant modes; the elementwise
recurrences stay fp32 (they are not GEMMs — outside SPOGA's scope, see
DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    causal_conv1d,
    conv1d_decode,
    init_conv1d,
    init_linear,
    linear,
    truncated_normal_init,
)

# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    lru = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "w_gate_branch": init_linear(ks[0], d, lru),
        "w_x_branch": init_linear(ks[1], d, lru),
        "conv_w": init_conv1d(ks[2], lru, cfg.conv_width),
        "w_rec_gate": init_linear(ks[3], lru, lru),
        "w_in_gate": init_linear(ks[4], lru, lru),
        # Λ init so that a = exp(-c softplus(Λ)) lands in [0.9, 0.999]; fp32
        "lam": truncated_normal_init(ks[5], (lru,), scale=0.1, dtype=jnp.float32) - 4.0,
        "w_out": init_linear(ks[6], lru, d),
    }


def _rglru_coeffs(xb, p, quant_mode, backend=None):
    r = jax.nn.sigmoid(linear(xb, p["w_rec_gate"], quant_mode, backend).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(xb, p["w_in_gate"], quant_mode, backend).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r          # (B, S, lru), <= 0
    a = jnp.exp(log_a)
    gated_x = i * xb.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * gated_x
    return a, b


def rglru_scan(xb, p, quant_mode, h0=None, backend=None):
    """xb: (B, S, lru) conv'd branch -> (y (B,S,lru) fp32, h_last (B,lru))."""
    a, b = _rglru_coeffs(xb, p, quant_mode, backend)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_block(x, p, cfg: ModelConfig, state=None):
    """Griffin recurrent block. state: None | {"h": (B,lru), "conv": (B,W-1,lru)}."""
    qm, be = cfg.quant_mode, cfg.gemm_backend
    gate = jax.nn.gelu(linear(x, p["w_gate_branch"], qm, be).astype(jnp.float32))
    xb_raw = linear(x, p["w_x_branch"], qm, be)
    xb = causal_conv1d(xb_raw, p["conv_w"])
    h, h_last = rglru_scan(xb, p, qm, None, backend=be)
    y = (gate * h).astype(x.dtype)
    out = linear(y, p["w_out"], qm, be)
    new_state = None
    if state is not None:
        # decode continues from here: conv state holds the last W-1 *raw*
        # branch inputs (pre-conv), h the last recurrent state.
        w = cfg.conv_width
        raw = jnp.pad(xb_raw.astype(jnp.float32), ((0, 0), (w - 1, 0), (0, 0)))
        new_state = {"h": h_last, "conv": raw[:, -(w - 1):, :]}
    return out, new_state


def rglru_chunk(x, p, cfg: ModelConfig, state, n_valid):
    """Chunked prefill step: (1, C, d) chunk with only the first
    ``n_valid`` rows real, carrying cell state across chunks.

    ``state`` holds the previous chunk's carry — ``h`` (B, lru) and
    ``conv`` (B, W-1, lru), the last W-1 *raw* pre-conv branch rows in
    fp32 (zeros for the first chunk ≡ ``causal_conv1d``'s left padding).
    Pad rows are forced to the identity recurrence (a=1, b=0) so the
    hidden state holds its last valid value past the boundary: ``h_last``
    and the conv carry are exact regardless of padding, and pad-row
    outputs are garbage confined to rows no later block ever reads (the
    same argument chunked attention makes for its padded tail)."""
    qm, be = cfg.quant_mode, cfg.gemm_backend
    w = cfg.conv_width
    c = x.shape[1]
    gate = jax.nn.gelu(linear(x, p["w_gate_branch"], qm, be).astype(jnp.float32))
    xb_raw = linear(x, p["w_x_branch"], qm, be)
    # depthwise causal conv with carried context in place of zero padding
    full_raw = jnp.concatenate(
        [state["conv"].astype(jnp.float32), xb_raw.astype(jnp.float32)],
        axis=1)                                           # (B, W-1+C, lru)
    xb = jnp.zeros_like(full_raw[:, w - 1:, :])
    for i in range(w):  # width is tiny (4); matches causal_conv1d's order
        xb = xb + full_raw[:, i: i + c, :] * p["conv_w"][i]
    xb = xb.astype(xb_raw.dtype)
    a, bcoef = _rglru_coeffs(xb, p, qm, be)
    valid = (jnp.arange(c) < n_valid)[None, :, None]
    a = jnp.where(valid, a, 1.0)
    bcoef = jnp.where(valid, bcoef, 0.0)
    h, h_last = rglru_scan_coeffs(a, bcoef, state["h"])
    y = (gate * h).astype(x.dtype)
    out = linear(y, p["w_out"], qm, be)
    # conv carry: raw rows at positions [n_valid - W + 1, n_valid) of the
    # ctx+chunk concat — the last W-1 rows ending at the chunk's last
    # valid token (n_valid >= 1 always; the engine never feeds empty chunks)
    new_conv = jax.lax.dynamic_slice_in_dim(full_raw, n_valid, w - 1, axis=1)
    return out, {"h": h_last, "conv": new_conv}


def rglru_scan_coeffs(a, b, h0):
    """The associative scan over precomputed (a, b) with carried ``h0``."""
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_decode(x_t, p, cfg: ModelConfig, state):
    """One step. x_t: (B, 1, d); state {"h": (B,lru), "conv": (B,W-1,lru)}."""
    qm, be = cfg.quant_mode, cfg.gemm_backend
    gate = jax.nn.gelu(linear(x_t, p["w_gate_branch"], qm, be).astype(jnp.float32))
    xb = linear(x_t, p["w_x_branch"], qm, be)[:, 0, :]
    xb_c, conv_state = conv1d_decode(xb, state["conv"], p["conv_w"])
    a, b = _rglru_coeffs(xb_c[:, None, :], p, qm, be)
    h = a[:, 0, :] * state["h"] + b[:, 0, :]
    y = (gate[:, 0, :] * h).astype(x_t.dtype)
    out = linear(y[:, None, :], p["w_out"], qm, be)
    return out, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell) — chunkwise parallel
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    return {
        "wq": init_linear(ks[0], d, h * dh),
        "wk": init_linear(ks[1], d, h * dh),
        "wv": init_linear(ks[2], d, h * dh),
        "w_igate": init_linear(ks[3], d, h),
        "w_fgate": init_linear(ks[4], d, h),
        "w_ogate": init_linear(ks[5], d, d),
        "w_out": init_linear(ks[6], d, d),
    }


_MLSTM_CHUNK = 256


def _mlstm_chunk_math(q, k, v, logf, logi, C0, n0):
    """One chunk. q,k,v: (B,H,L,dh) fp32; logf,logi: (B,H,L); C0: (B,H,dh,dh)."""
    L = q.shape[2]
    cum_f = jnp.cumsum(logf, axis=-1)                     # log F_t (inclusive)
    # intra-chunk decay: D[t, s] = exp(cum_f[t] - cum_f[s]) * exp(logi[s]), s <= t
    dmat = cum_f[..., :, None] - cum_f[..., None, :] + logi[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    dmat = jnp.where(mask, dmat, -jnp.inf)
    D = jnp.exp(dmat)
    scores = jnp.einsum("bhld,bhsd->bhls", q, k) * D
    intra = jnp.einsum("bhls,bhsd->bhld", scores, v)
    Ft = jnp.exp(cum_f)[..., None]                        # (B,H,L,1)
    inter = Ft * jnp.einsum("bhld,bhde->bhle", q, C0)
    num = intra + inter
    # normalizer: n_t = F_t n0 + sum_s (F_t/F_s) i_s k_s ; den = |q . n_t|
    inter_n = Ft * jnp.einsum("bhld,bhd->bhl", q, n0)[..., None]
    n_intra = jnp.einsum("bhls,bhsd->bhld", D, k)
    qn = jnp.einsum("bhld,bhld->bhl", q, n_intra)[..., None] + inter_n
    den = jnp.maximum(jnp.abs(qn), 1.0)
    h = num / den
    # carry to next chunk
    FL = jnp.exp(cum_f[..., -1])[..., None, None]         # (B,H,1,1)
    decay_to_end = jnp.exp(cum_f[..., -1:] - cum_f + logi)  # (B,H,L)
    C1 = FL * C0 + jnp.einsum("bhl,bhld,bhle->bhde", decay_to_end, k, v)
    n1 = FL[..., 0] * n0 + jnp.einsum("bhl,bhld->bhd", decay_to_end, k)
    return h, C1, n1


def mlstm_block(x, p, cfg: ModelConfig, state=None):
    """x: (B, S, d) -> (out, new_state). Chunkwise-parallel mLSTM."""
    qm, be = cfg.quant_mode, cfg.gemm_backend
    b, s, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads

    def heads(t):
        return t.reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3).astype(jnp.float32)

    q = heads(linear(x, p["wq"], qm, be)) * (dh ** -0.5)
    k = heads(linear(x, p["wk"], qm, be)) * (dh ** -0.5)
    v = heads(linear(x, p["wv"], qm, be))
    logi = jax.nn.log_sigmoid(
        linear(x, p["w_igate"], qm, be).astype(jnp.float32)
    ).transpose(0, 2, 1)
    logf = jax.nn.log_sigmoid(
        linear(x, p["w_fgate"], qm, be).astype(jnp.float32)
    ).transpose(0, 2, 1)

    L = min(_MLSTM_CHUNK, s)
    assert s % L == 0, f"seq {s} not divisible by mLSTM chunk {L}"
    nc = s // L

    def to_chunks(t):
        return t.reshape(b, h_heads, nc, L, *t.shape[3:]).transpose(2, 0, 1, 3, *range(4, t.ndim + 1))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    fic = logi.reshape(b, h_heads, nc, L).transpose(2, 0, 1, 3)
    ffc = logf.reshape(b, h_heads, nc, L).transpose(2, 0, 1, 3)

    C0 = jnp.zeros((b, h_heads, dh, dh), jnp.float32) if state is None else state["C"]
    n0 = jnp.zeros((b, h_heads, dh), jnp.float32) if state is None else state["n"]

    def body(carry, xs):
        C, n = carry
        qi, ki, vi, lfi, lii = xs
        h, C1, n1 = _mlstm_chunk_math(qi, ki, vi, lfi, lii, C, n)
        return (C1, n1), h

    (C_f, n_f), hs = jax.lax.scan(body, (C0, n0), (qc, kc, vc, ffc, fic))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, h_heads, s, dh)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, d)
    o = jax.nn.sigmoid(linear(x, p["w_ogate"], qm, be).astype(jnp.float32))
    out = linear((o * h).astype(x.dtype), p["w_out"], qm, be)
    new_state = None if state is None else {"C": C_f, "n": n_f}
    return out, new_state


def mlstm_chunk(x, p, cfg: ModelConfig, state, n_valid):
    """Chunked prefill step: (1, C, d) chunk, first ``n_valid`` rows real,
    carrying the (C, n) matrix memory across chunks.

    Pad rows are neutralized in the gate domain — ``log f = 0`` (decay 1:
    cumulative products past the boundary are unchanged) and
    ``log i = -inf`` (zero injection: exp() zeroes every pad contribution
    to the intra-chunk D matrix and the chunk-boundary carry) — so the
    carried (C, n) equal the exact-length computation's."""
    qm, be = cfg.quant_mode, cfg.gemm_backend
    b, s, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads

    def heads(t):
        return t.reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3).astype(jnp.float32)

    q = heads(linear(x, p["wq"], qm, be)) * (dh ** -0.5)
    k = heads(linear(x, p["wk"], qm, be)) * (dh ** -0.5)
    v = heads(linear(x, p["wv"], qm, be))
    logi = jax.nn.log_sigmoid(
        linear(x, p["w_igate"], qm, be).astype(jnp.float32)
    ).transpose(0, 2, 1)
    logf = jax.nn.log_sigmoid(
        linear(x, p["w_fgate"], qm, be).astype(jnp.float32)
    ).transpose(0, 2, 1)
    valid = (jnp.arange(s) < n_valid)[None, None, :]      # (1, 1, S)
    logf = jnp.where(valid, logf, 0.0)
    logi = jnp.where(valid, logi, -jnp.inf)

    L = min(_MLSTM_CHUNK, s)
    assert s % L == 0, f"seq {s} not divisible by mLSTM chunk {L}"
    nc = s // L

    def to_chunks(t):
        return t.reshape(b, h_heads, nc, L, *t.shape[3:]).transpose(2, 0, 1, 3, *range(4, t.ndim + 1))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    fic = logi.reshape(b, h_heads, nc, L).transpose(2, 0, 1, 3)
    ffc = logf.reshape(b, h_heads, nc, L).transpose(2, 0, 1, 3)

    def body(carry, xs):
        C, n = carry
        qi, ki, vi, lfi, lii = xs
        h, C1, n1 = _mlstm_chunk_math(qi, ki, vi, lfi, lii, C, n)
        return (C1, n1), h

    (C_f, n_f), hs = jax.lax.scan(body, (state["C"], state["n"]),
                                  (qc, kc, vc, ffc, fic))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, h_heads, s, dh)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, d)
    o = jax.nn.sigmoid(linear(x, p["w_ogate"], qm, be).astype(jnp.float32))
    out = linear((o * h).astype(x.dtype), p["w_out"], qm, be)
    return out, {"C": C_f, "n": n_f}


def mlstm_decode(x_t, p, cfg: ModelConfig, state):
    """One step recurrent mLSTM. state: {"C": (B,H,dh,dh), "n": (B,H,dh)}."""
    qm, be = cfg.quant_mode, cfg.gemm_backend
    b, _, d = x_t.shape
    h_heads = cfg.n_heads
    dh = d // h_heads

    def heads(t):
        return t.reshape(b, h_heads, dh).astype(jnp.float32)

    q = heads(linear(x_t, p["wq"], qm, be)[:, 0]) * (dh ** -0.5)
    k = heads(linear(x_t, p["wk"], qm, be)[:, 0]) * (dh ** -0.5)
    v = heads(linear(x_t, p["wv"], qm, be)[:, 0])
    i = jax.nn.sigmoid(linear(x_t, p["w_igate"], qm, be).astype(jnp.float32))[:, 0][..., None]
    f = jax.nn.sigmoid(linear(x_t, p["w_fgate"], qm, be).astype(jnp.float32))[:, 0][..., None]
    C = f[..., None] * state["C"] + (i * k)[..., :, None] * v[..., None, :]
    n = f * state["n"] + i * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))[..., None], 1.0)
    h = (num / den).reshape(b, 1, d)
    o = jax.nn.sigmoid(linear(x_t, p["w_ogate"], qm, be).astype(jnp.float32))
    out = linear((o * h).astype(x_t.dtype), p["w_out"], qm, be)
    return out, {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory cell with recurrent block-diagonal weights)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    return {
        "w_zifo": init_linear(ks[0], d, 4 * d),
        "r_zifo": truncated_normal_init(ks[1], (4, h, dh, dh), scale=0.02),
        "w_out": init_linear(ks[2], d, d),
    }


def _slstm_step(p, cfg, carry, zifo_t):
    """carry: (c, n, h) each (B, H, dh); zifo_t: (B, 4, H, dh) pre-activations."""
    c, n, h = carry
    rec = jnp.einsum("bhd,ghde->bghe", h, p["r_zifo"].astype(jnp.float32))
    z_t, i_t, f_t, o_t = [zifo_t[:, g] + rec[:, g] for g in range(4)]
    z = jnp.tanh(z_t)
    i = jax.nn.sigmoid(i_t)
    f = jax.nn.sigmoid(f_t)
    o = jax.nn.sigmoid(o_t)
    c1 = f * c + i * z
    n1 = f * n + i
    h1 = o * c1 / jnp.maximum(n1, 1e-6)
    return (c1, n1, h1), h1


def slstm_block(x, p, cfg: ModelConfig, state=None):
    qm, be = cfg.quant_mode, cfg.gemm_backend
    b, s, d = x.shape
    hh = cfg.n_heads
    dh = d // hh
    zifo = linear(x, p["w_zifo"], qm, be).astype(jnp.float32).reshape(b, s, 4, hh, dh)
    if state is None:
        zeros = jnp.zeros((b, hh, dh), jnp.float32)
        carry = (zeros, zeros, zeros)
    else:
        carry = (state["c"], state["n"], state["h"])

    def step(carry, z_t):
        return _slstm_step(p, cfg, carry, z_t)

    (c, n, h_last), hs = jax.lax.scan(step, carry, zifo.transpose(1, 0, 2, 3, 4))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = linear(h, p["w_out"], qm, be)
    new_state = None if state is None else {"c": c, "n": n, "h": h_last}
    return out, new_state


def slstm_chunk(x, p, cfg: ModelConfig, state, n_valid):
    """Chunked prefill step: (1, C, d) chunk, first ``n_valid`` rows real.
    The scan is inherently sequential, so masking is a per-step carry
    freeze: pad steps compute and discard, keeping the carried (c, n, h)
    bitwise the exact-length run's (identical op sequence on valid rows)."""
    qm, be = cfg.quant_mode, cfg.gemm_backend
    b, s, d = x.shape
    hh = cfg.n_heads
    dh = d // hh
    zifo = linear(x, p["w_zifo"], qm, be).astype(jnp.float32).reshape(b, s, 4, hh, dh)
    carry0 = (state["c"], state["n"], state["h"])

    def step(carry, xs):
        z_t, t = xs
        stepped, h1 = _slstm_step(p, cfg, carry, z_t)
        keep = t < n_valid
        return tuple(jnp.where(keep, sc, c)
                     for sc, c in zip(stepped, carry)), h1

    (c, n, h_last), hs = jax.lax.scan(
        step, carry0,
        (zifo.transpose(1, 0, 2, 3, 4), jnp.arange(s, dtype=jnp.int32)))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = linear(h, p["w_out"], qm, be)
    return out, {"c": c, "n": n, "h": h_last}


def slstm_decode(x_t, p, cfg: ModelConfig, state):
    qm, be = cfg.quant_mode, cfg.gemm_backend
    b, _, d = x_t.shape
    hh = cfg.n_heads
    dh = d // hh
    zifo = linear(x_t, p["w_zifo"], qm, be).astype(jnp.float32).reshape(b, 4, hh, dh)
    carry = (state["c"], state["n"], state["h"])
    (c, n, h), h_out = _slstm_step(p, cfg, carry, zifo)
    out = linear(h_out.reshape(b, 1, d).astype(x_t.dtype), p["w_out"], qm, be)
    return out, {"c": c, "n": n, "h": h}
