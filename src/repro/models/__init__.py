from repro.models.model import (
    init_params,
    param_shapes,
    forward,
    lm_loss,
    prefill,
    decode_step,
    init_cache,
    cache_shapes,
)

__all__ = [
    "init_params",
    "param_shapes",
    "forward",
    "lm_loss",
    "prefill",
    "decode_step",
    "init_cache",
    "cache_shapes",
]
