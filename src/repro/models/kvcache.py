"""Decode-time caches for every block kind.

Cache pytrees mirror the parameter layout (per-slot stacked along the
scanned period axis) so ``lax.scan`` can thread them through the stack:

* ``attn``       -> {"k","v"}: (B, S_cache, H_kv, D); local_attn uses a
                    ring buffer of S_cache == window (O(1) memory at 500k).
* ``mla``        -> {"ckv","kr"}: compressed latent cache (the MLA win).
* ``rglru``      -> {"h","conv"}: O(1) recurrent state.
* ``mlstm``      -> {"C","n"}: matrix memory, O(1) in sequence length.
* ``slstm``      -> {"c","n","h"}.

``paged_block_cache_shape`` gives the paged layout (repro/paging/): the
same payloads re-cut into a global ``(n_pages, page_size, ...)`` pool that
per-lane block tables index, for kinds whose cache grows with sequence
length; O(1)/O(window) kinds keep the per-lane layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE


def block_cache_shape(kind: str, cfg: ModelConfig, batch: int, cache_len: int):
    """ShapeDtypeStructs for one layer's cache of the given kind."""
    hd = cfg.resolved_head_dim
    f32 = jnp.float32
    if kind in ("attn", "local_attn", "moe", "dense_ffn_layer"):
        s = cache_len
        if kind == "local_attn" and cfg.sliding_window is not None:
            s = min(cache_len, cfg.sliding_window)
        shp = (batch, s, cfg.n_kv_heads, hd)
        if cfg.kv_cache_dtype == "int8":
            # SPOGA-style byte-size storage: int8 payload + per-(pos, head)
            # scale — halves the dominant HBM stream of long-context decode.
            return {
                "k": jax.ShapeDtypeStruct(shp, jnp.int8),
                "v": jax.ShapeDtypeStruct(shp, jnp.int8),
                "k_scale": jax.ShapeDtypeStruct(shp[:3], jnp.float32),
                "v_scale": jax.ShapeDtypeStruct(shp[:3], jnp.float32),
            }
        return {
            "k": jax.ShapeDtypeStruct(shp, COMPUTE_DTYPE),
            "v": jax.ShapeDtypeStruct(shp, COMPUTE_DTYPE),
        }
    if kind == "mla":
        m = cfg.mla
        return {
            "ckv": jax.ShapeDtypeStruct((batch, cache_len, m.kv_lora_rank), COMPUTE_DTYPE),
            "kr": jax.ShapeDtypeStruct((batch, cache_len, m.qk_rope_head_dim), COMPUTE_DTYPE),
        }
    if kind == "rglru":
        lru = cfg.lru_width or cfg.d_model
        return {
            "h": jax.ShapeDtypeStruct((batch, lru), f32),
            "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, lru), f32),
        }
    if kind == "mlstm":
        dh = cfg.d_model // cfg.n_heads
        return {
            "C": jax.ShapeDtypeStruct((batch, cfg.n_heads, dh, dh), f32),
            "n": jax.ShapeDtypeStruct((batch, cfg.n_heads, dh), f32),
        }
    if kind == "slstm":
        dh = cfg.d_model // cfg.n_heads
        s = jax.ShapeDtypeStruct((batch, cfg.n_heads, dh), f32)
        return {"c": s, "n": s, "h": s}
    raise ValueError(f"no cache for block kind {kind!r}")


def paged_block_cache_shape(kind: str, cfg: ModelConfig, batch: int,
                            cache_len: int, n_pages: int, page_size: int):
    """ShapeDtypeStructs for one layer's *paged* cache of the given kind.

    Attention-family kinds store KV in a global page pool shared by every
    lane — ``(n_pages, page_size, H_kv, D)`` payloads (``kp``/``vp``, plus
    ``kp_scale``/``vp_scale`` planes for the int8 byte-size variant) indexed
    through per-lane block tables.  MLA pages hold the compressed latents
    (``ckvp``/``krp``).  Kinds whose state is already O(1) or O(window) per
    lane keep their per-lane layout from :func:`block_cache_shape`:

    * recurrent state (rglru/mlstm/slstm) — nothing to page;
    * local_attn ring buffers — a window-sized ring is its own best
      packing; paging it would only re-introduce indirection.
    """
    hd = cfg.resolved_head_dim
    if kind in ("attn", "moe", "dense_ffn_layer") or (
        kind == "local_attn" and cfg.sliding_window is None
    ):
        shp = (n_pages, page_size, cfg.n_kv_heads, hd)
        if cfg.kv_cache_dtype == "int8":
            return {
                "kp": jax.ShapeDtypeStruct(shp, jnp.int8),
                "vp": jax.ShapeDtypeStruct(shp, jnp.int8),
                "kp_scale": jax.ShapeDtypeStruct(shp[:3], jnp.float32),
                "vp_scale": jax.ShapeDtypeStruct(shp[:3], jnp.float32),
            }
        return {
            "kp": jax.ShapeDtypeStruct(shp, COMPUTE_DTYPE),
            "vp": jax.ShapeDtypeStruct(shp, COMPUTE_DTYPE),
        }
    if kind == "mla":
        m = cfg.mla
        return {
            "ckvp": jax.ShapeDtypeStruct(
                (n_pages, page_size, m.kv_lora_rank), COMPUTE_DTYPE),
            "krp": jax.ShapeDtypeStruct(
                (n_pages, page_size, m.qk_rope_head_dim), COMPUTE_DTYPE),
        }
    # per-lane kinds ride the slot layout unchanged
    return block_cache_shape(kind, cfg, batch, cache_len)


def zeros_like_shapes(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), tree)
