"""Attention variants: GQA/MQA/MHA, sliding-window, MLA, cross-attention.

Long sequences use a query-chunked exact attention (``lax.scan`` over query
blocks, fp32 softmax) so no S x S score matrix is ever materialized — the
XLA-friendly equivalent of a flash kernel, used by both the CPU dry-run and
as the reference for any future fused TPU attention kernel.  Local
(sliding-window) blocks additionally slice only the KV band each chunk
needs, making them O(S * window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, init_linear, linear, rmsnorm

NEG_INF = -1e30


def _pick_chunk(s: int) -> int:
    for c in (512, 256, 128, 64):
        if s % c == 0 and s > c:
            return c
    return s


# ---------------------------------------------------------------------------
# Standard (GQA) attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, d, cfg.n_heads * hd),
        "wk": init_linear(k2, d, cfg.n_kv_heads * hd),
        "wv": init_linear(k3, d, cfg.n_kv_heads * hd),
        "wo": init_linear(k4, cfg.n_heads * hd, d),
    }


def _attend_chunk(q, k, v, q_offset, kv_offset, causal, window):
    """q: (B, C, G, Hkv, D); k/v: (B, S, Hkv, D). Exact fp32 softmax."""
    d = q.shape[-1]
    # bf16 operands, f32 accumulation: never materializes an f32 copy of
    # the (B, S, H, D) keys (at 32k-decode that copy alone is GiBs/device).
    scores = jnp.einsum("bcghd,bshd->bcghs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (d ** -0.5)
    qpos = q_offset + jnp.arange(q.shape[1])[:, None]
    kpos = kv_offset + jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bcghs,bshd->bcghd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def multihead_attention(q, k, v, *, causal=True, window=None):
    """q: (B, Sq, Hq, D), k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D).

    Query-chunked, memory O(C x Skv); local attention slices the KV band.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA: qk_dim != v_head_dim)
    g = hq // hkv
    qg = q.reshape(b, sq, g, hkv, d)
    chunk = _pick_chunk(sq)
    if chunk == sq:
        out = _attend_chunk(qg, k, v, 0, 0, causal, window)
        return out.reshape(b, sq, hq, dv)

    n_chunks = sq // chunk
    qs = qg.reshape(b, n_chunks, chunk, g, hkv, d).transpose(1, 0, 2, 3, 4, 5)

    # NB: chunk bodies are rematerialized — without this, the scan's backward
    # saves every chunk's softmax probs, i.e. the full S x S score matrix.
    if window is not None and sq == skv:
        # Local attention: each chunk only needs KV in [start-window, start+chunk).
        band = window + chunk

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def body(_, args):
            qc, idx = args
            start = jnp.maximum(idx * chunk - window, 0)
            # clamp so the static-size band stays in bounds
            start = jnp.minimum(start, skv - band) if skv >= band else 0
            kc = jax.lax.dynamic_slice_in_dim(k, start, min(band, skv), axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, min(band, skv), axis=1)
            out = _attend_chunk(qc, kc, vc, idx * chunk, start, causal, window)
            return None, out

        _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n_chunks)))
    else:

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def body(_, args):
            qc, idx = args
            out = _attend_chunk(qc, k, v, idx * chunk, 0, causal, window)
            return None, out

        _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, dv)
    return out


def _constrain_heads(t):
    """Pin (B, S, H, D) to batch-over-DP x heads-over-"model" (Megatron TP).

    Without the explicit constraint the partitioner reshards the chunked
    attention's 6-D reshapes through an 'involuntary full
    rematerialization' (replicate-then-repartition) path."""
    try:
        from jax.sharding import PartitionSpec as P
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty or t.ndim != 4:
            return t
        dp = tuple(a for a in m.axis_names if a in ("pod", "data"))
        dp_size = 1
        for a in dp:
            dp_size *= m.shape[a]
        model = m.shape.get("model", 1)
        first = dp if (dp and t.shape[0] % dp_size == 0) else None
        heads = "model" if t.shape[2] % model == 0 else None
        return jax.lax.with_sharding_constraint(t, P(first, None, heads, None))
    except Exception:  # pragma: no cover
        return t


def attention_block(x, p, cfg: ModelConfig, positions, *, causal=True, window=None):
    """Full self-attention over x: projections + RoPE + attend + output."""
    b, s, _ = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q = linear(x, p["wq"], qm, be).reshape(b, s, hq, hd)
    k = linear(x, p["wk"], qm, be).reshape(b, s, hkv, hd)
    v = linear(x, p["wv"], qm, be).reshape(b, s, hkv, hd)
    q = _constrain_heads(apply_rope(q, positions, cfg.rope_theta))
    k = _constrain_heads(apply_rope(k, positions, cfg.rope_theta))
    v = _constrain_heads(v)
    out = multihead_attention(q, k, v, causal=causal, window=window)
    return linear(out.reshape(b, s, hq * hd), p["wo"], qm, be), (k, v)


def quantize_kv(t):
    """(B, S, H, D) -> int8 payload + per-(b, s, h) f32 scale (SPOGA-style
    byte-size cache storage; halves decode's dominant HBM stream)."""
    tf = t.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(tf), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(tf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _decode_qkv(x_t, p, cfg: ModelConfig, pos):
    """Shared decode-side projections + RoPE. Returns q, k, v (B, 1, H, D)."""
    b = x_t.shape[0]
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q = linear(x_t, p["wq"], qm, be).reshape(b, 1, hq, hd)
    k = linear(x_t, p["wk"], qm, be).reshape(b, 1, hkv, hd)
    v = linear(x_t, p["wv"], qm, be).reshape(b, 1, hkv, hd)
    posb = pos[:, None]
    q = _constrain_heads(apply_rope(q, posb, cfg.rope_theta))
    k = _constrain_heads(apply_rope(k, posb, cfg.rope_theta))
    return q, k, _constrain_heads(v)


def _verify_qkv(x, p, cfg: ModelConfig, pos):
    """W-row verify-window projections + RoPE. x: (B, W, d); pos: (B,) is
    the window's first absolute position. Returns q, k, v (B, W, H, D)."""
    b, w, _ = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q = linear(x, p["wq"], qm, be).reshape(b, w, hq, hd)
    k = linear(x, p["wk"], qm, be).reshape(b, w, hkv, hd)
    v = linear(x, p["wv"], qm, be).reshape(b, w, hkv, hd)
    positions = pos[:, None] + jnp.arange(w)[None, :]
    q = _constrain_heads(apply_rope(q, positions, cfg.rope_theta))
    k = _constrain_heads(apply_rope(k, positions, cfg.rope_theta))
    return q, k, _constrain_heads(v)


def _verify_valid(pos, w, smax):
    """(B, W, S) causal mask for the verify window: query row c of lane b
    sits at absolute position pos[b] + c and may attend kpos <= pos[b] + c
    — causal within the window, full paged/slot history before it."""
    row_pos = pos[:, None] + jnp.arange(w)[None, :]
    return jnp.arange(smax)[None, None, :] <= row_pos[:, :, None]


def _decode_attend(qg, k_cache, v_cache, k_scale, v_scale, valid):
    """Single-token attention math over a logically-contiguous KV view.

    qg: (B, C, G, Hkv, D); k_cache/v_cache: (B, S, Hkv, D) payloads
    (int8 when scales are given); valid: (B, S) bool, or (B, C, S) for a
    per-query-row mask (the speculative verify window, where row c may
    attend one position more than row c-1).  Shared by the slot path and
    the paged jnp twin so the two lower to the same graph — that
    structural identity is what makes paged serving bitwise
    output-invisible when the gathered view matches the slot cache_len.
    """
    hd = qg.shape[-1]
    int8_cache = k_scale is not None
    # int8 payload feeds the dot (fused dequant / MXU int8 path); the
    # per-(pos, head) scale factors out of the D-contraction.
    k_op = k_cache.astype(qg.dtype) if int8_cache else k_cache
    scores = jnp.einsum("bcghd,bshd->bcghs", qg, k_op,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    if int8_cache:
        scores = scores * k_scale.transpose(0, 2, 1)[:, None, None, :, :]
    if valid.ndim == 2:
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    else:
        scores = jnp.where(valid[:, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if int8_cache:
        probs = probs * v_scale.transpose(0, 2, 1)[:, None, None, :, :]
        v_op = v_cache.astype(qg.dtype)
    else:
        v_op = v_cache
    return jnp.einsum("bcghs,bshd->bcghd", probs.astype(v_op.dtype), v_op,
                      preferred_element_type=jnp.float32)


def attention_decode(x_t, p, cfg: ModelConfig, cache, pos, *, window=None):
    """One-token decode. x_t: (B, 1, d); cache {"k","v"[,"k_scale","v_scale"]}
    payloads (B, Smax, Hkv, D); pos (B,). Returns (out, new cache dict)."""
    b = x_t.shape[0]
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    int8_cache = cfg.kv_cache_dtype == "int8"
    q, k, v = _decode_qkv(x_t, p, cfg, pos)

    k_cache, v_cache = cache["k"], cache["v"]
    smax = k_cache.shape[1]
    if window is not None and smax > window:
        # Ring-buffer local cache: slot = pos % window over a window-sized cache
        raise ValueError("local decode cache must be allocated with Smax == window")
    slot = pos % smax if window is not None else pos

    def upd(c, t, i):
        return jax.vmap(
            lambda cc, tt, ii: jax.lax.dynamic_update_slice_in_dim(cc, tt, ii, axis=0)
        )(c, t, i)

    new_cache = dict(cache)
    k_scale = v_scale = None
    if int8_cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache, v_cache = upd(k_cache, kq, slot), upd(v_cache, vq, slot)
        k_scale = upd(cache["k_scale"], ks, slot)
        v_scale = upd(cache["v_scale"], vs, slot)
        new_cache.update(k_scale=k_scale, v_scale=v_scale)
    else:
        k_cache, v_cache = upd(k_cache, k, slot), upd(v_cache, v, slot)
    new_cache.update(k=k_cache, v=v_cache)

    g = hq // hkv
    qg = q.reshape(b, 1, g, hkv, hd)
    kpos = jnp.arange(smax)[None, :]
    if window is not None:
        # Ring cache (smax == window): before the ring wraps only slots
        # <= pos hold data; after wrapping every slot is within the window.
        valid = jnp.where(pos[:, None] >= smax, jnp.ones_like(kpos, bool), kpos <= pos[:, None])
    else:
        valid = kpos <= pos[:, None]
    out = _decode_attend(qg, k_cache, v_cache, k_scale, v_scale, valid)
    out = out.astype(x_t.dtype).reshape(b, 1, hq * hd)
    return linear(out, p["wo"], qm, be), new_cache


def attention_verify(x, p, cfg: ModelConfig, cache, pos):
    """W-token speculative verify over a slot cache.

    x: (B, W, d) — the last accepted token plus the drafted window; pos:
    (B,) absolute position of the window's first row.  Writes all W K/V
    rows at pos..pos+W-1 (rows past the eventually-accepted prefix are
    garbage, but the next verify/decode step overwrites them before any
    query can attend them — the same argument that keeps chunked-prefill
    padding output-invisible), then attends the slot history under the
    per-row causal mask.  Row-for-row this lowers to the same dot products
    as W sequential :func:`attention_decode` calls, which is what makes
    greedy speculative output bitwise identical to plain decode.
    Windowed (ring-buffer) local caches are unsupported here — the spec
    stack gates on ``chunkable(cfg)``.
    """
    b, w, _ = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    int8_cache = cfg.kv_cache_dtype == "int8"
    q, k, v = _verify_qkv(x, p, cfg, pos)

    k_cache, v_cache = cache["k"], cache["v"]
    smax = k_cache.shape[1]

    def upd(c, t, i):
        return jax.vmap(
            lambda cc, tt, ii: jax.lax.dynamic_update_slice_in_dim(cc, tt, ii, axis=0)
        )(c, t, i)

    new_cache = dict(cache)
    k_scale = v_scale = None
    if int8_cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache, v_cache = upd(k_cache, kq, pos), upd(v_cache, vq, pos)
        k_scale = upd(cache["k_scale"], ks, pos)
        v_scale = upd(cache["v_scale"], vs, pos)
        new_cache.update(k_scale=k_scale, v_scale=v_scale)
    else:
        k_cache, v_cache = upd(k_cache, k, pos), upd(v_cache, v, pos)
    new_cache.update(k=k_cache, v=v_cache)

    qg = q.reshape(b, w, hq // hkv, hkv, hd)
    valid = _verify_valid(pos, w, smax)
    out = _decode_attend(qg, k_cache, v_cache, k_scale, v_scale, valid)
    out = out.astype(x.dtype).reshape(b, w, hq * hd)
    return linear(out, p["wo"], qm, be), new_cache


# ---------------------------------------------------------------------------
# Paged attention (block-table KV cache; repro/paging/)
# ---------------------------------------------------------------------------

def _resolve_paged_impl(cfg: ModelConfig) -> str:
    if cfg.paged_attn_impl is not None:
        return cfg.paged_attn_impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _write_page(tables, pos, page_size, active):
    """(physical page, in-page offset) each lane's next token writes to.

    Inactive lanes are redirected to the reserved trash page 0: unlike the
    slot cache, a lane's pages return to the shared pool on eviction, so a
    garbage write through a stale table entry would corrupt whichever
    request owns that page now.
    """
    pg = jnp.take_along_axis(tables, (pos // page_size)[:, None], axis=1,
                             mode="clip")[:, 0]
    off = pos % page_size
    if active is not None:
        pg = jnp.where(active, pg, 0)
        off = jnp.where(active, off, 0)
    return pg, off


def _gather_pages(pool, tables):
    """(n_pages, page_size, ...) pool + (B, P) tables -> (B, P*page_size, ...)
    logically-contiguous per-lane view (gather; the Pallas kernel instead
    streams pages directly from the pool)."""
    b, n_tbl = tables.shape
    g = pool[tables]
    return g.reshape((b, n_tbl * pool.shape[1]) + pool.shape[2:])


def paged_attention_decode(x_t, p, cfg: ModelConfig, cache, pos, tables, *,
                           active=None):
    """One-token decode over this layer's page pools.

    cache: {"kp","vp"[,"kp_scale","vp_scale"]} with payloads
    (n_pages, page_size, Hkv, D); tables: (B, P) int32 block tables;
    pos: (B,).  The new token's K/V is scattered into page
    ``tables[b, pos // page_size]`` and attention runs over the gathered
    logical view (jnp twin) or streams pages inside the Pallas kernel.
    With ``P * page_size == cache_len`` the jnp twin is bitwise identical
    to :func:`attention_decode` on a slot cache.
    """
    b = x_t.shape[0]
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    int8_cache = "kp_scale" in cache
    q, k, v = _decode_qkv(x_t, p, cfg, pos)

    kp, vp = cache["kp"], cache["vp"]
    page_size = kp.shape[1]
    pg, off = _write_page(tables, pos, page_size, active)

    new_cache = dict(cache)
    if int8_cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        kp, vp = kp.at[pg, off].set(kq[:, 0]), vp.at[pg, off].set(vq[:, 0])
        kps = cache["kp_scale"].at[pg, off].set(ks[:, 0])
        vps = cache["vp_scale"].at[pg, off].set(vs[:, 0])
        new_cache.update(kp_scale=kps, vp_scale=vps)
    else:
        kp = kp.at[pg, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[pg, off].set(v[:, 0].astype(vp.dtype))
    new_cache.update(kp=kp, vp=vp)

    g = hq // hkv
    impl = _resolve_paged_impl(cfg)
    if impl == "jnp":
        qg = q.reshape(b, 1, g, hkv, hd)
        smax = tables.shape[1] * page_size
        k_all, v_all = _gather_pages(kp, tables), _gather_pages(vp, tables)
        ks_all = _gather_pages(kps, tables) if int8_cache else None
        vs_all = _gather_pages(vps, tables) if int8_cache else None
        valid = jnp.arange(smax)[None, :] <= pos[:, None]
        out = _decode_attend(qg, k_all, v_all, ks_all, vs_all, valid)
    else:
        from repro.kernels.paged_attention import paged_attention

        qk = q[:, 0].reshape(b, g, hkv, hd).transpose(0, 2, 1, 3)  # (B,Hkv,G,D)
        out = paged_attention(
            qk, kp, vp, tables, pos + 1,
            k_scale=new_cache.get("kp_scale"),
            v_scale=new_cache.get("vp_scale"),
            interpret=(impl == "pallas_interpret"),
        )
        out = out.transpose(0, 2, 1, 3)[:, None]  # (B, 1, G, Hkv, D)
    out = out.astype(x_t.dtype).reshape(b, 1, hq * hd)
    return linear(out, p["wo"], qm, be), new_cache


def _write_pages(tables, pos, w, page_size, active):
    """Multi-row variant of :func:`_write_page`: (B, W) page/offset pairs
    for the verify-window rows ``pos + [0, w)``.  Inactive lanes redirect
    to the reserved trash page 0 for the same pool-safety reason."""
    idx = pos[:, None] + jnp.arange(w)[None, :]                # (B, W)
    pg = jnp.take_along_axis(tables, idx // page_size, axis=1, mode="clip")
    off = idx % page_size
    if active is not None:
        pg = jnp.where(active[:, None], pg, 0)
        off = jnp.where(active[:, None], off, 0)
    return pg, off


def paged_attention_verify(x, p, cfg: ModelConfig, cache, pos, tables, *,
                           active=None):
    """W-token speculative verify over this layer's page pools.

    The paged twin of :func:`attention_verify`: scatters the window's W
    K/V rows through the block table (engine capacity checks reserve the
    overshoot pages up front) and attends the gathered logical view under
    the per-row causal mask.  Always the jnp gather twin — the Pallas
    decode kernel is single-query, and the bitwise greedy contract is
    anchored to the gather path.
    """
    b, w, _ = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    int8_cache = "kp_scale" in cache
    q, k, v = _verify_qkv(x, p, cfg, pos)

    kp, vp = cache["kp"], cache["vp"]
    page_size = kp.shape[1]
    pg, off = _write_pages(tables, pos, w, page_size, active)

    new_cache = dict(cache)
    kps = vps = None
    if int8_cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        kp, vp = kp.at[pg, off].set(kq), vp.at[pg, off].set(vq)
        kps = cache["kp_scale"].at[pg, off].set(ks)
        vps = cache["vp_scale"].at[pg, off].set(vs)
        new_cache.update(kp_scale=kps, vp_scale=vps)
    else:
        kp = kp.at[pg, off].set(k.astype(kp.dtype))
        vp = vp.at[pg, off].set(v.astype(vp.dtype))
    new_cache.update(kp=kp, vp=vp)

    qg = q.reshape(b, w, hq // hkv, hkv, hd)
    smax = tables.shape[1] * page_size
    k_all, v_all = _gather_pages(kp, tables), _gather_pages(vp, tables)
    ks_all = _gather_pages(kps, tables) if int8_cache else None
    vs_all = _gather_pages(vps, tables) if int8_cache else None
    valid = _verify_valid(pos, w, smax)
    out = _decode_attend(qg, k_all, v_all, ks_all, vs_all, valid)
    out = out.astype(x.dtype).reshape(b, w, hq * hd)
    return linear(out, p["wo"], qm, be), new_cache


def _chunk_pages(tables_row, start, chunk, page_size):
    """Page/offset pairs for chunk positions ``start + [0, chunk)`` of one
    lane. tables_row: (1, P); start: (1,) int32. Returns ((C,), (C,))."""
    idx = start[:, None] + jnp.arange(chunk)[None, :]          # (1, C)
    pg = jnp.take_along_axis(tables_row, idx // page_size, axis=1, mode="clip")
    return pg[0], (idx % page_size)[0]


def attention_chunk(x, p, cfg: ModelConfig, cache, tables_row, start, *,
                    positions):
    """Chunked-prefill extend of one lane's paged KV (B == 1).

    x: (1, C, d) chunk hidden states; cache: this layer's page pools;
    tables_row: (1, P) block-table row; start: (1,) absolute position of
    the chunk's first token; positions: (1, C) for RoPE.  Writes the
    chunk's K/V into the lane's pages, then attends gathered prefix +
    chunk under the standard causal mask.  Prior chunks' rows are bitwise
    what full prefill computes (the bf16 cache roundtrip is lossless) and
    a padded tail is overwritten by the next chunk before any query can
    attend it, so chunking stays output-invisible.
    """
    b, cs, _ = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    int8_cache = "kp_scale" in cache
    q = linear(x, p["wq"], qm, be).reshape(b, cs, hq, hd)
    k = linear(x, p["wk"], qm, be).reshape(b, cs, hkv, hd)
    v = linear(x, p["wv"], qm, be).reshape(b, cs, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    kp, vp = cache["kp"], cache["vp"]
    page_size = kp.shape[1]
    pg, off = _chunk_pages(tables_row, start, cs, page_size)

    new_cache = dict(cache)
    if int8_cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        kp, vp = kp.at[pg, off].set(kq[0]), vp.at[pg, off].set(vq[0])
        kps = cache["kp_scale"].at[pg, off].set(ks[0])
        vps = cache["vp_scale"].at[pg, off].set(vs[0])
        new_cache.update(kp_scale=kps, vp_scale=vps)
    else:
        kp = kp.at[pg, off].set(k[0].astype(kp.dtype))
        vp = vp.at[pg, off].set(v[0].astype(vp.dtype))
    new_cache.update(kp=kp, vp=vp)

    k_all, v_all = _gather_pages(kp, tables_row), _gather_pages(vp, tables_row)
    if int8_cache:
        # prefill-side chunks attend the dequantized pages (tolerance path;
        # the exactness argument applies to the full-precision pools)
        ks_all = _gather_pages(kps, tables_row)
        vs_all = _gather_pages(vps, tables_row)
        k_all = (k_all.astype(jnp.float32) * ks_all[..., None]).astype(x.dtype)
        v_all = (v_all.astype(jnp.float32) * vs_all[..., None]).astype(x.dtype)
    qg = q.reshape(b, cs, hq // hkv, hkv, hd)
    out = _attend_chunk(qg, k_all, v_all, start[0], 0, True, None)
    out = out.astype(x.dtype).reshape(b, cs, hq * hd)
    return linear(out, p["wo"], qm, be), new_cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ModelConfig):
    return init_attention(key, cfg)


def cross_attention_block(x, enc_kv, p, cfg: ModelConfig):
    """x: (B, St, d) decoder states; enc_kv: precomputed (k, v) from encoder."""
    b, s, _ = x.shape
    hd, hq = cfg.resolved_head_dim, cfg.n_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q = linear(x, p["wq"], qm, be).reshape(b, s, hq, hd)
    k, v = enc_kv
    out = multihead_attention(q, k, v, causal=False, window=None)
    return linear(out.reshape(b, s, hq * hd), p["wo"], qm, be)


def encode_cross_kv(enc_out, p, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    qm, be = cfg.quant_mode, cfg.gemm_backend
    k = linear(enc_out, p["wk"], qm, be).reshape(b, s, hkv, hd)
    v = linear(enc_out, p["wv"], qm, be).reshape(b, s, hkv, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": init_linear(ks[0], d, m.q_lora_rank),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "w_uq": init_linear(ks[1], m.q_lora_rank, h * qk_dim),
        "w_dkv": init_linear(ks[2], d, m.kv_lora_rank),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "w_uk": init_linear(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim),
        "w_uv": init_linear(ks[4], m.kv_lora_rank, h * m.v_head_dim),
        "w_kr": init_linear(ks[5], d, m.qk_rope_head_dim),
        "wo": init_linear(ks[6], h * m.v_head_dim, d),
    }


def _mla_qkv(x, p, cfg, positions):
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    qm, be = cfg.quant_mode, cfg.gemm_backend
    cq = rmsnorm(linear(x, p["w_dq"], qm, be), p["q_norm"], cfg.norm_eps)
    q = linear(cq, p["w_uq"], qm, be).reshape(b, s, h, -1)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = rmsnorm(linear(x, p["w_dkv"], qm, be), p["kv_norm"], cfg.norm_eps)
    k_rope = linear(x, p["w_kr"], qm, be).reshape(b, s, 1, m.qk_rope_head_dim)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_block(x, p, cfg: ModelConfig, positions):
    """Training / prefill MLA (non-absorbed: reconstruct K, V per token)."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(x, p, cfg, positions)
    k_nope = linear(c_kv, p["w_uk"], qm, be).reshape(b, s, h, m.qk_nope_head_dim)
    v = linear(c_kv, p["w_uv"], qm, be).reshape(b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))], axis=-1)
    out = multihead_attention(q, k, v, causal=True)
    out = linear(out.reshape(b, s, h * m.v_head_dim), p["wo"], qm, be)
    return out, (c_kv, k_rope.reshape(b, s, m.qk_rope_head_dim))


def _mla_attend(q_nope, q_rope, ckv_view, kr_view, pos, p, cfg: ModelConfig):
    """Absorbed-matmul MLA attention over a logically-contiguous latent view.

    ckv_view: (B, S, kv_lora_rank); kr_view: (B, S, rope_dim).  Shared by
    the slot path and the paged gather twin (same structural-identity
    argument as ``_decode_attend``).  q_nope/q_rope may carry C > 1 query
    rows (the speculative verify window); row c then attends positions
    <= pos + c. Returns (B, C, H * v_head_dim).
    """
    m, h = cfg.mla, cfg.n_heads
    b = q_nope.shape[0]
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum(
        "bchd,lhd->bchl", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32)
    )  # (B,1,H,latent)
    scores = jnp.einsum("bchl,bsl->bchs", q_lat.astype(ckv_view.dtype), ckv_view,
                        preferred_element_type=jnp.float32)
    scores += jnp.einsum("bchr,bsr->bchs", q_rope.astype(kr_view.dtype), kr_view,
                         preferred_element_type=jnp.float32)
    scores *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    smax = ckv_view.shape[1]
    c = q_nope.shape[1]
    if c == 1:
        valid = jnp.arange(smax)[None, :] <= pos[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    else:
        row_pos = pos[:, None] + jnp.arange(c)[None, :]
        valid = jnp.arange(smax)[None, None, :] <= row_pos[:, :, None]
        scores = jnp.where(valid[:, :, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bchs,bsl->bchl", probs.astype(ckv_view.dtype), ckv_view,
                         preferred_element_type=jnp.float32)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bchl,lhv->bchv", out_lat, w_uv.astype(jnp.float32))
    return out.reshape(b, c, h * m.v_head_dim)


def mla_decode(x_t, p, cfg: ModelConfig, ckv_cache, krope_cache, pos):
    """Absorbed-matmul MLA decode: attention runs in the latent space, the
    cache holds only (c_kv, k_rope) — the MLA memory saving."""
    m = cfg.mla
    b = x_t.shape[0]
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_qkv(x_t, p, cfg, pos[:, None])

    ckv_cache = jax.vmap(
        lambda c, t, i: jax.lax.dynamic_update_slice_in_dim(c, t, i, axis=0)
    )(ckv_cache, c_kv_t, pos)
    krope_cache = jax.vmap(
        lambda c, t, i: jax.lax.dynamic_update_slice_in_dim(c, t, i, axis=0)
    )(krope_cache, k_rope_t.reshape(b, 1, m.qk_rope_head_dim), pos)

    out = _mla_attend(q_nope, q_rope, ckv_cache, krope_cache, pos, p, cfg)
    out = out.astype(x_t.dtype)
    return linear(out, p["wo"], qm, be), (ckv_cache, krope_cache)


def mla_verify(x, p, cfg: ModelConfig, ckv_cache, krope_cache, pos):
    """W-token speculative verify over the slot latent caches (the MLA
    twin of :func:`attention_verify`; same garbage-row-overwrite and
    row-for-row bitwise arguments)."""
    m = cfg.mla
    b, w, _ = x.shape
    qm, be = cfg.quant_mode, cfg.gemm_backend
    positions = pos[:, None] + jnp.arange(w)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(x, p, cfg, positions)

    ckv_cache = jax.vmap(
        lambda c, t, i: jax.lax.dynamic_update_slice_in_dim(c, t, i, axis=0)
    )(ckv_cache, c_kv, pos)
    krope_cache = jax.vmap(
        lambda c, t, i: jax.lax.dynamic_update_slice_in_dim(c, t, i, axis=0)
    )(krope_cache, k_rope.reshape(b, w, m.qk_rope_head_dim), pos)

    out = _mla_attend(q_nope, q_rope, ckv_cache, krope_cache, pos, p, cfg)
    out = out.astype(x.dtype)
    return linear(out, p["wo"], qm, be), (ckv_cache, krope_cache)


def mla_paged_decode(x_t, p, cfg: ModelConfig, cache, pos, tables, *,
                     active=None):
    """Absorbed MLA decode over latent page pools.

    cache: {"ckvp","krp"} with (n_pages, page_size, rank) payloads — the
    compressed latents are already the MLA memory saving; paging makes the
    *pool* shared across lanes.  jnp gather twin only (the latent view is
    rank-sized, far below the GQA KV stream the Pallas kernel targets).
    """
    m = cfg.mla
    b = x_t.shape[0]
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_qkv(x_t, p, cfg, pos[:, None])

    ckvp, krp = cache["ckvp"], cache["krp"]
    pg, off = _write_page(tables, pos, ckvp.shape[1], active)
    ckvp = ckvp.at[pg, off].set(c_kv_t[:, 0].astype(ckvp.dtype))
    krp = krp.at[pg, off].set(
        k_rope_t.reshape(b, m.qk_rope_head_dim).astype(krp.dtype))
    new_cache = dict(cache, ckvp=ckvp, krp=krp)

    ckv_view = _gather_pages(ckvp, tables)
    kr_view = _gather_pages(krp, tables)
    out = _mla_attend(q_nope, q_rope, ckv_view, kr_view, pos, p, cfg)
    out = out.astype(x_t.dtype)
    return linear(out, p["wo"], qm, be), new_cache


def mla_paged_verify(x, p, cfg: ModelConfig, cache, pos, tables, *,
                     active=None):
    """W-token speculative verify over the latent page pools (the paged
    twin of :func:`mla_verify`; gather path only, like
    :func:`mla_paged_decode`)."""
    m = cfg.mla
    b, w, _ = x.shape
    qm, be = cfg.quant_mode, cfg.gemm_backend
    positions = pos[:, None] + jnp.arange(w)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(x, p, cfg, positions)

    ckvp, krp = cache["ckvp"], cache["krp"]
    pg, off = _write_pages(tables, pos, w, ckvp.shape[1], active)
    ckvp = ckvp.at[pg, off].set(c_kv.astype(ckvp.dtype))
    krp = krp.at[pg, off].set(
        k_rope.reshape(b, w, m.qk_rope_head_dim).astype(krp.dtype))
    new_cache = dict(cache, ckvp=ckvp, krp=krp)

    ckv_view = _gather_pages(ckvp, tables)
    kr_view = _gather_pages(krp, tables)
    out = _mla_attend(q_nope, q_rope, ckv_view, kr_view, pos, p, cfg)
    out = out.astype(x.dtype)
    return linear(out, p["wo"], qm, be), new_cache


def mla_chunk(x, p, cfg: ModelConfig, cache, tables_row, start, *, positions):
    """Chunked-prefill extend of one lane's paged MLA latents (B == 1).

    Mirrors :func:`mla_block` (the non-absorbed prefill form): the chunk's
    latents are written to pages, then K/V are *recomputed from the
    gathered latents* via the up-projections — bitwise the values the full
    prefill computes, because the latent cache roundtrips bf16 losslessly
    and the up-projection is row-independent.  This keeps chunked MLA
    admission output-invisible even though decode later switches to the
    absorbed form (exactly like the unchunked prefill -> decode handoff).
    """
    m, h = cfg.mla, cfg.n_heads
    b, cs, _ = x.shape
    qm, be = cfg.quant_mode, cfg.gemm_backend
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(x, p, cfg, positions)

    ckvp, krp = cache["ckvp"], cache["krp"]
    pg, off = _chunk_pages(tables_row, start, cs, ckvp.shape[1])
    ckvp = ckvp.at[pg, off].set(c_kv[0].astype(ckvp.dtype))
    krp = krp.at[pg, off].set(
        k_rope.reshape(b, cs, m.qk_rope_head_dim)[0].astype(krp.dtype))
    new_cache = dict(cache, ckvp=ckvp, krp=krp)

    ckv_all = _gather_pages(ckvp, tables_row)                  # (1, L, rank)
    kr_all = _gather_pages(krp, tables_row)                    # (1, L, rope)
    smax = ckv_all.shape[1]
    k_nope = linear(ckv_all, p["w_uk"], qm, be).reshape(b, smax, h, m.qk_nope_head_dim)
    v_all = linear(ckv_all, p["w_uv"], qm, be).reshape(b, smax, h, m.v_head_dim)
    k_all = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(kr_all[:, :, None, :], (b, smax, h, m.qk_rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    qg = q.reshape(b, cs, 1, h, q.shape[-1])
    out = _attend_chunk(qg, k_all, v_all, start[0], 0, True, None)
    out = out.astype(x.dtype).reshape(b, cs, h * m.v_head_dim)
    return linear(out, p["wo"], qm, be), new_cache
