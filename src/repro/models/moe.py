"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Covers granite-moe (40 routed, top-8) and deepseek-moe (2 shared + 64
routed, top-6, first layer dense).  Dispatch is MegaBlocks-style: tokens
are argsorted by expert id, packed into an (E, C, d) buffer (capacity
C = ceil(T * k / E * capacity_factor); overflow tokens drop to a trash
row), run through grouped GEMMs (sharded over the "model" mesh axis =
expert parallelism), then combined with router weights.  Expert GEMMs run
under the same SPOGA quantization modes as dense layers.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.backends import dynamic_quant, parse_quant_mode
from repro.backends.pipeline import effective_bits
from repro.configs.base import ModelConfig
from repro.core import spoga as spoga_ops
from repro.core.slicing import slice_planes
from repro.models.layers import (
    COMPUTE_DTYPE,
    _act,
    glu_mlp,
    init_glu_mlp,
    truncated_normal_init,
)


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, e, de = cfg.d_model, m.num_experts, m.d_expert
    ks = jax.random.split(key, 5)
    import jax.numpy as _jnp

    p = {
        # router stays fp32: routing logits are precision-sensitive
        "router": truncated_normal_init(ks[0], (d, e), scale=0.02, dtype=_jnp.float32),
        "experts_gate": truncated_normal_init(ks[1], (e, d, de), scale=0.02),
        "experts_up": truncated_normal_init(ks[2], (e, d, de), scale=0.02),
        "experts_down": truncated_normal_init(ks[3], (e, de, d), scale=0.02),
    }
    if m.num_shared_experts:
        p["shared"] = init_glu_mlp(ks[4], d, m.num_shared_experts * de)
    return p


def _grouped_matmul(x, w, quant_mode, backend=None):
    """x: (..., E, C, K), w: (E, K, N) -> (..., E, C, N).

    The expert dim stays aligned with the weights' leading dim (sharded
    over "model" = expert parallelism); any leading dims (the batch rows
    of the local-capacity dispatch) stay sharded over "data".
    Integer paths bit-slice per the mode's QuantSpec and reuse the generic
    radix accumulation from :mod:`repro.core.spoga` with this expert-batched
    contraction — the Pallas kernels are strictly 2-D, so the grouped GEMM
    keeps the jnp dataflow (sharded by pjit) for every mode family; an
    explicit ``backend`` override still picks the dataflow family.
    """
    if quant_mode == "bf16":
        return jnp.einsum("...eck,ekn->...ecn",
                          x.astype(COMPUTE_DTYPE), w.astype(COMPUTE_DTYPE))
    spec, family = parse_quant_mode(quant_mode)
    if backend is not None:
        from repro.backends import get_backend

        family = get_backend(backend).family
    a_bits, w_bits = effective_bits(spec, x.shape[-1])
    xq, xs = dynamic_quant(x.astype(jnp.float32), axis=-1, bits=a_bits)
    wq, ws = dynamic_quant(w.astype(jnp.float32), axis=1, bits=w_bits)

    e_axis = x.ndim - 3

    def dot(a, b):
        # contract K; batch over E; leading dims of `a` ride along.
        out = jax.lax.dot_general(
            a, b,
            (((a.ndim - 1,), (1,)), ((e_axis,), (0,))),
            preferred_element_type=jnp.int32,
        )  # -> (E, ..., C, N)
        return jnp.moveaxis(out, 0, e_axis)

    if family == "direct":
        acc = dot(xq, wq)
    else:
        acc = spoga_ops.sliced_dot_planes(
            slice_planes(xq, spec.n_a_slices, spec.slice_bits),
            slice_planes(wq, spec.n_w_slices, spec.slice_bits),
            spec.slice_bits,
            dot_fn=dot,
            materialize=(family == "deas"),
        )
    out = acc.astype(jnp.float32) * xs * ws
    return out.astype(COMPUTE_DTYPE)


def _grouped_glu(x, p, act, quant_mode, backend=None):
    g = _act(act)(_grouped_matmul(x, p["experts_gate"], quant_mode, backend))
    u = _grouped_matmul(x, p["experts_up"], quant_mode, backend)
    return _grouped_matmul(g * u, p["experts_down"], quant_mode, backend)


def moe_ffn(x, p, cfg: ModelConfig):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Capacity is enforced PER BATCH ROW (local capacity): the sort-based
    dispatch is vmapped over B, so every tensor keeps its leading batch
    dim sharded over "data" while the expert dim aligns with the "model"
    axis (EP).  A global (B*S)-token sort would force XLA SPMD to gather
    the full (E, C, d) dispatch buffer onto every device — at 1M tokens
    that alone is tens of GiB/device (this was measured, see EXPERIMENTS
    Perf log), whereas the local form keeps it at tokens_per_device * k
    * capacity_factor.
    """
    m = cfg.moe
    b, s, d = x.shape
    k = m.top_k
    e = m.num_experts
    cap = max(1, math.ceil(s * k / e * m.capacity_factor))
    if cap > 128:
        # Round capacity up to a 128 multiple: when the expert count does
        # not divide the "model" axis (granite: 40 experts, TP-16), the
        # dispatch buffer is sharded along CAPACITY instead — it must
        # divide any model-axis size up to 128 (<=8% padding).
        cap = -(-cap // 128) * 128

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                    # (B, S, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)     # renormalize

    def route_row(xrow, topi_row):
        """xrow (S, d), topi_row (S, k) -> (buf (E, C, d), dest, sort_idx)."""
        flat_e = topi_row.reshape(-1)                       # (S*k,)
        sort_idx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sort_idx]
        group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos_in_e = jnp.arange(s * k) - group_start          # rank within expert
        keep = pos_in_e < cap
        dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # trash row
        x_sorted = jnp.take(xrow, sort_idx // k, axis=0)    # (S*k, d)
        buf = jnp.zeros((e * cap + 1, d), xrow.dtype).at[dest].set(x_sorted)
        return buf[: e * cap].reshape(e, cap, d), dest, sort_idx

    bufs, dest, sort_idx = jax.vmap(route_row)(x, topi)     # (B, E, C, d), ...
    bufs = _constrain_ep(bufs)                              # B->data, E->model

    y = _grouped_glu(bufs, p, cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)      # (B, E, C, d)
    y = _constrain_ep(y)  # keep expert outputs EP-sharded until combine

    def combine_row(y_row, dest_row, sort_idx_row, topw_row):
        y_flat = jnp.concatenate(
            [y_row.reshape(e * cap, d), jnp.zeros((1, d), y_row.dtype)], axis=0)
        out_sorted = jnp.take(y_flat, dest_row, axis=0)     # dropped -> zeros
        out_flat = jnp.zeros((s * k, d), y_row.dtype).at[sort_idx_row].set(out_sorted)
        return jnp.einsum("skd,sk->sd", out_flat.reshape(s, k, d).astype(jnp.float32),
                          topw_row)

    out = jax.vmap(combine_row)(y, dest, sort_idx, topw).astype(x.dtype)

    if m.num_shared_experts:
        out = out + glu_mlp(x, p["shared"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)

    # Switch-style load-balance aux loss (global over B*S tokens).
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(topi, e, dtype=jnp.float32).sum(2), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(dispatch_frac * mean_prob) / k
    return out, aux


def _constrain_ep(bufs):
    """Pin the dispatch buffer (B, E, C, d) to batch-over-data x
    expert-over-model sharding (EP+DP).  No-op outside a mesh / on
    non-divisible dims."""
    try:
        from jax.sharding import PartitionSpec as P
        from jax._src import mesh as mesh_lib

        mesh = mesh_lib.thread_resources.env.physical_mesh
        if mesh.empty:
            return bufs
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        model = mesh.shape.get("model", 1)
        first = dp if (dp and bufs.shape[0] % dp_size == 0) else None
        # EP when the expert dim divides the model axis; otherwise shard
        # the (128-padded) capacity dim so the buffer still never
        # replicates across "model".
        second = third = None
        if bufs.shape[1] % model == 0:
            second = "model"
        elif bufs.shape[2] % model == 0:
            third = "model"
        return jax.lax.with_sharding_constraint(bufs, P(first, second, third, None))
    except Exception:  # pragma: no cover
        return bufs


def moe_ffn_reference(x, p, cfg: ModelConfig):
    """Dense (every-expert) reference for tests: no capacity, no drops."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    gate = jnp.zeros_like(probs).at[jnp.arange(xf.shape[0])[:, None], topi].set(topw)
    ys = _grouped_glu(
        jnp.broadcast_to(xf, (m.num_experts,) + xf.shape), p, cfg.act,
        cfg.quant_mode, backend=cfg.gemm_backend,
    )  # (E, T, d)
    out = jnp.einsum("etd,te->td", ys.astype(jnp.float32), gate).astype(x.dtype)
    if m.num_shared_experts:
        out = out + glu_mlp(xf, p["shared"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
    return out.reshape(b, s, d)
