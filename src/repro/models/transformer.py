"""Stack assembly: blocks, scan-over-periods, decoder-only / enc-dec stacks.

The layer pattern (cfg.block_pattern) is cycled through the depth.  Layers
are grouped into ``n_periods`` repetitions of the pattern; parameters of
slot *s* are stacked along a leading period axis so one ``lax.scan``
(optionally rematerialized) executes the whole stack with O(1) compile-time
in depth.  Remainder layers ("tail", e.g. RecurrentGemma's 38 = 12*3 + 2)
and leading dense-FFN layers (DeepSeekMoE's first layer) run unrolled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import glu_mlp, init_glu_mlp, init_rmsnorm, rmsnorm


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def effective_kind(kind: str, cfg: ModelConfig) -> str:
    if kind == "attn" and cfg.use_mla:
        return "mla"
    return kind


def init_block(key, kind: str, cfg: ModelConfig):
    kind = effective_kind(kind, cfg)
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    p = {"norm1": init_rmsnorm(d)}
    if kind in ("attn", "local_attn"):
        p["attn"] = attn.init_attention(k1, cfg)
        p["norm2"] = init_rmsnorm(d)
        p["mlp"] = init_glu_mlp(k2, d, cfg.d_ff)
    elif kind == "mla":
        p["attn"] = attn.init_mla(k1, cfg)
        p["norm2"] = init_rmsnorm(d)
        p["mlp"] = init_glu_mlp(k2, d, cfg.d_ff)
    elif kind == "moe":
        p["attn"] = attn.init_attention(k1, cfg)
        p["norm2"] = init_rmsnorm(d)
        p["moe"] = moe_mod.init_moe(k2, cfg)
    elif kind == "dense_ffn_layer":  # MoE stack's leading dense layer(s)
        p["attn"] = attn.init_attention(k1, cfg)
        p["norm2"] = init_rmsnorm(d)
        p["mlp"] = init_glu_mlp(k2, d, cfg.moe.d_ff_dense or cfg.d_ff)
    elif kind == "rglru":
        p["cell"] = rec.init_rglru(k1, cfg)
        p["norm2"] = init_rmsnorm(d)
        p["mlp"] = init_glu_mlp(k2, d, cfg.d_ff)
    elif kind == "mlstm":
        p["cell"] = rec.init_mlstm(k1, cfg)
    elif kind == "slstm":
        p["cell"] = rec.init_slstm(k1, cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def apply_block(x, p, kind: str, cfg: ModelConfig, positions, *, causal=True):
    """Full-sequence (train/prefill) application. Returns (x, aux, cache_out).

    Megatron-SP boundaries: activations live seq-sharded over "model"
    between layers; ``sp_enter`` all-gathers the sequence entering each
    TP region (attention / MLP) and ``sp_exit`` reduce-scatters the
    row-parallel output back — otherwise the SPMD partitioner prefers to
    all-gather the much larger TP weight shards (see runtime/sharding.py).
    """
    from repro.runtime.sharding import sp_enter, sp_exit

    kind = effective_kind(kind, cfg)
    aux = jnp.zeros((), jnp.float32)
    cache_out = None
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "local_attn", "moe", "dense_ffn_layer"):
        window = cfg.sliding_window if kind == "local_attn" else None
        a, kv = attn.attention_block(sp_enter(h), p["attn"], cfg, positions,
                                     causal=causal, window=window)
        x = x + sp_exit(a)
        cache_out = kv
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            f, aux = moe_mod.moe_ffn(h2, p["moe"], cfg)
        else:
            f = sp_exit(glu_mlp(sp_enter(h2), p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend))
        x = x + f
    elif kind == "mla":
        a, ckv = attn.mla_block(sp_enter(h), p["attn"], cfg, positions)
        x = x + sp_exit(a)
        cache_out = ckv
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + sp_exit(glu_mlp(sp_enter(h2), p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend))
    elif kind == "rglru":
        a, state = rec.rglru_block(h, p["cell"], cfg, None)
        x = x + a
        cache_out = state
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
    elif kind == "mlstm":
        a, state = rec.mlstm_block(h, p["cell"], cfg, None)
        x = x + a
        cache_out = state
    elif kind == "slstm":
        a, state = rec.slstm_block(h, p["cell"], cfg, None)
        x = x + a
        cache_out = state
    else:
        raise ValueError(kind)
    return x, aux, cache_out


def apply_block_prefill(x, p, kind: str, cfg: ModelConfig, positions, cache_template):
    """Like apply_block but materializes a decode cache into cache_template.

    Recurrent kinds pass the template through the cell so the returned
    state tree has identical structure/dtypes; attention kinds write the
    fresh K/V (or MLA latents) into the template buffer (ring-rolled for
    local attention so decode's ``pos % window`` slotting lines up).
    """
    kind_e = effective_kind(kind, cfg)
    if kind_e in ("rglru", "mlstm", "slstm"):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        cell = {"rglru": rec.rglru_block, "mlstm": rec.mlstm_block, "slstm": rec.slstm_block}[kind_e]
        a, state = cell(h, p["cell"], cfg, cache_template)
        x = x + a
        if kind_e == "rglru":
            h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
            x = x + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
        state = jax.tree_util.tree_map(
            lambda tpl, v: v.astype(tpl.dtype), cache_template, state
        )
        return x, jnp.zeros((), jnp.float32), state

    x, aux, fresh = apply_block(x, p, kind, cfg, positions)
    cache = cache_template
    s = x.shape[1]
    if kind_e in ("attn", "local_attn", "mla", "moe", "dense_ffn_layer"):
        if kind_e == "mla":
            names, vals = ("ckv", "kr"), fresh
        elif cfg.kv_cache_dtype == "int8":
            # quantize fresh K/V into the byte-size cache (+ scale planes)
            kq, ks = attn.quantize_kv(fresh[0])
            vq, vs = attn.quantize_kv(fresh[1])
            names = ("k", "v", "k_scale", "v_scale")
            vals = (kq, vq, ks, vs)
        else:
            names, vals = ("k", "v"), fresh
        for name, val in zip(names, vals):
            buf = cache[name]
            cache_len = buf.shape[1]
            if cache_len >= s:
                buf = jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros(buf.shape, buf.dtype), val.astype(buf.dtype), 0, axis=1
                )
            else:  # local ring: keep the last `cache_len` positions
                buf = val[:, s - cache_len:, :].astype(buf.dtype)
                # ring expects slot order [0..W): roll so slot (pos % W) is correct
                shift = s % cache_len
                buf = jnp.roll(buf, shift, axis=1)
            cache = {**cache, name: buf}
    return x, aux, cache


# ---------------------------------------------------------------------------
# Decode-time single-token application
# ---------------------------------------------------------------------------

def apply_block_decode(x_t, p, kind: str, cfg: ModelConfig, cache, pos,
                       tables=None, active=None):
    """One-token decode through one block.  A paged cache is recognized by
    its pool keys (``kp``/``ckvp``); ``tables`` are the block tables
    threaded down from the cache root, ``active`` the live-lane mask (see
    ``model.decode_step``).  Per-lane kinds (recurrent state, local-attn
    rings) take the same path in both cache modes."""
    kind = effective_kind(kind, cfg)
    h = rmsnorm(x_t, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "local_attn", "moe", "dense_ffn_layer"):
        window = cfg.sliding_window if kind == "local_attn" else None
        if "kp" in cache:
            a, cache = attn.paged_attention_decode(h, p["attn"], cfg, cache, pos,
                                                   tables, active=active)
        else:
            a, cache = attn.attention_decode(h, p["attn"], cfg, cache, pos, window=window)
        x_t = x_t + a
        h2 = rmsnorm(x_t, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            f, _ = moe_mod.moe_ffn(h2, p["moe"], cfg)
        else:
            f = glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
        x_t = x_t + f
    elif kind == "mla":
        if "ckvp" in cache:
            a, cache = attn.mla_paged_decode(h, p["attn"], cfg, cache, pos,
                                             tables, active=active)
        else:
            a, (ckv, kr) = attn.mla_decode(h, p["attn"], cfg, cache["ckv"], cache["kr"], pos)
            cache = {**cache, "ckv": ckv, "kr": kr}
        x_t = x_t + a
        h2 = rmsnorm(x_t, p["norm2"], cfg.norm_eps)
        x_t = x_t + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
    elif kind == "rglru":
        a, state = rec.rglru_decode(h, p["cell"], cfg, cache)
        x_t = x_t + a
        cache = state
        h2 = rmsnorm(x_t, p["norm2"], cfg.norm_eps)
        x_t = x_t + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
    elif kind == "mlstm":
        a, state = rec.mlstm_decode(h, p["cell"], cfg, cache)
        x_t = x_t + a
        cache = state
    elif kind == "slstm":
        a, state = rec.slstm_decode(h, p["cell"], cfg, cache)
        x_t = x_t + a
        cache = state
    else:
        raise ValueError(kind)
    return x_t, cache


def apply_block_verify(x, p, kind: str, cfg: ModelConfig, cache, pos,
                       tables=None, active=None):
    """W-token speculative verify through one block.

    The verify twin of :func:`apply_block_decode`, restricted to the
    row-independent kinds (``chunkable(cfg)``: attn / mla / dense FFN) —
    MoE is excluded because expert capacity depends on dispatch width, so
    a (B, W) routed FFN could drop different tokens than W sequential
    (B, 1) decodes; recurrent and windowed kinds carry per-step state.
    """
    kind = effective_kind(kind, cfg)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "dense_ffn_layer"):
        if "kp" in cache:
            a, cache = attn.paged_attention_verify(h, p["attn"], cfg, cache, pos,
                                                   tables, active=active)
        else:
            a, cache = attn.attention_verify(h, p["attn"], cfg, cache, pos)
        x = x + a
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
    elif kind == "mla":
        if "ckvp" in cache:
            a, cache = attn.mla_paged_verify(h, p["attn"], cfg, cache, pos,
                                             tables, active=active)
        else:
            a, (ckv, kr) = attn.mla_verify(h, p["attn"], cfg, cache["ckv"], cache["kr"], pos)
            cache = {**cache, "ckv": ckv, "kr": kr}
        x = x + a
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + glu_mlp(h2, p["mlp"], cfg.act, cfg.quant_mode, backend=cfg.gemm_backend)
    else:
        raise ValueError(f"speculative verify unsupported for block kind {kind!r}")
    return x, cache


def scan_periods_verify(x, stacked_params, stacked_cache, cfg: ModelConfig, pos,
                        tables=None, active=None):
    from repro.runtime.sharding import constrain_decode_carry

    pattern = cfg.block_pattern

    def period_fn(carry, xs):
        h = constrain_decode_carry(carry)  # TP: see scan_periods_decode
        slot_params, slot_cache = xs
        new_cache = []
        for s, kind in enumerate(pattern):
            h, c = apply_block_verify(h, slot_params[s], kind, cfg, slot_cache[s], pos,
                                      tables=tables, active=active)
            new_cache.append(c)
        return h, tuple(new_cache)

    x, new_cache = jax.lax.scan(period_fn, x, (stacked_params, stacked_cache),
                                unroll=cfg.scan_unroll)
    return x, new_cache


# ---------------------------------------------------------------------------
# Layer layout: periods + tail
# ---------------------------------------------------------------------------

def layer_layout(cfg: ModelConfig, n_layers=None):
    """(first_k_dense, n_periods, tail_kinds) for the given depth."""
    n = n_layers if n_layers is not None else cfg.n_layers
    lead = cfg.moe.first_k_dense if cfg.moe is not None else 0
    rest = n - lead
    period = cfg.pattern_period
    n_periods = rest // period
    tail_kinds = tuple(cfg.block_pattern[i % period] for i in range(n_periods * period, rest))
    return lead, n_periods, tail_kinds


@jax.custom_vjp
def _opt_barrier(h):
    return jax.lax.optimization_barrier(h)


def _opt_barrier_fwd(h):
    return _opt_barrier(h), None


def _opt_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


# optimization_barrier has no differentiation rule on some jax versions
# (0.4.x); a barrier is linear, so its VJP is a barrier on the cotangent.
_opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


def scan_periods(x, stacked_params, cfg: ModelConfig, positions, *, causal=True):
    """Run n_periods x pattern via lax.scan. stacked_params: tuple per slot."""
    from repro.runtime.sharding import constrain_activations

    pattern = cfg.block_pattern

    def period_fn(carry, slot_params):
        h, aux = carry
        h = constrain_activations(h)  # SP: carry saved seq-sharded for bwd
        # barrier: stops XLA hoisting the rmsnorm f32 upcast across the
        # remat boundary (it would store the carry stack at 2x bytes)
        h = _opt_barrier(h)
        for s, kind in enumerate(pattern):
            h, a, _ = apply_block(h, slot_params[s], kind, cfg, positions, causal=causal)
            aux = aux + a
        return (h, aux), None

    if cfg.remat:
        # "nothing": save NOTHING inside a period — the scan stores exactly
        # the bf16 carry per layer-period (min memory, full recompute).
        # "dots": save matmul/einsum outputs — bwd recomputes only the
        # elementwise ops (±0 extra MXU flops, more activation memory).
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "nothing"
                  else jax.checkpoint_policies.checkpoint_dots)
        period_fn = jax.checkpoint(period_fn, policy=policy, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(period_fn, (x, jnp.zeros((), jnp.float32)), stacked_params,
                               unroll=cfg.scan_unroll)
    return x, aux


def scan_periods_decode(x_t, stacked_params, stacked_cache, cfg: ModelConfig, pos,
                        tables=None, active=None):
    from repro.runtime.sharding import constrain_decode_carry

    pattern = cfg.block_pattern

    def period_fn(carry, xs):
        # TP: pin the (B, 1, d) carry replicated-over-model between periods
        # so the partitioner never round-trips it through sharded layouts
        h = constrain_decode_carry(carry)
        slot_params, slot_cache = xs
        new_cache = []
        for s, kind in enumerate(pattern):
            # tables/active are loop-invariant captures: every period indexes
            # its own page pool through the same per-lane block tables
            h, c = apply_block_decode(h, slot_params[s], kind, cfg, slot_cache[s], pos,
                                      tables=tables, active=active)
            new_cache.append(c)
        return h, tuple(new_cache)

    x_t, new_cache = jax.lax.scan(period_fn, x_t, (stacked_params, stacked_cache),
                                  unroll=cfg.scan_unroll)
    return x_t, new_cache


def scan_periods_prefill(x, stacked_params, stacked_cache_tpl, cfg: ModelConfig, positions):
    pattern = cfg.block_pattern

    def period_fn(carry, xs):
        h, aux = carry
        slot_params, slot_tpl = xs
        new_cache = []
        for s, kind in enumerate(pattern):
            h, a, c = apply_block_prefill(h, slot_params[s], kind, cfg, positions, slot_tpl[s])
            aux = aux + a
            new_cache.append(c)
        return (h, aux), tuple(new_cache)

    (x, aux), new_cache = jax.lax.scan(
        period_fn, (x, jnp.zeros((), jnp.float32)), (stacked_params, stacked_cache_tpl),
        unroll=cfg.scan_unroll,
    )
    return x, aux, new_cache
