"""Modality frontend STUBS (per assignment: the [vlm]/[audio] entries
specify the transformer BACKBONE only; ``input_specs()`` provides
precomputed frame/patch embeddings).

These helpers generate deterministic fake embeddings for smoke tests and
the ShapeDtypeStructs the dry-run feeds the backbone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE


def embed_spec(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct for precomputed frontend embeddings."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), COMPUTE_DTYPE)


def fake_vision_embeds(key, cfg: ModelConfig, batch: int, seq: int):
    """Stand-in for the LLaVA-NeXT anyres tiling -> CLIP -> projector path."""
    return (jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32) * 0.02).astype(
        COMPUTE_DTYPE
    )


def fake_audio_frames(key, cfg: ModelConfig, batch: int, seq: int):
    """Stand-in for the SeamlessM4T speech frontend (fbank -> conformer adaptor)."""
    return (jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32) * 0.02).astype(
        COMPUTE_DTYPE
    )
