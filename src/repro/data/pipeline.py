"""Deterministic, shardable synthetic data pipeline.

Stateless by construction: batch ``i`` is a pure function of
``(seed, step=i)`` — ``jax.random.fold_in`` — so a restarted worker (fault
tolerance) or a re-sharded elastic job regenerates *exactly* the same
stream with no iterator state to checkpoint.  Per-host slicing takes the
host's batch shard by index, the multi-host analogue of tf.data sharding.

Synthetic text is a structured Markov-ish stream (not iid uniform) so that
a ~100M-parameter model shows a real, monotonically decreasing loss in the
end-to-end example.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for one global batch of the given shape cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16
    if shape.kind == "train":
        if cfg.is_encoder_decoder:
            return {
                "src_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f),
                "tgt_tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.frontend is not None:
            return {
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            return {
                "src_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f),
                "tgt_tokens": jax.ShapeDtypeStruct((b, 1), i32),
            }
        if cfg.frontend is not None:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((b,), i32)}


@dataclasses.dataclass(frozen=True)
class SyntheticTokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def global_batch_at(self, step: int) -> jnp.ndarray:
        """(global_batch, seq_len) int32 tokens; pure function of step."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        v = max(self.vocab_size, 4)
        b, s = self.global_batch, self.seq_len
        # low-order structure: tokens = base pattern + rare jumps
        base = jax.random.randint(k1, (b, 1), 0, v)
        drift = jnp.cumsum(jax.random.bernoulli(k2, 0.1, (b, s)).astype(jnp.int32), axis=1)
        noise = jax.random.randint(k3, (b, s), 0, 7)
        return ((base + 13 * drift + noise) % self.vocab_size).astype(jnp.int32)

    def host_batch_at(self, step: int) -> jnp.ndarray:
        g = self.global_batch_at(step)
        hb = self.host_batch
        return g[self.host_id * hb : (self.host_id + 1) * hb]

    def __iter__(self):
        step = 0
        while True:
            yield self.host_batch_at(step)
            step += 1
