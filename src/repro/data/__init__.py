from repro.data.pipeline import SyntheticTokenPipeline, batch_specs

__all__ = ["SyntheticTokenPipeline", "batch_specs"]
