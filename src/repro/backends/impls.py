"""The built-in GEMM backends.

Six strategies over the same integer arithmetic (all bit-exact vs
``direct_matmul`` — property-tested in tests/test_backends.py):

=====================  =====================================================
``jnp_spoga``          fused radix accumulation, pure jnp (CPU/GPU default)
``jnp_deas``           prior-work baseline: materialized slice partials
``direct``             native int dot_general (the MXU byte path endpoint)
``pallas_spoga``       fused Pallas kernel, int32 out (TPU; interpreted off-TPU)
``pallas_spoga_dequant``  fused Pallas kernel + dequant epilogue (TPU default)
``pallas_deas``        materialized-slice Pallas baseline (W8A8 2x4b only)
``pallas_interpret``   the fused dequant kernel forced through the Pallas
                       interpreter — CI's way to exercise the TPU kernel
                       body on CPU
=====================  =====================================================
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backends.registry import GemmBackend, register_backend
from repro.backends.spec import DEFAULT_SPEC, QuantSpec
from repro.core import spoga as _spoga
from repro.kernels.deas_gemm import deas_gemm
from repro.kernels.spoga_gemm import spoga_gemm
from repro.kernels.spoga_gemm_dequant import spoga_gemm_dequant


def _not_on_tpu() -> bool:
    return jax.default_backend() != "tpu"


def _epilogue(acc, x_scale, w_scale):
    return acc.astype(jnp.float32) * x_scale * w_scale


# -- pure-jnp dataflows -----------------------------------------------------

def _jnp_sliced(materialize):
    def gemm(x_q, w_q, spec: QuantSpec):
        return _spoga.sliced_matmul(
            x_q, w_q,
            n_x_slices=spec.n_a_slices, n_w_slices=spec.n_w_slices,
            slice_bits=spec.slice_bits, materialize=materialize,
        )
    return gemm


def _direct_gemm(x_q, w_q, spec: QuantSpec):
    return _spoga.direct_matmul(x_q, w_q)


# -- Pallas kernels ---------------------------------------------------------

def _pallas_gemm(interpret=None):
    def gemm(x_q, w_q, spec: QuantSpec):
        return spoga_gemm(
            x_q, w_q,
            n_x_slices=spec.n_a_slices, n_w_slices=spec.n_w_slices,
            slice_bits=spec.slice_bits,
            interpret=_not_on_tpu() if interpret is None else interpret,
        )
    return gemm


def _pallas_gemm_dequant(interpret=None):
    def gemm_dequant(x_q, w_q, x_scale, w_scale, spec: QuantSpec):
        return spoga_gemm_dequant(
            x_q, w_q, x_scale, w_scale,
            n_x_slices=spec.n_a_slices, n_w_slices=spec.n_w_slices,
            slice_bits=spec.slice_bits,
            interpret=_not_on_tpu() if interpret is None else interpret,
        )
    return gemm_dequant


def _pallas_deas_gemm(interpret=None):
    def gemm(x_q, w_q, spec: QuantSpec):
        return deas_gemm(
            x_q, w_q,
            interpret=_not_on_tpu() if interpret is None else interpret,
        )
    return gemm


def _supports_nibble_planes(spec: QuantSpec) -> bool:
    # The Pallas kernels cast planes to int8 for the MXU byte path.
    return spec.slice_bits <= 7


register_backend(GemmBackend(
    name="jnp_spoga", family="spoga", gemm=_jnp_sliced(materialize=False),
    description="fused radix accumulation, algebraic jnp twin of the kernel",
))
register_backend(GemmBackend(
    name="jnp_deas", family="deas", gemm=_jnp_sliced(materialize=True),
    description="prior-work DEAS: materialized per-slice partial matrices",
))
register_backend(GemmBackend(
    name="direct", family="direct", gemm=_direct_gemm,
    description="native integer dot_general (no slicing; beyond-paper endpoint)",
))
register_backend(GemmBackend(
    name="pallas_spoga", family="spoga", gemm=_pallas_gemm(),
    supports=_supports_nibble_planes,
    description="fused SPOGA Pallas kernel, int32 out (interpreted off-TPU)",
))
register_backend(GemmBackend(
    name="pallas_spoga_dequant", family="spoga", gemm=_pallas_gemm(),
    gemm_dequant=_pallas_gemm_dequant(), supports=_supports_nibble_planes,
    description="fused SPOGA Pallas kernel with in-kernel dequant epilogue",
))
register_backend(GemmBackend(
    name="pallas_deas", family="deas", gemm=_pallas_deas_gemm(),
    supports=lambda spec: spec == DEFAULT_SPEC,
    description="materialized-slice Pallas baseline (paper Fig. 2a; W8A8 only)",
))
register_backend(GemmBackend(
    name="pallas_deas_interpret", family="deas", gemm=_pallas_deas_gemm(interpret=True),
    supports=lambda spec: spec == DEFAULT_SPEC,
    description="the DEAS baseline kernels forced through the Pallas interpreter",
))
register_backend(GemmBackend(
    name="pallas_interpret", family="spoga", gemm=_pallas_gemm(interpret=True),
    gemm_dequant=_pallas_gemm_dequant(interpret=True),
    supports=_supports_nibble_planes,
    description="fused dequant kernel forced through the Pallas interpreter",
))
