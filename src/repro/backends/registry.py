"""GemmBackend registry: one pluggable home for quantize -> GEMM -> dequant.

A backend owns the integer GEMM (and optionally the fused dequantizing
epilogue) for a :class:`~repro.backends.spec.QuantSpec`.  Registration is
global and name-keyed; resolution order for a quantized linear is

1. an explicit ``backend=`` override (threaded from ``ModelConfig
   .gemm_backend`` / the launch ``--gemm-backend`` flag),
2. the process-wide default set via :func:`set_default_backend`,
3. auto-selection by dataflow family and ``jax.default_backend()``:
   TPU runs the fused Pallas kernels, everything else the algebraic jnp
   twins (the Pallas interpreter stays available as ``pallas_interpret``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.backends.spec import QuantSpec, parse_quant_mode

__all__ = [
    "GemmBackend",
    "register_backend",
    "get_backend",
    "list_backends",
    "resolve_backend",
    "set_default_backend",
    "get_default_backend",
]


@dataclasses.dataclass(frozen=True)
class GemmBackend:
    """One GEMM execution strategy.

    ``gemm(x_q, w_q, spec) -> int32 (M, N)`` is mandatory and operates on
    already-quantized 2-D operands.  ``gemm_dequant(x_q, w_q, x_scale,
    w_scale, spec) -> f32 (M, N)`` is the fused epilogue; when absent the
    pipeline composes ``gemm`` with a jnp epilogue (same math, one extra
    (M, N) int32 round trip — exactly what the fused kernels avoid).
    ``supports(spec)`` gates specs the strategy cannot express (e.g. the
    materialized DEAS Pallas baseline is pinned to the paper's 2x4b W8A8).
    """

    name: str
    family: str                      # "spoga" | "deas" | "direct"
    gemm: Callable
    gemm_dequant: Optional[Callable] = None
    supports: Callable[[QuantSpec], bool] = lambda spec: True
    description: str = ""


_REGISTRY: dict[str, GemmBackend] = {}
_DEFAULT_BACKEND: Optional[str] = None


def register_backend(backend: GemmBackend, *, override: bool = False) -> GemmBackend:
    if backend.name in _REGISTRY and not override:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> GemmBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown GEMM backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def set_default_backend(name: Optional[str]) -> None:
    """Process-wide override (launch scripts call this from --gemm-backend).

    ``None`` restores family/platform auto-selection.  Set this before
    building jitted step functions: the choice is baked in at trace time.
    """
    global _DEFAULT_BACKEND
    if name is not None:
        get_backend(name)  # validate eagerly
    _DEFAULT_BACKEND = name


def get_default_backend() -> Optional[str]:
    return _DEFAULT_BACKEND


def _auto_name(family: str) -> str:
    on_tpu = jax.default_backend() == "tpu"
    if family == "direct":
        return "direct"
    if family == "deas":
        return "pallas_deas" if on_tpu else "jnp_deas"
    if family == "spoga":
        return "pallas_spoga_dequant" if on_tpu else "jnp_spoga"
    raise ValueError(f"unknown dataflow family {family!r}")


def resolve_backend(
    quant_mode: str, backend: Optional[str] = None
) -> tuple[GemmBackend, QuantSpec]:
    """(mode string, optional override) -> (backend, spec), validated."""
    spec, family = parse_quant_mode(quant_mode)
    name = backend or _DEFAULT_BACKEND or _auto_name(family)
    b = get_backend(name)
    if not b.supports(spec):
        raise ValueError(
            f"backend {b.name!r} does not support quant mode {quant_mode!r} "
            f"(spec {spec}); pick one of {list_backends()}"
        )
    return b, spec
