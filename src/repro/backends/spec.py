"""Quantization execution specs: mode strings -> (bit widths, slicing plan).

One ``QuantSpec`` pins everything a GEMM backend needs to know about a
quantized linear: operand bit widths, the slice width the "photonic
hardware" natively supports (OAMEs are 4-bit in the paper), and the derived
plane counts.  Mode strings come in two forms:

* legacy dataflow names — ``int8_spoga`` / ``int8_deas`` / ``int8_direct``
  (all W8A8; the suffix picks the dataflow *family*);
* parametric names — ``w{W}a{A}`` with an optional ``_s{B}`` slice-width
  suffix: ``w4a8`` (4-bit weights, one plane), ``w4a4``, ``w16a16``
  (four planes each), ``w8a8_s2`` (byte operands on 2-bit slices).  All
  parametric modes run the fused SPOGA family.

``configs/base.py`` imports :data:`QUANT_MODES` from here so the config
layer and the backend layer can never drift apart.
"""

from __future__ import annotations

import dataclasses
import re

# Dataflow families (paper Fig. 2): fused radix accumulation, materialized
# prior-work slices, or the native byte-capable MXU path.
FAMILIES = ("spoga", "deas", "direct")

# Canonical mode strings accepted by ModelConfig.quant_mode ("bf16" opts out
# of quantization entirely and never reaches a GEMM backend).
QUANT_MODES = (
    "bf16",
    "int8_spoga",
    "int8_deas",
    "int8_direct",
    "w4a8",
    "w4a4",
    "w16a16",
)

_PARAMETRIC = re.compile(r"^w(\d+)a(\d+)(?:_s(\d+))?$")


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Bit widths + slicing plan for one quantized GEMM."""

    a_bits: int = 8        # activation operand width
    w_bits: int = 8        # weight operand width
    slice_bits: int = 4    # native slice width of the analog cores

    def __post_init__(self):
        for b in (self.a_bits, self.w_bits):
            if not 2 <= b <= 16:
                raise ValueError(f"operand widths must be in [2, 16], got {b}")
        if not 1 <= self.slice_bits <= 8:
            raise ValueError(f"slice_bits must be in [1, 8], got {self.slice_bits}")

    @property
    def n_a_slices(self) -> int:
        return -(-self.a_bits // self.slice_bits)

    @property
    def n_w_slices(self) -> int:
        return -(-self.w_bits // self.slice_bits)

    @property
    def a_dtype(self):
        import jax.numpy as jnp
        return jnp.int8 if self.a_bits <= 8 else jnp.int16

    @property
    def w_dtype(self):
        import jax.numpy as jnp
        return jnp.int8 if self.w_bits <= 8 else jnp.int16

    @property
    def a_qmax(self) -> float:
        return float(2 ** (self.a_bits - 1) - 1)

    @property
    def w_qmax(self) -> float:
        return float(2 ** (self.w_bits - 1) - 1)


DEFAULT_SPEC = QuantSpec()  # W8A8 on nibble slices — the paper's operating point


def parse_quant_mode(mode: str) -> tuple[QuantSpec, str]:
    """Mode string -> (QuantSpec, dataflow family).

    Raises ValueError for unknown modes (including ``"bf16"`` — the caller
    must branch to the unquantized path before asking for a spec).
    """
    if mode == "int8_spoga":
        return DEFAULT_SPEC, "spoga"
    if mode == "int8_deas":
        return DEFAULT_SPEC, "deas"
    if mode == "int8_direct":
        return DEFAULT_SPEC, "direct"
    m = _PARAMETRIC.match(mode)
    if m:
        w_bits, a_bits = int(m.group(1)), int(m.group(2))
        slice_bits = int(m.group(3)) if m.group(3) else 4
        return QuantSpec(a_bits=a_bits, w_bits=w_bits, slice_bits=slice_bits), "spoga"
    raise ValueError(
        f"unknown quant mode {mode!r}: expected one of "
        f"{QUANT_MODES[1:]} or a parametric 'w<bits>a<bits>[_s<slice>]' string"
    )
