"""The quantized-linear pipeline: quantize -> GEMM -> dequant, one place.

Every quantized matmul in the model hot path lands here.  The pipeline

1. dynamically quantizes activations per row and weights per output channel
   to the spec's bit widths (int8 storage up to 8 bits, int16 above),
2. flattens leading batch dims ONCE into the (M, K) layout the kernels
   expect,
3. runs the resolved backend — preferring its fused ``gemm_dequant`` (the
   paper's single-ADC-per-output semantics: no (M, N) int32 intermediate
   ever reaches HBM) and composing ``gemm`` + jnp epilogue otherwise,
4. restores the leading dims.

The old per-layer re-implementations (``models/layers._int8_forward``,
the dict dispatch in ``core/spoga.quantized_matmul`` and ``kernels/ops``)
are gone; they all route through here / the registry now.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp

from repro.backends import impls  # noqa: F401  (populates the registry)
from repro.backends.registry import resolve_backend
from repro.backends.spec import parse_quant_mode
from repro.obs import watchdog as _watchdog

__all__ = ["dynamic_quant", "effective_bits", "quantized_linear", "gemm_int"]

ACC_BITS = 32  # the kernels accumulate in int32 (paper: >=16-bit accumulation)


def dynamic_quant(x: jnp.ndarray, axis, bits: int = 8):
    """Symmetric dynamic quantization to ``bits`` (int8/int16 storage).

    Returns ``(q, scale)`` with ``x ~= q * scale``; clips to ±(2^(bits-1)-1)
    so every value honors the slicing budget (e.g. int4 weights stay in
    [-7, 7] and pass through a single 4-bit plane unchanged).  Thin wrapper
    over :func:`repro.quant.qtensor.quantize` — the quantization arithmetic
    lives in exactly one place.

    Tensor-parallel note: under pjit the per-row ``amax`` reduction over a
    "model"-sharded K axis lowers to a cross-device collective (pjit's
    global-view semantics), so per-row scales are GLOBALLY exact — every
    device quantizes its K-slice against the same scale, and the partial
    int32 accumulators psum into exactly what an unsharded quantized GEMM
    would produce.  Column-parallel (N-sharded) weights are even simpler:
    each device owns whole output columns, so weight scales never cross
    devices.  No sharding-specific code is needed here; this is why the
    tp=1 engine is bitwise and tp>1 differs only by float reduction order
    in the row-parallel psums.
    """
    from repro.quant.qtensor import quantize  # lazy: keeps layering one-way

    q = quantize(x, axis=axis, bits=bits)
    return q.data, q.scale


def effective_bits(spec, k: int) -> tuple[int, int]:
    """Accumulator-aware operand widths for a K-length contraction.

    A product of a ``a``-bit and a ``w``-bit operand spans ``a + w - 2``
    magnitude bits; summing K of them adds ``ceil(log2 K)`` more.  To keep
    the int32 accumulator exact (no mod-2^32 wrap) the effective widths are
    shrunk — largest first — until ``a + w + ceil(log2 K) <= 33``.  W8A8
    is untouched for every realistic K (it would take K > 2^17 to bind);
    ``w16a16`` lands at e.g. 14+13 bits for K = 64 — still far finer than
    int8, which is the point of the wide mode.  Storage dtype and the
    slicing plan keep following the *nominal* spec (values simply occupy
    fewer of the planes' bits).
    """
    headroom = (k - 1).bit_length() if k > 1 else 0  # ceil(log2 k)
    budget = ACC_BITS + 1 - headroom                 # a + w <= 33 - log2(K)
    a, w = spec.a_bits, spec.w_bits
    while a + w > budget and (a > 2 or w > 2):
        if a >= w and a > 2:
            a -= 1
        else:
            w -= 1
    return a, w


def _stage_watchdog_stats(label: str, quant_mode: str, xf, wf, xq, xs, wq,
                          a_bits: int, w_bits: int, nominal: int) -> None:
    """Stage this GEMM's numerics stats out of the jit via debug.callback.

    Everything is computed in-graph (no host sync; the callback is an
    effectful side output that does not feed the computation, so enabling
    it cannot change results):

    - at-rail occupancy of both quantized operands (``rail_hits``),
    - activation ``amax`` and mean relative quantization error,
    - an accumulator-magnitude bound in bits: ``max_row sum_k |xq|``
      times ``max |wq|`` is the largest int32 any output element can
      reach, so ``log2`` of it against the 31 usable magnitude bits is
      the live headroom the ``effective_bits`` clamp guarantees
      statically (the fused gemm_dequant path never materializes the
      accumulator, so this bound is the only runtime view of it).
    """
    import jax

    from repro.quant.qtensor import rail_hits

    xqf = xq.astype(jnp.float32)
    deq = xqf * xs
    abs_mean = jnp.mean(jnp.abs(xf))
    stats = jnp.stack([
        rail_hits(xq, a_bits).astype(jnp.float32),
        rail_hits(wq, w_bits).astype(jnp.float32),
        jnp.float32(xq.size),
        jnp.float32(wq.size),
        jnp.max(jnp.abs(xf)),
        jnp.mean(jnp.abs(xf - deq)) / (abs_mean + 1e-12),
        jnp.log2(1.0 + jnp.max(jnp.sum(jnp.abs(xqf), axis=-1))
                 * jnp.max(jnp.abs(wq.astype(jnp.float32)))),
        jnp.float32(nominal - (a_bits + w_bits)),
    ])
    jax.debug.callback(
        functools.partial(_watchdog.record, label, quant_mode), stats)


def quantized_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    quant_mode: str,
    *,
    backend: Optional[str] = None,
    out_dtype=None,
    watch: Optional[bool] = None,
    layer: Optional[str] = None,
) -> jnp.ndarray:
    """x (..., K) fp @ w (K, N) fp -> (..., N) fp via the quantized pipeline.

    ``watch``/``layer`` drive the numerics watchdog explicitly; by
    default the ambient trace-time context set by the model entry points
    (``watchdog.watching``, keyed off ``ModelConfig.numerics_watchdog``)
    decides, so the ~60 model call sites need no extra plumbing.
    """
    b, spec = resolve_backend(quant_mode, backend)
    a_bits, w_bits = effective_bits(spec, x.shape[-1])
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    xq, xs = dynamic_quant(xf, axis=-1, bits=a_bits)
    wq, ws = dynamic_quant(wf, axis=0, bits=w_bits)

    ctx = _watchdog.trace_ctx()
    if watch if watch is not None else ctx is not None:
        label = layer or _watchdog.next_label(
            ctx, x.shape[-1], w.shape[-1])
        _stage_watchdog_stats(label, quant_mode, xf, wf, xq, xs, wq,
                              a_bits, w_bits, spec.a_bits + spec.w_bits)

    xq = xq.astype(spec.a_dtype)
    wq = wq.astype(spec.w_dtype)

    lead = xq.shape[:-1]
    k = xq.shape[-1]
    n = wq.shape[-1]
    x2 = xq.reshape(-1, k)
    xs2 = xs.reshape(-1, 1)
    ws2 = ws.reshape(1, n)
    if b.gemm_dequant is not None:
        out = b.gemm_dequant(x2, wq, xs2, ws2, spec)
    else:
        out = b.gemm(x2, wq, spec).astype(jnp.float32) * xs2 * ws2
    out = out.reshape(*lead, n)
    return out.astype(out_dtype if out_dtype is not None else x.dtype)


def gemm_int(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    *,
    quant_mode: str = "int8_spoga",
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Already-quantized (..., K) @ (K, N) -> (..., N) int32 accumulator.

    Leading batch dims are flattened around the backend call (the Pallas
    kernels are strictly 2-D); the jnp backends would broadcast natively but
    take the same path for uniformity.
    """
    b, spec = resolve_backend(quant_mode, backend)
    lead = x_q.shape[:-1]
    k = x_q.shape[-1]
    acc = b.gemm(x_q.reshape(-1, k), w_q, spec)
    return acc.reshape(*lead, w_q.shape[-1])


def quant_mode_summary(quant_mode: str) -> str:
    """Human-readable one-liner for logs/benchmarks: 'w4a8: 2x1 4b planes'."""
    spec, family = parse_quant_mode(quant_mode)
    return (f"{quant_mode}: {family}, a{spec.a_bits}/w{spec.w_bits}, "
            f"{spec.n_a_slices}x{spec.n_w_slices} planes of {spec.slice_bits}b")
