"""Unified GEMM backend registry (the SPOGA execution layer).

``register_backend`` / ``get_backend`` manage named :class:`GemmBackend`
strategies; :func:`quantized_linear` is the one quantize -> GEMM -> dequant
pipeline every quantized model layer routes through.  Auto-selection runs
the fused Pallas kernels on TPU and their algebraic jnp twins elsewhere;
``ModelConfig.gemm_backend`` (or ``set_default_backend``) overrides.

Only :mod:`repro.backends.spec` (pure dataclasses, no jax) loads eagerly —
``configs`` imports mode metadata from here without paying for the kernel
stack.  Registry/pipeline names resolve lazily (PEP 562) and pull in the
built-in backend implementations on first use.
"""

import importlib

from repro.backends.spec import (  # noqa: F401  (light: no jax import)
    FAMILIES,
    QUANT_MODES,
    DEFAULT_SPEC,
    QuantSpec,
    parse_quant_mode,
)

# name -> defining module; resolved on first attribute access, after loading
# repro.backends.impls so the built-in backends are always registered first.
_LAZY = {
    "GemmBackend": "repro.backends.registry",
    "register_backend": "repro.backends.registry",
    "get_backend": "repro.backends.registry",
    "list_backends": "repro.backends.registry",
    "resolve_backend": "repro.backends.registry",
    "set_default_backend": "repro.backends.registry",
    "get_default_backend": "repro.backends.registry",
    "dynamic_quant": "repro.backends.pipeline",
    "effective_bits": "repro.backends.pipeline",
    "gemm_int": "repro.backends.pipeline",
    "quantized_linear": "repro.backends.pipeline",
    "quant_mode_summary": "repro.backends.pipeline",
}

__all__ = [
    "FAMILIES",
    "QUANT_MODES",
    "DEFAULT_SPEC",
    "QuantSpec",
    "parse_quant_mode",
    *sorted(_LAZY),
]


def __getattr__(name):
    if name in _LAZY:
        importlib.import_module("repro.backends.impls")  # registers built-ins
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.backends' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
