"""Config registry: one module per assigned architecture (+ paper's CNNs).

``get_config("mistral-large-123b")`` returns the full published config;
``reduced(cfg)`` returns a smoke-test-sized config of the same family.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import (
    DEFAULT_PAGE_SIZE,
    KV_CACHE_HEADROOM,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    default_cache_len,
    default_page_count,
    pages_for,
)

from repro.configs import (
    mistral_large_123b,
    minicpm3_4b,
    mistral_nemo_12b,
    llama32_1b,
    granite_moe_3b,
    deepseek_moe_16b,
    xlstm_125m,
    llava_next_mistral_7b,
    seamless_m4t_large_v2,
    recurrentgemma_9b,
)

ARCHS = {
    "mistral-large-123b": mistral_large_123b.CONFIG,
    "minicpm3-4b": minicpm3_4b.CONFIG,
    "mistral-nemo-12b": mistral_nemo_12b.CONFIG,
    "llama3.2-1b": llama32_1b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b.CONFIG,
    "deepseek-moe-16b": deepseek_moe_16b.CONFIG,
    "xlstm-125m": xlstm_125m.CONFIG,
    "llava-next-mistral-7b": llava_next_mistral_7b.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
}

# archs with *bounded-state* sequence mixing run the 500k-decode cell;
# pure full-attention archs skip it (DESIGN.md §Arch-applicability).
SUBQUADRATIC = ("xlstm-125m", "recurrentgemma-9b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """All assigned (arch x shape) dry-run cells. 40 total, 34 runnable."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and arch not in SUBQUADRATIC
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name, skipped))
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test config of the same family: tiny dims, same block pattern."""
    period = cfg.pattern_period
    lead = cfg.moe.first_k_dense if cfg.moe else 0
    n_layers = lead + 2 * period + (1 if cfg.name == "recurrentgemma-9b" else 0)
    kw = dict(
        n_layers=n_layers,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        lru_width=128 if cfg.lru_width else None,
        sliding_window=32 if cfg.sliding_window else None,
        n_encoder_layers=2 if cfg.is_encoder_decoder else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=8,
            top_k=2,
            num_shared_experts=cfg.moe.num_shared_experts,
            d_expert=64,
            # high capacity -> no token drops at smoke scale, so the
            # prefill+decode path is exactly consistent with full forward
            # (capacity is per-sequence; different S would otherwise drop
            # different tokens)
            capacity_factor=8.0,
            first_k_dense=cfg.moe.first_k_dense,
            d_ff_dense=256 if cfg.moe.d_ff_dense else 0,
        )
    if cfg.use_mla:
        kw["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
    return dataclasses.replace(cfg, **kw)
