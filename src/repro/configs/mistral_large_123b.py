"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407.

88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=32_768,
    rope_theta=1_000_000.0,
)
