"""deepseek-moe-16b [moe] — arXiv:2401.06066 (DeepSeekMoE 16B).

28L, d_model 2048, 16 heads, 64 routed experts top-6 + 2 shared experts
(fine-grained, d_expert 1408), first layer dense (d_ff 10944),
vocab 102400.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2_048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1_408,
    vocab_size=102_400,
    block_pattern=("moe",),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        d_expert=1_408,
        first_k_dense=1,
        d_ff_dense=10_944,
    ),
)
