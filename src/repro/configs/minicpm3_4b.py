"""minicpm3-4b [dense, MLA] — hf:openbmb/MiniCPM3-4B.

62L, d_model 2560, 40 heads, d_ff 6400, vocab 73448, Multi-head Latent
Attention (q_lora 768, kv_lora 256, nope 64 + rope 32, v 64).
"""

from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2_560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,  # qk_nope + qk_rope
    d_ff=6_400,
    vocab_size=73_448,
    use_mla=True,
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
)
