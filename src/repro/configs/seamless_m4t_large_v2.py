"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596 (SeamlessM4T v2).

Encoder-decoder backbone: 24L encoder + 24L decoder, d_model 1024,
16 heads, d_ff 8192, vocab 256206.  Speech frontend is a STUB:
input_specs() feeds precomputed frame embeddings to the encoder.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1_024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8_192,
    vocab_size=256_206,
    is_encoder_decoder=True,
    n_encoder_layers=24,
    frontend="audio",
)
