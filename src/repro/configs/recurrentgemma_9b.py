"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin / RecurrentGemma).

38L, d_model 4096, 16 attention heads (MQA kv=1, head_dim 256), d_ff 12288,
vocab 256000; block pattern 2x RG-LRU recurrent : 1x local attention
(window 2048).  38 = 12 periods of 3 + 2 tail recurrent layers.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4_096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local_attn"),
    sliding_window=2_048,
    lru_width=4_096,
)
