"""granite-moe-3b-a800m [moe] — ibm-granite granite-3.0 MoE family.

32L, d_model 1536, 24 heads (GQA kv=8), per-expert d_ff 512, vocab 49155,
40 routed experts top-8.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1_536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
)
