"""Model / run configuration dataclasses.

One ``ModelConfig`` describes any of the 10 assigned architectures (dense /
MoE / MLA / SSM / hybrid / enc-dec / stub-frontend) plus the SPOGA
quantization execution mode.  Configs are plain frozen dataclasses so they
hash (static jit args) and serialize trivially.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Quantization execution modes (DESIGN.md §3) — canonical list lives next to
# the QuantSpec parser so configs and backends cannot drift apart.
from repro.backends.spec import QUANT_MODES, parse_quant_mode


# ---------------------------------------------------------------------------
# KV-cache sizing policy (shared by the static server, the continuous-batching
# engine and the benchmarks so they always agree on cache shapes).
#
# Headroom beyond prompt + generation covers (a) speculative/extra decode
# steps past a request's nominal budget and (b) rounding prompt lengths up to
# a prefill bucket — without it every off-by-one re-allocates (and re-jits)
# the cache. 8 slots is < 1% overhead at serving lengths.
KV_CACHE_HEADROOM = 8


def default_cache_len(prompt_len: int, gen_tokens: int,
                      headroom: int = KV_CACHE_HEADROOM) -> int:
    """Cache length for serving ``prompt_len`` + ``gen_tokens`` decode steps."""
    return prompt_len + gen_tokens + headroom


# Paged KV-cache policy (repro/paging/). A page holds PAGE_SIZE token rows;
# 16 keeps per-request internal fragmentation under one MXU tile while the
# byte-size int8 page (16 x H x D int8 + scales) stays a few KiB — small
# enough that mixed-length traffic packs the pool tightly.
DEFAULT_PAGE_SIZE = 16


def pages_for(tokens: int, page_size: int = DEFAULT_PAGE_SIZE) -> int:
    """Pages covering ``tokens`` cache rows (ceil division)."""
    return -(-max(int(tokens), 0) // page_size)


def default_page_count(n_lanes: int, cache_len: int,
                       page_size: int = DEFAULT_PAGE_SIZE) -> int:
    """Pool size matching the slot-cache KV budget: ``n_lanes`` worst-case
    requests, plus the reserved trash page 0 (see paging/manager.py)."""
    return n_lanes * pages_for(cache_len, page_size) + 1


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    top_k: int = 0
    num_shared_experts: int = 0   # DeepSeekMoE-style always-on experts
    d_expert: int = 0             # per-expert FFN hidden size
    capacity_factor: float = 1.25
    first_k_dense: int = 0        # leading layers that use a dense FFN
    d_ff_dense: int = 0           # hidden size of those dense FFN layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # defaults to d_model // n_heads
    # block pattern, cycled through the stack; entries in
    # {"attn", "local_attn", "moe", "mlstm", "slstm", "rglru"}
    block_pattern: tuple = ("attn",)
    # attention
    sliding_window: Optional[int] = None  # for local_attn blocks
    rope_theta: float = 10_000.0
    use_mla: bool = False
    mla: Optional[MLAConfig] = None
    # MoE
    moe: Optional[MoEConfig] = None
    # recurrent (rglru / xlstm)
    conv_width: int = 4
    lru_width: Optional[int] = None
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # modality frontend stub: None | "vision" | "audio"
    frontend: Optional[str] = None
    # numerics
    quant_mode: str = "bf16"
    # GEMM backend registry name ("jnp_spoga", "pallas_spoga_dequant",
    # "pallas_interpret", ...); None = auto-select by platform/family.
    gemm_backend: Optional[str] = None
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    # scan/remat
    scan_layers: bool = True
    remat: bool = True
    # "nothing"  — recompute the whole period in bwd (min memory, +33% flops)
    # "dots"     — save matmul outputs, recompute elementwise only
    remat_policy: str = "nothing"
    # KV cache storage dtype for decode: "bf16" | "int8" (SPOGA-sliced
    # storage: int8 payload + per-(pos, head) scale; halves cache HBM reads)
    kv_cache_dtype: str = "bf16"
    # Paged-attention implementation for block-table decode (repro/paging/):
    # None = auto (Pallas kernel on TPU, jnp gather twin elsewhere);
    # "jnp" | "pallas" | "pallas_interpret" force a path (interpret covers
    # the kernel body in CI, mirroring the GEMM backends).
    paged_attn_impl: Optional[str] = None
    # Fully unroll every lax.scan (layers + loss chunks). Used by the
    # dry-run's cost-calibration pass: XLA's HloCostAnalysis counts a
    # while-loop body ONCE (not x trip count), so scanned stacks would
    # under-report flops/bytes/collectives by ~n_layers. Never enable for
    # real execution of deep configs (compile time is O(depth)).
    scan_unroll: bool = False
    # Numerics watchdog (repro.obs.watchdog): when set, every quantized
    # GEMM stages per-layer saturation/amax/quant-error stats through
    # jax.debug.callback. Lives on ModelConfig (not a global) so every
    # lru_cached jit wrapper in the engine re-keys when it toggles —
    # a compiled trace can never be reused across watchdog states.
    numerics_watchdog: bool = False

    def __post_init__(self):
        if self.quant_mode not in QUANT_MODES:
            # Parametric modes ("w4a8", "w8a8_s2", ...) validate via the
            # spec parser; anything it rejects is a genuine config error.
            try:
                parse_quant_mode(self.quant_mode)
            except ValueError:
                raise ValueError(
                    f"quant_mode must be in {QUANT_MODES} or a parametric "
                    f"'w<bits>a<bits>[_s<slice>]' string, got {self.quant_mode!r}"
                ) from None
        if self.gemm_backend is not None:
            # Touching the registry loads the kernel stack (jax + Pallas);
            # only pay that when a backend override is actually configured.
            from repro.backends import get_backend

            get_backend(self.gemm_backend)  # raises KeyError on unknown names
        if self.paged_attn_impl not in (None, "jnp", "pallas", "pallas_interpret"):
            raise ValueError(
                "paged_attn_impl must be None (auto), 'jnp', 'pallas' or "
                f"'pallas_interpret', got {self.paged_attn_impl!r}")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires moe config")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    def layer_type(self, i: int) -> str:
        if self.moe is not None and i < self.moe.first_k_dense:
            return "dense_ffn_layer"
        return self.block_pattern[i % len(self.block_pattern)]

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str           # train_4k | prefill_32k | decode_32k | long_500k
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"      # cosine | linear | constant
    zero1: bool = True            # shard optimizer state over the data axis
    fsdp: bool = True             # ZeRO-3 weight sharding over the data axis
    microbatches: int = 1         # gradient accumulation steps per update
    grad_compression: bool = False  # int8 compressed gradient all-reduce
    # dtype of the gradient reduce-scatter payload: "f32" (exact) or
    # "bf16" (halves the dominant DP collective; AdamW still updates the
    # f32 master copy)
    grad_reduce_dtype: str = "f32"
