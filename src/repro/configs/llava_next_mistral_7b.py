"""llava-next-mistral-7b [vlm] — hf:llava-hf/llava-v1.6-mistral-7b-hf.

Backbone = Mistral-7B: 32L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 32000.  The anyres vision tower is a STUB per the assignment:
input_specs() feeds precomputed patch embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    rope_theta=1_000_000.0,
    frontend="vision",
)
