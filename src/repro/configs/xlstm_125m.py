"""xlstm-125m [ssm] — arXiv:2405.04517 (xLSTM).

12L, d_model 768, 4 heads, vocab 50304; mLSTM (matrix memory) with one
sLSTM (scalar memory, recurrent R) every 4th layer — the paper's
mLSTM:sLSTM ratio. d_ff=0: xLSTM cells carry their own projections.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
)
