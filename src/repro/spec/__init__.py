"""Speculative decoding: draft-verify loop for the serving engine.

``k`` drafted tokens per lane are verified in ONE batched model dispatch
(the paper's throughput-per-dispatch argument applied to serving: more
byte-size GEMM work per issued operation), with greedy accept fused into
the verify jit so speculative output stays bitwise identical to plain
decode.  See ``verify.py`` for the accept rule and the exactness
argument, ``ngram.py`` / ``draft_model.py`` for the two drafters.
"""

from repro.spec.config import SpecConfig
from repro.spec.ngram import NgramDrafter
from repro.spec.verify import jitted_verify


def make_drafter(spec: SpecConfig, target_cfg, n_slots: int, cache_len: int,
                 tree=None):
    """Build the configured drafter (imports the draft model lazily so the
    ngram path never touches model-init code)."""
    if spec.drafter == "ngram":
        return NgramDrafter(spec, tree=tree)
    from repro.spec.draft_model import DraftModelDrafter

    return DraftModelDrafter(spec, target_cfg, n_slots, cache_len)


__all__ = ["SpecConfig", "NgramDrafter", "jitted_verify", "make_drafter"]
