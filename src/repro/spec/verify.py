"""Batched k-token verification: one dispatch, in-jit greedy accept.

The verify window for a lane at position ``pos`` (cache rows < pos
written, last sampled token t0 not yet appended) is
``[t0, d1 .. dk]`` — W = k + 1 rows at absolute positions
``pos .. pos + k``.  One :func:`repro.models.model.verify_step` dispatch
writes all W K/V rows and returns (B, W, V) logits; row c's argmax is the
token plain greedy decode would emit after accepting rows <= c.

Accept rule (fused into the jit so the step stays traced-once across
acceptance lengths — acceptance is *data*, not shape):

    targets   = argmax(logits, -1)                       # (B, W)
    match[c]  = draft[c] == targets[c]                   # d_{c+1} vs row c
    ok[c]     = match[c] and c < n_draft                 # mask the pad
    accepted  = length of the leading all-ok run (cumprod-sum)
    new_pos   = pos + accepted + 1                       # +1: bonus row

The emitted tokens are ``targets[:accepted + 1]``: the accepted drafts
are *by construction* the argmax chain plain decode produces, and row
``accepted`` is either the correction (first mismatch) or the bonus
token (full accept) — so greedy speculative output is bitwise identical
to plain decode.  Rows past ``accepted`` hold garbage K/V, overwritten
by the next verify/decode window before any query attends them (the
chunked-prefill padding argument).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as model_lib


@functools.lru_cache(maxsize=None)
def jitted_verify(cfg, width: int):
    """One verify dispatch, jitted per (model config, window width).

    fn(params, cache, tokens (B, W) int32, n_draft (B,) int32,
       active (B,) bool) -> (new_cache, targets (B, W), accepted (B,))

    ``accepted`` counts accepted *drafts* (<= n_draft); the host emits
    ``targets[lane, : accepted + 1]``.  Inactive lanes keep pos = 0 and
    (paged) write to the trash page, exactly like plain decode.
    """

    def fn(params, cache, tokens, n_draft, active):
        # named scopes label the verify window + accept rule in device
        # profiles (obs.StepProfiler / --profile), separating the model
        # forward from the accept arithmetic in the HLO timeline
        with jax.named_scope("spec_verify"):
            logits, cache = model_lib.verify_step(params, cfg, tokens, cache,
                                                  active)
        with jax.named_scope("spec_accept"):
            targets = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if width > 1:
                match = tokens[:, 1:] == targets[:, :-1]
                ok = match & (jnp.arange(width - 1)[None, :] < n_draft[:, None])
                accepted = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1),
                                   axis=1)
            else:
                accepted = jnp.zeros((tokens.shape[0],), jnp.int32)
            pos = cache["pos"]
            cache = dict(cache)
            cache["pos"] = jnp.where(active, pos + accepted + 1, 0)
        return cache, targets, accepted

    return jax.jit(fn, donate_argnums=(1,))
