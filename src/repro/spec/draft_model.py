"""Draft-model drafter: a small transformer proposes, the target verifies.

The draft model runs its own slot cache, aligned lane-for-lane with the
engine's: row ``i`` of a lane's draft cache was written by feeding token
``i`` of that lane's sequence.  Rather than being told accept/reject
results, the drafter *re-derives* validity at propose time by comparing
the tokens it actually fed (``_fed``) against the lane's true history —
the longest common prefix is the count of valid draft-cache rows, and
the device position is rolled back to it.  After a verify with ``a``
accepted drafts the catch-up (history beyond the common prefix) is
always 1 token (partial accept / rejection: the correction replaces the
first bad draft) or 2 (full accept: the bonus token plus the next input
— row ``base + k - 1`` was the last written), so steady-state cost per
spec step is at most one catch-up dispatch + ``k`` draft dispatches,
each batched across all proposing lanes.

The drafter is engine-independent: it owns lru-cached jits built
directly on ``model_lib`` (prefill + scatter for admit, decode + argmax
for draft steps), so ``repro.spec`` never imports ``repro.serving``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.spec.config import SpecConfig


def draft_config(target: ModelConfig, spec: SpecConfig) -> ModelConfig:
    """Derive the draft architecture.

    ``spec.draft_arch`` names a registry config (reduced to smoke size,
    vocab forced to the target's so proposals index the same token
    space); otherwise the target is truncated to ``spec.draft_layers``
    layers — always same-vocab, and same-family by construction.
    """
    if spec.draft_arch is not None:
        cfg = reduced(get_config(spec.draft_arch))
        cfg = cfg.with_(vocab_size=target.vocab_size)
    else:
        lead = target.moe.first_k_dense if target.moe is not None else 0
        period = target.pattern_period
        cfg = target.with_(n_layers=lead + period * max(
            1, (spec.draft_layers - lead) // period))
    return cfg.with_(remat=False)


@functools.lru_cache(maxsize=None)
def _jitted_draft_admit(cfg: ModelConfig, cache_len: int):
    """Prefill one prompt into lane ``slot`` of the draft slot cache (no
    sampling — the *target* supplies t0; the draft only needs the rows)."""
    from repro.serving.slots import scatter_lane

    def admit(pool, params, tokens, lengths, slot, axes_flat):
        _logits, single = model_lib.prefill(params, cfg, {"tokens": tokens},
                                            cache_len, lengths=lengths)
        return scatter_lane(pool, single, slot, axes_flat)

    return jax.jit(admit, donate_argnums=(0,), static_argnums=(5,))


@functools.lru_cache(maxsize=None)
def _jitted_draft_step(cfg: ModelConfig):
    """One greedy draft decode over the full slot batch (argmax only)."""

    def step(params, tokens, cache, active):
        logits, cache = model_lib.decode_step(params, cfg, tokens, cache, active)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return jax.jit(step, donate_argnums=(2,))


class DraftModelDrafter:
    name = "model"

    def __init__(self, spec: SpecConfig, target_cfg: ModelConfig,
                 n_slots: int, cache_len: int):
        from repro.serving.slots import SlotCache

        self.spec = spec
        self.cfg = draft_config(target_cfg, spec)
        self.cache_len = cache_len
        self.params = model_lib.init_params(
            self.cfg, jax.random.PRNGKey(spec.draft_seed))
        self.store = SlotCache(self.cfg, n_slots, cache_len)
        # tokens fed to the draft cache per lane: row i <- _fed[slot][i]
        self._fed: dict[int, list[int]] = {}

    # -- lane lifecycle -----------------------------------------------------
    def admit(self, slot: int, history) -> None:
        prompt = [int(t) for t in history]
        admit = _jitted_draft_admit(self.cfg, self.cache_len)
        tokens = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        lengths = jnp.asarray([len(prompt)], jnp.int32)
        self.store.cache = admit(self.store.cache, self.params, tokens,
                                 lengths, jnp.int32(slot),
                                 self.store._axes_flat)
        self._fed[slot] = prompt

    def release(self, slot: int) -> None:
        self._fed.pop(slot, None)
        self.store.free(slot)

    # -- proposal -----------------------------------------------------------
    def _sync_pos(self, cache, slots):
        """Pin device positions to the fed-token ledger.  ``decode_step``
        zeroes inactive lanes' pos, so every dispatch re-anchors from the
        host ledger instead of trusting the previous dispatch."""
        p = np.zeros((self.store.n_slots,), np.int32)
        for s in slots:
            p[s] = len(self._fed[s])
        return {**cache, "pos": jnp.asarray(p)}

    def propose(self, slots, histories) -> list[list[int]]:
        """Batched: catch-up dispatches (usually <= 1) + k draft dispatches."""
        step = _jitted_draft_step(self.cfg)
        n = self.store.n_slots
        cache = self.store.cache

        pending = {}
        for slot, hist in zip(slots, histories):
            hist = [int(t) for t in hist]
            fed = self._fed.get(slot, [])
            common = 0
            for a, b in zip(fed, hist):
                if a != b:
                    break
                common += 1
            # rows beyond the common prefix were written from rejected
            # drafts — roll the lane back and feed what's missing
            self._fed[slot] = hist[:common]
            pending[slot] = hist[common:]

        # phase 1: lanes more than one token behind (full accept) feed
        # their extra token in one active-masked dispatch
        while any(len(c) > 1 for c in pending.values()):
            toks = np.zeros((n,), np.int32)
            active = np.zeros((n,), bool)
            cache = self._sync_pos(cache, slots)
            for s, c in list(pending.items()):
                if len(c) > 1:
                    toks[s], active[s] = c[0], True
                    self._fed[s].append(c[0])
                    pending[s] = c[1:]
            _d, cache = step(self.params, jnp.asarray(toks), cache,
                             jnp.asarray(active))

        # phase 2: k greedy draft steps, all proposing lanes at once
        # (feed t0 -> d1, then d_{j-1} -> d_j; the final draft d_k is
        # returned but never fed, so the ledger stays row-aligned)
        toks = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        for s, c in pending.items():
            toks[s], active[s] = c[0], True
        active_j = jnp.asarray(active)
        drafts = {s: [] for s in slots}
        for _ in range(self.spec.k):
            cache = self._sync_pos(cache, slots)
            for s in slots:
                self._fed[s].append(int(toks[s]))
            d, cache = step(self.params, jnp.asarray(toks), cache, active_j)
            d = np.asarray(d)
            for s in slots:
                drafts[s].append(int(d[s]))
                toks[s] = d[s]
        self.store.cache = cache
        return [drafts[s] for s in slots]
