"""Speculative-decoding configuration.

Kept dependency-free (stdlib only) so it can sit on ``RuntimeConfig``
(repro/api/) and on ``EngineConfig`` (repro/serving/) without import
cycles, and hashable so jit caches can key on it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

DRAFTERS = ("ngram", "model")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Draft-verify loop settings (see repro/spec/).

    ``k`` drafted tokens are verified per dispatch; the verify window is
    ``k + 1`` wide (last accepted token + drafts).  ``ngram`` is the
    model-free prompt-lookup drafter (free proposals; wins on repetitive
    / agentic workloads); ``model`` runs a small draft transformer with
    its own slot cache (costs k small dispatches per step; wins on
    free-form text).
    """

    enabled: bool = False
    k: int = 4
    drafter: str = "ngram"
    # prompt-lookup drafter: longest/shortest trailing n-gram to match
    ngram_max: int = 3
    ngram_min: int = 1
    # draft-model drafter: truncate the target architecture to this many
    # layers (ignored when draft_arch names a config outright)
    draft_layers: int = 2
    draft_arch: Optional[str] = None
    # PRNG seed for the draft model's (dryrun) parameters
    draft_seed: int = 0

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("spec.k must be >= 1")
        if self.drafter not in DRAFTERS:
            raise ValueError(f"spec.drafter must be one of {DRAFTERS}")
        if self.ngram_min < 1 or self.ngram_max < self.ngram_min:
            raise ValueError("need 1 <= ngram_min <= ngram_max")
        if self.draft_layers < 1:
            raise ValueError("spec.draft_layers must be >= 1")

    @property
    def width(self) -> int:
        """Verify-window width: last accepted token + k drafts."""
        return self.k + 1

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SpecConfig":
        return cls(**d)
