"""Model-free prompt-lookup drafter (PLD-style n-gram matching).

Proposals are free: match the lane's trailing n-gram against its own
prompt + generated suffix (most recent earlier occurrence wins) and, on a
miss, against the token paths of the radix tree ``repro/prefix/``
maintains — shared prefixes across requests are exactly where repeated
continuations live.  Wins on repetitive / agentic workloads (tool-call
loops, code edits, extraction over a quoted document) where the next few
tokens usually already appear verbatim upstream; on free-form text the
acceptance rate decays toward zero and the draft-model drafter takes
over.  Entirely deterministic: ties break toward the longest n-gram,
then the most recent occurrence, then lexicographically smallest tree
path — re-running a workload reproposes identical drafts.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.spec.config import SpecConfig


def _lookup(hist: Sequence[int], pattern: Sequence[int], k: int) -> Optional[list[int]]:
    """Continuation after the most recent earlier occurrence of ``pattern``
    in ``hist`` (the occurrence ending before the final token), or None."""
    n = len(pattern)
    pattern = list(pattern)
    for start in range(len(hist) - n - 1, -1, -1):
        if list(hist[start:start + n]) == pattern:
            return [int(t) for t in hist[start + n:start + n + k]]
    return None


class NgramDrafter:
    """Stateless per-lane; ``tree`` (a ``PrefixTree`` or None) is only read."""

    name = "ngram"

    def __init__(self, spec: SpecConfig, tree=None):
        self.spec = spec
        self.tree = tree

    # -- lane lifecycle (no per-lane state to keep) -------------------------
    def admit(self, slot: int, history: Sequence[int]) -> None:
        pass

    def release(self, slot: int) -> None:
        pass

    def propose(self, slots: Sequence[int],
                histories: Sequence[Sequence[int]]) -> list[list[int]]:
        """Up to ``spec.k`` drafted tokens per lane (may be shorter/empty).

        ``histories[i]`` is lane ``slots[i]``'s prompt + generated tokens,
        the final element being the next decode input t0.
        """
        return [self._propose_one(h) for h in histories]

    def _propose_one(self, hist: Sequence[int]) -> list[int]:
        k = self.spec.k
        n_max = min(self.spec.ngram_max, len(hist) - 1)
        for n in range(n_max, self.spec.ngram_min - 1, -1):
            pattern = [int(t) for t in hist[-n:]]
            cont = _lookup(hist, pattern, k)
            if cont:
                return cont
            cont = self._tree_lookup(pattern, k)
            if cont:
                return cont
        return []

    def _tree_lookup(self, pattern: list[int], k: int) -> Optional[list[int]]:
        """Scan radix-tree token paths for ``pattern``'s continuation.

        Paths are visited in sorted order and the *rightmost* occurrence
        within a path wins, mirroring ``_lookup``'s recency preference —
        deterministic regardless of dict/insertion order.
        """
        if self.tree is None:
            return None
        n = len(pattern)
        paths = []
        for node in self.tree.nodes():
            toks, cur = [], node
            while cur is not None and cur.key:
                toks = list(cur.key) + toks
                cur = cur.parent
            if len(toks) > n:
                paths.append(tuple(toks))
        for path in sorted(paths):
            for start in range(len(path) - n - 1, -1, -1):
                if list(path[start:start + n]) == pattern:
                    return [int(t) for t in path[start + n:start + n + k]]
        return None
