"""Token-ID radix tree over page-aligned prompt prefixes.

The tree maps token sequences to chains of *physical* KV pages: a node's
``key`` is a run of token ids whose length is a whole number of pages and
``pages`` holds the physical page ids storing those rows.  Edges are
path-compressed (one node can span many pages) but every structural
boundary — node splits, matches, inserts — happens on a page boundary,
because pages are the unit of sharing: a partially-filled page mixes one
request's rows with another's future rows, so it can never be aliased.

The tree itself is pure host-side bookkeeping; it never touches device
memory.  Page *ownership* (refcounts, free lists) lives in
``paging.PageManager`` — callers pair every structural change here with
the matching ``tree_ref``/``tree_unref`` there.

Siblings always differ within their first page (splits guarantee it), so
children are keyed by the first ``page_size`` tokens of their key.
Matching walks whole nodes and splits on a partial hit, which keeps the
"adopted pages form complete nodes" invariant the LRU eviction relies on:
a node's pages are either all shared with some lane or none are.

Recency is a deterministic logical clock (no wall time): ``match`` and
``insert`` touch the path they walk, and ``evict`` removes least-recently
used *leaves* first (children before parents), so a hot system prompt's
trunk survives while one-off tails age out.
"""

from __future__ import annotations

from typing import Callable, Iterator, Optional, Sequence


class PrefixNode:
    __slots__ = ("key", "pages", "children", "parent", "last_used")

    def __init__(self, key: tuple[int, ...], pages: list[int],
                 parent: Optional["PrefixNode"]):
        self.key = key
        self.pages = pages
        self.children: dict[tuple[int, ...], PrefixNode] = {}
        self.parent = parent
        self.last_used = 0

    @property
    def is_leaf(self) -> bool:
        return not self.children


class PrefixTree:
    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        self.root = PrefixNode((), [], None)
        self._clock = 0

    # -- internals ---------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _edge(self, tokens: tuple[int, ...]) -> tuple[int, ...]:
        return tokens[: self.page_size]

    def _match_pages(self, key: tuple[int, ...], tokens: Sequence[int],
                     start: int) -> int:
        """Whole pages of ``key`` matched by ``tokens[start:]``."""
        ps = self.page_size
        full = 0
        for i in range(0, len(key), ps):
            seg = tuple(tokens[start + i: start + i + ps])
            if seg != key[i: i + ps]:
                break
            full += 1
        return full

    def _split(self, node: PrefixNode, n_pages: int) -> PrefixNode:
        """Split ``node`` after its first ``n_pages`` pages; returns the new
        upper node (which keeps ``node``'s place in the tree)."""
        ps = self.page_size
        assert 0 < n_pages < len(node.pages)
        upper = PrefixNode(node.key[: n_pages * ps], node.pages[:n_pages],
                           node.parent)
        upper.last_used = node.last_used
        node.key = node.key[n_pages * ps:]
        node.pages = node.pages[n_pages:]
        node.parent.children[self._edge(upper.key)] = upper
        upper.children[self._edge(node.key)] = node
        node.parent = upper
        return upper

    # -- the public surface ------------------------------------------------
    def match(self, tokens: Sequence[int]
              ) -> tuple[list[int], tuple[PrefixNode, ...]]:
        """Longest page-aligned cached prefix of ``tokens``.

        Returns (physical pages covering the match, the matched node path).
        Only whole pages match — there is no sharing below page granularity.
        Splits a partially-matched node so the returned path's nodes are
        covered end to end (the all-or-none adoption invariant).
        """
        ps = self.page_size
        node, at = self.root, 0
        pages: list[int] = []
        path: list[PrefixNode] = []
        stamp = self._tick()
        while len(tokens) - at >= ps:
            child = node.children.get(tuple(tokens[at: at + ps]))
            if child is None:
                break
            n = self._match_pages(child.key, tokens, at)
            if n == 0:
                break
            if n < len(child.pages):
                child = self._split(child, n)
            child.last_used = stamp
            pages.extend(child.pages)
            path.append(child)
            at += len(child.key)
            node = child
        return pages, tuple(path)

    def insert(self, tokens: Sequence[int], pages: Sequence[int]
               ) -> list[int]:
        """Publish ``tokens`` (a whole number of pages) backed by ``pages``.

        Walks the existing structure; where the tree already covers a region
        the tree's pages win (the caller's duplicates stay lane-owned and
        die with the lane).  Returns the page ids NEWLY referenced by the
        tree — the caller increfs exactly those.
        """
        ps = self.page_size
        if len(tokens) % ps:
            raise ValueError("insert length must be a whole number of pages")
        if len(tokens) // ps != len(pages):
            raise ValueError("token/page length mismatch")
        tokens = tuple(int(t) for t in tokens)
        node, at = self.root, 0
        stamp = self._tick()
        while at < len(tokens):
            child = node.children.get(tokens[at: at + ps])
            if child is None:
                fresh = PrefixNode(tokens[at:], list(pages[at // ps:]), node)
                fresh.last_used = stamp
                node.children[self._edge(fresh.key)] = fresh
                return fresh.pages[:]
            n = self._match_pages(child.key, tokens, at)
            if n < len(child.pages):
                child = self._split(child, n)
            child.last_used = stamp
            at += len(child.key)
            node = child
        return []

    def touch(self, path: Sequence[PrefixNode]) -> None:
        stamp = self._tick()
        for node in path:
            node.last_used = stamp

    def evict(self, n_pages: int,
              evictable: Callable[[PrefixNode], bool],
              protect: Sequence[PrefixNode] = ()) -> list[int]:
        """Drop least-recently-used leaves until ``n_pages`` page ids have
        been released (or nothing evictable remains).  ``evictable`` vetoes
        nodes whose pages are still shared with running lanes; ``protect``
        pins a path (e.g. the match an in-flight admission is about to
        adopt).  Evicting a leaf may expose its parent as the next LRU leaf.
        """
        pinned = set(id(n) for n in protect)
        released: list[int] = []
        while len(released) < n_pages:
            victim = None
            for node in self.nodes():
                if not node.is_leaf or id(node) in pinned:
                    continue
                if not evictable(node):
                    continue
                if victim is None or node.last_used < victim.last_used:
                    victim = node
            if victim is None:
                break
            del victim.parent.children[self._edge(victim.key)]
            released.extend(victim.pages)
        return released

    def remap(self, mapping: dict[int, int]) -> None:
        """Rewrite physical page ids after a pool defrag."""
        for node in self.nodes():
            node.pages = [mapping.get(p, p) for p in node.pages]

    # -- introspection -----------------------------------------------------
    def nodes(self) -> Iterator[PrefixNode]:
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    @property
    def total_pages(self) -> int:
        return sum(len(n.pages) for n in self.nodes())

    @property
    def n_nodes(self) -> int:
        return sum(1 for _ in self.nodes())
