"""Shared-prefix KV cache: the glue between the radix tree and the page
pool.

``PrefixCache`` owns a ``tree.PrefixTree`` and pairs every structural tree
change with the matching refcount operation on the ``paging.PageManager``:

* ``plan``    — longest page-aligned cached prefix for a prompt, shaped
  into the engine's admission decision (which pages to alias, where the
  suffix (re)computation resumes, whether the last shared page must be
  copy-on-write forked first);
* ``publish`` — after a prefill completes, the prompt's *full* pages enter
  the tree (tree ref +1) so later prompts can alias them.  Only
  prefill-written rows are ever published: decode-written rows come from a
  different dispatch graph, so reusing them could break the bitwise
  cold-vs-warm guarantee;
* ``evict_for`` — LRU leaf eviction under pool pressure.  Only nodes whose
  pages no running lane aliases (refcount exactly the tree's own 1) are
  eligible; evicting a shared trunk would free nothing anyway.

Exactness contract (what keeps warm == cold bitwise): shared pages hold
rows written by (chunked) prefill, which this repo already pins down as
bitwise-equal to one-shot prefill; adopting them and resuming the suffix
through the same chunk step therefore reproduces the cold computation
exactly.  int8 pools need one extra structural condition: the chunk step
*attends dequantized pages* while one-shot prefill attends raw bf16 K/V,
so cold and warm admissions must take the SAME path for their graphs to
match.  The engine guarantees this by forcing every admission on an
int8 + prefix pool through the chunk step (any prompt length; see
``ServingEngine._should_chunk_len``), which lifts the old one-page cap:
full-prompt hits CoW-fork the boundary page and resume at the final
prompt token (``allow_fork=True``).  The re-prefilled boundary row
quantizes to the same bytes a cold chunked prefill wrote (row-independent
projections + deterministic quantize), so warm stays bitwise cold.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.prefix.tree import PrefixNode, PrefixTree


@dataclasses.dataclass
class PrefixPlan:
    """One admission's prefix decision (host-side, recomputed cheaply)."""

    pages: list[int]              # physical pages the lane will alias
    match_tokens: int             # len(pages) * page_size
    resume: int                   # first prompt position to (re)compute
    fork_index: Optional[int]     # lane page index to CoW-fork, or None
    nodes: tuple[PrefixNode, ...]  # matched path (LRU touch / evict pin)


class PrefixCache:
    def __init__(self, manager, page_size: int, allow_fork: bool = True):
        self.manager = manager
        self.page_size = page_size
        self.allow_fork = allow_fork
        self.tree = PrefixTree(page_size)
        # bumped on every structural change (publish / evict / remap) so
        # callers can memoize plans: a plan stays valid while the epoch
        # does (node SPLITS don't invalidate — they preserve page chains)
        self.epoch = 0
        # defrag moves physical pages; the tree must follow the remap so
        # shared-page aliasing survives compaction
        manager.remap_listeners.append(self.remap)

    # -- admission side ----------------------------------------------------
    def plan(self, prompt: Sequence[int]) -> Optional[PrefixPlan]:
        """Longest page-aligned cached prefix of ``prompt`` (None = miss).

        A full-prompt hit still needs one forward position (the last
        prompt token's logits seed sampling): with ``allow_fork`` the plan
        keeps every shared page, CoW-forks the one covering the final
        token and resumes at ``prompt_len - 1``; otherwise the last page is
        dropped from the match and a whole page's tokens recompute.
        """
        ps = self.page_size
        pages, path = self.tree.match(prompt)
        if not pages:
            return None
        match = len(pages) * ps
        if match < len(prompt):
            return PrefixPlan(pages=list(pages), match_tokens=match,
                              resume=match, fork_index=None, nodes=path)
        # full-prompt hit (prompt_len is a whole number of pages)
        if self.allow_fork:
            return PrefixPlan(pages=list(pages), match_tokens=match,
                              resume=len(prompt) - 1,
                              fork_index=len(pages) - 1, nodes=path)
        pages = list(pages[:-1])
        if not pages:
            return None
        return PrefixPlan(pages=pages, match_tokens=match - ps,
                          resume=match - ps, fork_index=None, nodes=path)

    def adopt(self, plan: PrefixPlan, lane: int) -> None:
        """Alias the plan's pages into ``lane``'s block table (ref +1 each)
        and refresh the matched path's recency."""
        self.manager.adopt(lane, plan.pages)
        self.tree.touch(plan.nodes)

    # -- publish / evict ---------------------------------------------------
    def publish(self, prompt: Sequence[int], lane_pages: Sequence[int]) -> int:
        """Enter the prompt's full pages into the tree; returns how many
        pages the tree newly references.  Regions the tree already covers
        keep the tree's pages (the lane's duplicates stay lane-owned)."""
        n_full = len(prompt) // self.page_size
        if n_full == 0:
            return 0
        new = self.tree.insert(list(prompt[: n_full * self.page_size]),
                               list(lane_pages[:n_full]))
        if new:
            self.manager.tree_ref(new)
            self.epoch += 1
        return len(new)

    def evict_for(self, n_pages: int,
                  protect: Sequence[PrefixNode] = ()) -> int:
        """LRU-evict tree-only nodes until ``n_pages`` physical pages are
        freed (best effort).  Returns pages actually returned to the pool."""
        ref = self.manager.refcount

        def only_tree(node: PrefixNode) -> bool:
            return all(ref[p] == 1 for p in node.pages)

        released = self.tree.evict(n_pages, only_tree, protect=protect)
        if not released:
            return 0
        self.epoch += 1
        return self.manager.tree_unref(released)

    def remap(self, mapping: dict[int, int]) -> None:
        self.tree.remap(mapping)
        self.epoch += 1

    @property
    def cached_pages(self) -> int:
        return self.tree.total_pages

    @property
    def evictable_pages(self) -> int:
        """Pages eviction could free right now: tree-held with no lane
        aliasing them (refcount exactly the tree's 1).  Upper bound — a
        protected path can pin some of them during one admission gate."""
        mgr = self.manager
        return int((mgr.tree_held & (mgr.refcount == 1)).sum())

    def stats(self) -> dict:
        """Structured snapshot of the tree for the observability layer:
        what's cached, what's reclaimable, and the epoch (plan-memo
        generation) — one dict, JSON-serializable."""
        return {
            "cached_pages": self.cached_pages,
            "evictable_pages": self.evictable_pages,
            "cached_tokens": self.cached_pages * self.page_size,
            "epoch": self.epoch,
        }
