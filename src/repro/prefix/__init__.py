"""Shared-prefix KV cache subsystem: a token-ID radix tree over
page-aligned prompt prefixes plus the refcount/copy-on-write glue that
lets many serving lanes alias the same physical KV pages.

* ``tree.PrefixTree``   — host-side radix tree (path-compressed, page-
  granular splits, deterministic LRU clock) mapping token runs to
  physical page chains.
* ``cache.PrefixCache`` — ties the tree to ``paging.PageManager``:
  admission planning (longest cached prefix, CoW fork decision), prefill
  publishing, and LRU eviction under pool pressure.

The engine integration lives in ``serving/engine.py`` (admission seeds
the lane's block table with shared pages and chunk-prefills only the
uncached suffix) behind the ``policies.PrefixPolicy`` seam; page
refcounts and forking live in ``paging/manager.py``.
"""

from repro.prefix.cache import PrefixCache, PrefixPlan
from repro.prefix.tree import PrefixNode, PrefixTree

__all__ = ["PrefixCache", "PrefixNode", "PrefixPlan", "PrefixTree"]
