"""Component power/area/energy models — paper Table II + published values.

Every constant the paper states is taken verbatim (ADC/DAC power & area vs
sampling rate, Table II).  Constants the paper defers to its refs [1], [2]
(laser wall-plug efficiency, MRR thermal tuning, TIA/BPCA analog power,
SRAM access energy, DEAS datapath energy) use typical published values,
cited inline.  ``accelerator_sim.py`` composes these into full-chip FPS /
FPS/W / FPS/W/mm2 numbers.
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Paper Table II — data converters, indexed by sampling rate in GS/s.
# ---------------------------------------------------------------------------

ADC_TABLE = {  # rate_gs: (area_mm2, power_mw)   [paper refs 13-15]
    1.0: (0.002, 2.55),
    5.0: (0.021, 11.0),
    10.0: (0.103, 29.0),
}

DAC_TABLE = {  # rate_gs: (area_mm2, power_mw)   [paper refs 16-18]
    1.0: (0.00007, 0.12),
    5.0: (0.06, 26.0),
    10.0: (0.06, 30.0),
}


def adc(rate_gs: float) -> tuple[float, float]:
    return ADC_TABLE[rate_gs]


def dac(rate_gs: float) -> tuple[float, float]:
    return DAC_TABLE[rate_gs]


# ---------------------------------------------------------------------------
# Photonic & analog components (typical published values).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhotonicConstants:
    laser_wallplug_eff: float = 0.10      # [Al-Qadasi APL'22] ~10% WPE DFB
    mrr_tuning_mw: float = 0.08           # thermal tuning / ring [TCAD'22]
    mrr_area_mm2: float = 0.00015         # 10 um ring + drop spacing
    laser_area_mm2: float = 0.05          # hybrid-integrated DFB die share
    tia_mw: float = 1.5                   # TIA / BPCA receiver analog power
    tia_area_mm2: float = 0.0003
    bpca_cap_bank_mw: float = 0.2         # integrate-and-dump switch bank
    splitter_area_mm2: float = 0.00005

    # Digital-electronic side (prior-work DEAS pipeline) — 28 nm class.
    sram_pj_per_byte: float = 1.0         # on-chip SRAM access [TCAD'22]
    deas_pj_per_op: float = 0.4           # 32-bit shift+add @ 28 nm
    deas_lane_area_mm2: float = 0.0005
    sram_mm2_per_kb: float = 0.0025
    deas_clock_ghz: float = 2.0           # electronic post-processing clock
    # Sustained ADC->SRAM->DEAS results per lane (Gops/s): 3-deep banked
    # SRAM + shift-add lanes at deas_clock -> ~6 G results/s/lane. Prior
    # work stalls the photonic front end beyond this (paper Sec. II-D).
    # Calibrated against the paper's Fig. 5 FPS ratios at 10 GS/s.
    post_gops_per_lane: float = 6.0

    # Shared digital infrastructure (both SPOGA and baselines).
    io_sram_pj_per_byte: float = 1.0      # operand staging buffers
    control_mw_per_core: float = 5.0      # sequencing, clocking, misc


CONST = PhotonicConstants()


def laser_wall_power_mw(laser_dbm: float, n_lasers: int,
                        eff: float = CONST.laser_wallplug_eff) -> float:
    """Electrical wall power for n lasers each emitting ``laser_dbm``."""
    p_opt_mw = 10.0 ** (laser_dbm / 10.0)
    return n_lasers * p_opt_mw / eff
