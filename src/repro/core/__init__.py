"""SPOGA core: bit-sliced integer GEMM dataflows + photonic hardware models.

The paper's primary contribution, adapted TPU-natively (see DESIGN.md §2):
fused radix-weighted accumulation of INT4-sliced partial products
(:mod:`repro.core.spoga`), the prior-work DEAS baseline, and the analytical
photonic scalability / transaction-level performance models that regenerate
the paper's Table I and Fig. 5.
"""

from repro.core.slicing import (
    slice_tc,
    slice_sm,
    slice_nibbles,
    slice_planes,
    reconstruct,
    reconstruct_planes,
)
from repro.core.spoga import (
    direct_matmul,
    spoga_matmul,
    deas_matmul,
    sliced_matmul,
    sliced_dot_planes,
    quantized_matmul,
)

__all__ = [
    "slice_tc",
    "slice_sm",
    "slice_nibbles",
    "slice_planes",
    "reconstruct",
    "reconstruct_planes",
    "direct_matmul",
    "spoga_matmul",
    "deas_matmul",
    "sliced_matmul",
    "sliced_dot_planes",
    "quantized_matmul",
]
