"""INT8 -> nibble (INT4 slice) decompositions used by the SPOGA dataflow.

The paper splits every INT8 operand into a Most Significant Nibble (MSN)
and Least Significant Nibble (LSN) so that the analog photonic cores only
ever see 4-bit operands (Sec. II-C).  Two exact decompositions are
implemented:

* ``tc``  — two's-complement slicing: ``x = 16 * msn + lsn`` with a *signed*
  MSN in [-8, 7] and an *unsigned* LSN in [0, 15].  This is the natural
  digital-hardware encoding and what the TPU kernel uses.

* ``sm``  — sign-magnitude slicing, faithful to the paper's +ve/-ve
  aggregation lanes: the sign of ``x`` is folded into both magnitude
  nibbles, giving ``msn in [-8, 8]`` and ``lsn in [-15, 15]`` with
  ``x = 16 * msn + lsn`` still exact.  A product of two sliced values then
  carries the product sign, exactly as the optical signal picks the + or -
  lane.

Both reconstruct **exactly** for the full int8 range including -128
(property-tested in tests/test_slicing.py).
"""

from __future__ import annotations

import jax.numpy as jnp

RADIX = 16  # one nibble
RADIX_BITS = 4

__all__ = [
    "RADIX",
    "RADIX_BITS",
    "slice_tc",
    "slice_sm",
    "reconstruct",
    "slice_nibbles",
]


def slice_tc(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two's-complement nibble slicing of an int8 array.

    Returns ``(msn, lsn)`` as int8 arrays with ``x == 16 * msn + lsn``;
    ``msn`` is the arithmetically-shifted signed high nibble in [-8, 7],
    ``lsn`` the unsigned low nibble in [0, 15].
    """
    if x.dtype != jnp.int8:
        raise TypeError(f"slice_tc expects int8, got {x.dtype}")
    msn = jnp.right_shift(x, RADIX_BITS)  # arithmetic shift for signed ints
    lsn = jnp.bitwise_and(x, RADIX - 1)   # always in [0, 15]
    return msn, lsn


def slice_sm(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sign-magnitude nibble slicing (paper's +/- lane encoding).

    The sign is folded into both nibbles: ``msn = sign(x) * (|x| >> 4)``,
    ``lsn = sign(x) * (|x| & 15)``.  Exact: ``x == 16 * msn + lsn``.
    Magnitude is computed in int32 so that ``|-128|`` does not overflow.
    """
    if x.dtype != jnp.int8:
        raise TypeError(f"slice_sm expects int8, got {x.dtype}")
    wide = x.astype(jnp.int32)
    sign = jnp.sign(wide)
    mag = jnp.abs(wide)
    msn = (sign * (mag >> RADIX_BITS)).astype(jnp.int8)  # in [-8, 8]
    lsn = (sign * (mag & (RADIX - 1))).astype(jnp.int8)  # in [-15, 15]
    return msn, lsn


def slice_nibbles(x: jnp.ndarray, encoding: str = "tc"):
    if encoding == "tc":
        return slice_tc(x)
    if encoding == "sm":
        return slice_sm(x)
    raise ValueError(f"unknown slicing encoding {encoding!r}")


def reconstruct(msn: jnp.ndarray, lsn: jnp.ndarray) -> jnp.ndarray:
    """Exact inverse of either slicing (computed in int32, cast to int8)."""
    return (msn.astype(jnp.int32) * RADIX + lsn.astype(jnp.int32)).astype(jnp.int8)
