"""INT8 -> nibble (INT4 slice) decompositions used by the SPOGA dataflow.

The paper splits every INT8 operand into a Most Significant Nibble (MSN)
and Least Significant Nibble (LSN) so that the analog photonic cores only
ever see 4-bit operands (Sec. II-C).  Two exact decompositions are
implemented:

* ``tc``  — two's-complement slicing: ``x = 16 * msn + lsn`` with a *signed*
  MSN in [-8, 7] and an *unsigned* LSN in [0, 15].  This is the natural
  digital-hardware encoding and what the TPU kernel uses.

* ``sm``  — sign-magnitude slicing, faithful to the paper's +ve/-ve
  aggregation lanes: the sign of ``x`` is folded into both magnitude
  nibbles, giving ``msn in [-8, 8]`` and ``lsn in [-15, 15]`` with
  ``x = 16 * msn + lsn`` still exact.  A product of two sliced values then
  carries the product sign, exactly as the optical signal picks the + or -
  lane.

Both reconstruct **exactly** for the full int8 range including -128
(property-tested in tests/test_slicing.py).

Beyond the paper's fixed MSN/LSN pair, :func:`slice_planes` generalizes the
two's-complement decomposition to ``n_slices`` planes of ``slice_bits`` each
(SCONNA / SiN-accelerator style slice-count vs. parallelism trade-offs):
int8 -> 2x4b (the paper), int8 -> 4x2b, int4-in-int8 -> 1x4b, int16 -> 4x4b.
Planes are emitted least-significant first; the top plane is the
arithmetically-shifted *signed* remainder, so reconstruction
``x == sum_j planes[j] << (j * slice_bits)`` is exact for ANY input value,
while the per-plane range claim (top plane in ``[-2^(b-1), 2^(b-1)-1]``)
additionally requires ``x`` to fit in ``n_slices * slice_bits`` bits.
"""

from __future__ import annotations

import jax.numpy as jnp

RADIX = 16  # one nibble
RADIX_BITS = 4

__all__ = [
    "RADIX",
    "RADIX_BITS",
    "slice_tc",
    "slice_sm",
    "reconstruct",
    "slice_nibbles",
    "slice_planes",
    "reconstruct_planes",
]


def slice_tc(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two's-complement nibble slicing of an int8 array.

    Returns ``(msn, lsn)`` as int8 arrays with ``x == 16 * msn + lsn``;
    ``msn`` is the arithmetically-shifted signed high nibble in [-8, 7],
    ``lsn`` the unsigned low nibble in [0, 15].
    """
    if x.dtype != jnp.int8:
        raise TypeError(f"slice_tc expects int8, got {x.dtype}")
    msn = jnp.right_shift(x, RADIX_BITS)  # arithmetic shift for signed ints
    lsn = jnp.bitwise_and(x, RADIX - 1)   # always in [0, 15]
    return msn, lsn


def slice_sm(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sign-magnitude nibble slicing (paper's +/- lane encoding).

    The sign is folded into both nibbles: ``msn = sign(x) * (|x| >> 4)``,
    ``lsn = sign(x) * (|x| & 15)``.  Exact: ``x == 16 * msn + lsn``.
    Magnitude is computed in int32 so that ``|-128|`` does not overflow.
    """
    if x.dtype != jnp.int8:
        raise TypeError(f"slice_sm expects int8, got {x.dtype}")
    wide = x.astype(jnp.int32)
    sign = jnp.sign(wide)
    mag = jnp.abs(wide)
    msn = (sign * (mag >> RADIX_BITS)).astype(jnp.int8)  # in [-8, 8]
    lsn = (sign * (mag & (RADIX - 1))).astype(jnp.int8)  # in [-15, 15]
    return msn, lsn


def slice_nibbles(x: jnp.ndarray, encoding: str = "tc"):
    if encoding == "tc":
        return slice_tc(x)
    if encoding == "sm":
        return slice_sm(x)
    raise ValueError(f"unknown slicing encoding {encoding!r}")


def reconstruct(msn: jnp.ndarray, lsn: jnp.ndarray) -> jnp.ndarray:
    """Exact inverse of either slicing (computed in int32, cast to int8)."""
    return (msn.astype(jnp.int32) * RADIX + lsn.astype(jnp.int32)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Generalized bit-plane slicing (arbitrary slice count / width)
# ---------------------------------------------------------------------------

_SIGNED_INTS = (jnp.int8, jnp.int16, jnp.int32)


def _plane_dtype(slice_bits: int):
    # An unsigned plane spans [0, 2^b - 1]; int8 holds it up to b == 7.
    return jnp.int8 if slice_bits <= 7 else jnp.int16


def slice_planes(
    x: jnp.ndarray, n_slices: int, slice_bits: int
) -> tuple[jnp.ndarray, ...]:
    """Two's-complement decomposition into ``n_slices`` planes, LSB first.

    ``x == sum_j planes[j] << (j * slice_bits)`` exactly, for any signed
    integer input: lower planes are the unsigned ``slice_bits``-wide digits,
    the top plane is the arithmetically-shifted signed remainder (it absorbs
    every bit above the lower planes, so reconstruction never loses range).

    ``slice_planes(x, 2, 4)`` is the paper's (LSN, MSN) pair; ``(x, 1, 4)``
    passes an int4-in-int8 operand straight through; ``(x, 4, 4)`` handles
    int16 on nibble-wide hardware.
    """
    if x.dtype not in _SIGNED_INTS:
        raise TypeError(f"slice_planes expects a signed integer array, got {x.dtype}")
    if n_slices < 1 or slice_bits < 1:
        raise ValueError(f"need n_slices >= 1 and slice_bits >= 1, got "
                         f"{n_slices}, {slice_bits}")
    out_dtype = _plane_dtype(slice_bits)
    mask = (1 << slice_bits) - 1
    planes = []
    for j in range(n_slices - 1):
        digit = jnp.bitwise_and(jnp.right_shift(x, j * slice_bits), mask)
        planes.append(digit.astype(out_dtype))
    # The top plane stays in the input dtype: it carries every remaining high
    # bit, which keeps reconstruction exact even when |x| exceeds the nominal
    # n_slices * slice_bits budget (the narrow cast would silently wrap).
    planes.append(jnp.right_shift(x, (n_slices - 1) * slice_bits))
    return tuple(planes)


def reconstruct_planes(
    planes: tuple[jnp.ndarray, ...] | list, slice_bits: int, dtype=jnp.int32
) -> jnp.ndarray:
    """Exact inverse of :func:`slice_planes` (accumulated in int32)."""
    acc = planes[0].astype(jnp.int32)
    for j, p in enumerate(planes[1:], start=1):
        acc = acc + (p.astype(jnp.int32) << (j * slice_bits))
    return acc.astype(dtype)
