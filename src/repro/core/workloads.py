"""Im2Col GEMM traces for the paper's four benchmark CNNs (Sec. IV-B).

Each convolution layer (Cin, Cout, k, stride, groups) at spatial size HxW
is unrolled into a GEMM per the Im2Col transform the paper cites [7]:

    output (M x N) = Toeplitz weights (M x K) @ input patches (K x N)
    M = Cout / groups,  K = (Cin / groups) * k * k,  N = Hout * Wout

repeated ``groups`` times (depthwise convs: groups == Cin, K == k*k).
Fully-connected layers are GEMMs with N == 1 (batch folded at sim level).

Architectures are the standard published ImageNet (224x224) definitions:
MobileNet-V2 [Sandler+18], ShuffleNet-V2 1x [Ma+18], ResNet-50 [He+16],
GoogLeNet/Inception-v1 [Szegedy+15].
"""

from __future__ import annotations

import dataclasses

__all__ = ["GemmShape", "cnn_gemm_trace", "CNNS", "total_macs"]


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """One Im2Col GEMM: (M x K) @ (K x N), executed ``groups * repeat`` times."""

    name: str
    m: int
    k: int
    n: int
    groups: int = 1
    repeat: int = 1

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.groups * self.repeat

    @property
    def dots(self) -> int:
        """Dot products of length K per instance."""
        return self.m * self.n


class _Net:
    """Tiny builder: tracks spatial size, emits GemmShapes."""

    def __init__(self, name: str, hw: int = 224):
        self.name, self.hw, self.c = name, hw, 3
        self.layers: list[GemmShape] = []

    def conv(self, cout: int, k: int, stride: int = 1, groups: int = 1,
             cin: int | None = None, tag: str = ""):
        cin = self.c if cin is None else cin
        if stride > 1:
            self.hw = (self.hw + stride - 1) // stride
        n = self.hw * self.hw
        self.layers.append(GemmShape(
            tag or f"conv{len(self.layers)}", m=cout // groups,
            k=(cin // groups) * k * k, n=n, groups=groups))
        self.c = cout
        return self

    def dw(self, k: int = 3, stride: int = 1):          # depthwise
        return self.conv(self.c, k, stride, groups=self.c, tag=f"dw{len(self.layers)}")

    def pool(self, stride: int = 2):
        self.hw = (self.hw + stride - 1) // stride
        return self

    def fc(self, cout: int):
        self.layers.append(GemmShape(f"fc{len(self.layers)}", m=cout, k=self.c, n=1))
        self.c = cout
        return self


def _resnet50() -> list[GemmShape]:
    net = _Net("resnet50")
    net.conv(64, 7, 2).pool(2)
    for cmid, cout, blocks, stride in ((64, 256, 3, 1), (128, 512, 4, 2),
                                       (256, 1024, 6, 2), (512, 2048, 3, 2)):
        cin = net.c
        net.conv(cout, 1, stride, cin=cin, tag="proj")       # downsample proj
        hw_after = net.hw
        net.hw, net.c = hw_after * stride, cin               # rewind for main path
        net.conv(cmid, 1, 1)
        net.conv(cmid, 3, stride)
        net.conv(cout, 1, 1)
        for _ in range(blocks - 1):
            net.conv(cmid, 1, 1)
            net.conv(cmid, 3, 1)
            net.conv(cout, 1, 1)
    net.pool(net.hw).fc(1000)
    return net.layers


def _mobilenet_v2() -> list[GemmShape]:
    net = _Net("mobilenet_v2")
    net.conv(32, 3, 2)
    cfg = ((1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1))
    for t, c, n, s in cfg:
        for i in range(n):
            cin = net.c
            if t != 1:
                net.conv(cin * t, 1, 1)
            net.dw(3, s if i == 0 else 1)
            net.conv(c, 1, 1)
    net.conv(1280, 1, 1)
    net.pool(net.hw).fc(1000)
    return net.layers


def _shufflenet_v2() -> list[GemmShape]:
    net = _Net("shufflenet_v2")
    net.conv(24, 3, 2).pool(2)
    for cout, units in ((116, 4), (232, 8), (464, 4)):
        half = cout // 2
        cin = net.c
        # downsample unit: both branches (stride-2 dw + 1x1 each)
        net.dw(3, 2)
        net.conv(half, 1, 1, cin=cin, tag="branch_proj")
        net.c = cin
        net.conv(half, 1, 1, cin=cin)
        net.dw(3, 1)
        net.conv(half, 1, 1, cin=half)
        net.c = cout
        for _ in range(units - 1):  # basic units act on half the channels
            net.conv(half, 1, 1, cin=half)
            saved = net.c
            net.c = half
            net.dw(3, 1)
            net.conv(half, 1, 1, cin=half)
            net.c = saved
    net.conv(1024, 1, 1)
    net.pool(net.hw).fc(1000)
    return net.layers


_INCEPTION = (  # (n1x1, n3x3red, n3x3, n5x5red, n5x5, pool_proj)
    ("3a", 64, 96, 128, 16, 32, 32), ("3b", 128, 128, 192, 32, 96, 64),
    ("4a", 192, 96, 208, 16, 48, 64), ("4b", 160, 112, 224, 24, 64, 64),
    ("4c", 128, 128, 256, 24, 64, 64), ("4d", 112, 144, 288, 32, 64, 64),
    ("4e", 256, 160, 320, 32, 128, 128), ("5a", 256, 160, 320, 32, 128, 128),
    ("5b", 384, 192, 384, 48, 128, 128),
)


def _googlenet() -> list[GemmShape]:
    net = _Net("googlenet")
    net.conv(64, 7, 2).pool(2)
    net.conv(64, 1, 1)
    net.conv(192, 3, 1)
    net.pool(2)
    for name, n1, n3r, n3, n5r, n5, pp in _INCEPTION:
        if name in ("4a", "5a"):
            net.pool(2)
        cin, hw = net.c, net.hw
        n = hw * hw
        L = net.layers
        L.append(GemmShape(f"i{name}_1x1", n1, cin, n))
        L.append(GemmShape(f"i{name}_3x3r", n3r, cin, n))
        L.append(GemmShape(f"i{name}_3x3", n3, n3r * 9, n))
        L.append(GemmShape(f"i{name}_5x5r", n5r, cin, n))
        L.append(GemmShape(f"i{name}_5x5", n5, n5r * 25, n))
        L.append(GemmShape(f"i{name}_pool", pp, cin, n))
        net.c = n1 + n3 + n5 + pp
    net.pool(net.hw).fc(1000)
    return net.layers


CNNS = {
    "mobilenet_v2": _mobilenet_v2,
    "shufflenet_v2": _shufflenet_v2,
    "resnet50": _resnet50,
    "googlenet": _googlenet,
}


def cnn_gemm_trace(name: str) -> list[GemmShape]:
    return CNNS[name]()


def total_macs(name: str) -> int:
    return sum(g.macs for g in cnn_gemm_trace(name))
