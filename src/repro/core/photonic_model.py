"""Optical link-budget / scalability model — reproduces paper Table I.

The paper sizes each photonic GEMM core by the classic silicon-photonic
link budget (methodology of its refs [1], [2], [12]):

    P_laser(dBm) - L_total(N, M)  >=  S_detector(DR, levels)      (dBm)

* ``L_total`` — insertion losses accumulated between laser and detector:
  a fixed part (fiber/chip coupling, modulator insertion, mux/demux,
  propagation) plus terms growing with the core's parallelism:
  an **N-linear** through-loss (every extra wavelength element adds MRR
  through-loss in series on the shared bus) and, for the square MAW/AMW
  organizations, a **10*log10(fanout)** splitting loss (optical power is
  divided over the M waveguides).

* ``S_detector`` — the minimum detectable per-channel power for 4-bit
  (16-level) analog signaling.  Shot-noise-limited reception scales the
  required power with the *square root* of the sampling bandwidth, i.e.
  **+5 dB per decade of data rate** — the fit below recovers ~5.15
  dB/decade, confirming the paper operates in the shot-noise regime.

Constants below are *calibrated* so that the solver reproduces all 15
entries of paper Table I exactly (see tests/test_photonic_model.py and
benchmarks/table1_scalability.py).  The paper body defers its exact
loss/sensitivity numbers to ref [2] (Vatsavai, TCAD'22), so calibration
against the published table is the faithful way to recover them; each
fitted value sits inside the published range for its component class
(MRR through loss 0.01-0.1 dB, splitter excess <1 dB, APD sensitivity
around -20 dBm at GHz rates).

Organizations modeled (paper Sec. II-A / Table I):

* ``MWA``  — SPOGA's Modulation-Weighting-Aggregation DPU: M is fixed at
  16 DPUs per core; N (INT8 vector elements == OAMEs per DPU) is set by
  the budget.  Per-element loss is higher (0.058 dB) because each OAME
  inserts a modulator *and* a weighting ring in series plus the homodyne
  aggregation mux.
* ``MAW``  — HOLYLIGHT-style square core (N == M).
* ``AMW``  — DEAPCNN-style square core (N == M); aggregation-first costs
  a little extra fixed loss, hence the smaller budget.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = [
    "LinkBudget",
    "BUDGETS",
    "max_vector_length",
    "scalability_table",
    "PAPER_TABLE_I",
]

# Shot-noise-limited sensitivity slope: dB of extra power per decade of
# data rate (ideal sqrt(BW) scaling == 5.0; fitted 5.15 absorbs the mild
# TIA noise-bandwidth excess).
SENS_DB_PER_DECADE = 5.15


@dataclasses.dataclass(frozen=True)
class LinkBudget:
    """Per-organization link-budget parameters (all in dB / dBm).

    ``headroom(P, DR)`` = power left for parallelism after fixed losses
    and detector sensitivity:  P - fixed_loss - S(DR).
    ``spend(N)``        = loss charged against that headroom by an
    N-element core: ``N * elem_loss + split_coeff * log10(fanout(N))``.
    """

    name: str
    elem_loss_db: float          # dB per additional vector element (MRR through)
    split_coeff: float           # dB per decade of waveguide fanout (10 == ideal)
    fixed_minus_sens_dbm: float  # (fixed losses + detector sensitivity) lump, 1 GS/s
    square: bool                 # True: N == M (MAW/AMW); False: M fixed (MWA)
    m_fixed: int = 16            # waveguide/DPU count when not square

    def headroom(self, laser_dbm: float, datarate_gs: float) -> float:
        return (
            laser_dbm
            - self.fixed_minus_sens_dbm
            - SENS_DB_PER_DECADE * math.log10(datarate_gs)
        )

    def spend(self, n: int) -> float:
        fanout = n if self.square else 1.0  # MWA fanout folded into fixed loss
        return n * self.elem_loss_db + self.split_coeff * math.log10(max(fanout, 1.0))


# Calibrated so scalability_table() == PAPER_TABLE_I (all 15 cells).
BUDGETS = {
    # SPOGA's DPU: 2 rings in series per OAME + homodyne mux excess.
    "MWA": LinkBudget("MWA", elem_loss_db=9.0 / 155.0, split_coeff=0.0,
                      fixed_minus_sens_dbm=-4.458065, square=False, m_fixed=16),
    # HOLYLIGHT: modulation-aggregation-weighting, square N x N core.
    "MAW": LinkBudget("MAW", elem_loss_db=0.0323, split_coeff=9.28,
                      fixed_minus_sens_dbm=10.0 - 16.5475, square=True),
    # DEAPCNN: aggregation-first costs extra fixed insertion loss.
    "AMW": LinkBudget("AMW", elem_loss_db=0.0315, split_coeff=9.21,
                      fixed_minus_sens_dbm=10.0 - 15.4675, square=True),
}


def max_vector_length(
    org: str, laser_dbm: float, datarate_gs: float, *, _tol: float = 1e-9
) -> tuple[int, int]:
    """-> (N, M): largest supported vector length / dot-product lanes.

    Solves ``spend(N) == headroom`` for continuous N (monotone, bisect)
    and rounds to the nearest integer — matching the paper's rounding.
    """
    b = BUDGETS[org]
    h = b.headroom(laser_dbm, datarate_gs)
    if h <= b.spend(1):
        return (1, b.m_fixed if not b.square else 1)
    lo, hi = 1.0, 1.0
    while b.spend(int(math.ceil(hi))) < h and hi < 1e6:
        lo, hi = hi, hi * 2
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if mid * b.elem_loss_db + b.split_coeff * math.log10(mid if b.square else 1.0) < h:
            lo = mid
        else:
            hi = mid
    n = int(round(0.5 * (lo + hi)))
    return (n, n if b.square else b.m_fixed)


def scalability_table(
    datarates=(1.0, 5.0, 10.0), mwa_powers=(1.0, 5.0, 10.0), square_power: float = 10.0
):
    """Regenerate paper Table I. -> {row_name: {DR: (N, M)}}"""
    out: dict[str, dict[float, tuple[int, int]]] = {}
    out["HOLYLIGHT [3]"] = {dr: max_vector_length("MAW", square_power, dr) for dr in datarates}
    out["DEAPCNN [9]"] = {dr: max_vector_length("AMW", square_power, dr) for dr in datarates}
    for p in mwa_powers:
        out[f"MWA ({p:g}dBm)"] = {dr: max_vector_length("MWA", p, dr) for dr in datarates}
    return out


# Ground truth from the paper (Table I): {row: {DR_GS: (N, M)}}.
PAPER_TABLE_I = {
    "HOLYLIGHT [3]": {1.0: (43, 43), 5.0: (21, 21), 10.0: (15, 15)},
    "DEAPCNN [9]": {1.0: (36, 36), 5.0: (17, 17), 10.0: (12, 12)},
    "MWA (1dBm)": {1.0: (94, 16), 5.0: (32, 16), 10.0: (5, 16)},
    "MWA (5dBm)": {1.0: (163, 16), 5.0: (101, 16), 10.0: (74, 16)},
    "MWA (10dBm)": {1.0: (249, 16), 5.0: (187, 16), 10.0: (160, 16)},
}
