"""SPOGA bit-sliced integer GEMM dataflows (pure-JAX reference layer).

Three execution strategies for an INT8 x INT8 -> INT32 GEMM, mirroring the
paper's Fig. 2:

* :func:`deas_matmul` — the *prior-work* baseline (Fig. 2a): four INT4-slice
  GEMMs executed as separate kernels whose int32 intermediate matrices are
  **materialized** (``lax.optimization_barrier`` forbids XLA from fusing
  them away, exactly like the four photonic cores + ADCs + memory of
  HOLYLIGHT/DEAPCNN-style designs), then combined by a Digital Electronic
  Shifter-and-Adder (DEAS) pass.

* :func:`spoga_matmul` — the paper's technique (Fig. 2b/c): the four partial
  products are produced *inside one fused dataflow* and radix-weighted while
  being accumulated, never leaving the accumulator.  On TPU the Pallas
  kernel in ``repro/kernels/spoga_gemm.py`` implements this tile-by-tile in
  VMEM; this jnp expression is its algebraic twin and is what the dry-run
  lowers on CPU.

* :func:`direct_matmul` — beyond-paper endpoint: native int8 x int8 -> int32
  ``dot_general`` (the MXU's byte-capable path; one op, zero slicing).

All three are **exactly** equal in int32 arithmetic (property-tested).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.slicing import RADIX_BITS, slice_nibbles, slice_planes

__all__ = [
    "direct_matmul",
    "spoga_matmul",
    "deas_matmul",
    "spoga_dot_slices",
    "sliced_dot_planes",
    "sliced_matmul",
    "quantized_matmul",
]


def _dot_i32(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """int8 x int8 -> int32 contraction over the last/first dims."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def direct_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Native int8 GEMM with int32 accumulation (no slicing)."""
    return _dot_i32(x, w)


def spoga_dot_slices(xm, xl, wm, wl):
    """The four nibble partial GEMMs + in-accumulator radix weighting.

    O = (Xm.Wm << 8) + ((Xm.Wl + Xl.Wm) << 4) + Xl.Wl

    This is the PWAB: three radix groups (16^2, 16^1, 16^0), the 16^1 lane
    receiving *two* homodyne contributions (the cross terms), all summed
    into a single accumulator before one "ADC" (output write).
    """
    mm = _dot_i32(xm, wm)
    ml = _dot_i32(xm, wl)
    lm = _dot_i32(xl, wm)
    ll = _dot_i32(xl, wl)
    return (mm << (2 * RADIX_BITS)) + ((ml + lm) << RADIX_BITS) + ll


def spoga_matmul(x: jnp.ndarray, w: jnp.ndarray, *, encoding: str = "tc") -> jnp.ndarray:
    """Fused bit-sliced INT8 GEMM (the paper's SPOGA dataflow), int32 out.

    ``encoding``: ``"tc"`` (two's-complement nibbles, TPU-native) or ``"sm"``
    (sign-magnitude, faithful to the paper's +/- optical lanes).
    """
    xm, xl = slice_nibbles(x, encoding)
    wm, wl = slice_nibbles(w, encoding)
    return spoga_dot_slices(xm, xl, wm, wl)


def sliced_dot_planes(
    x_planes,
    w_planes,
    slice_bits: int,
    *,
    dot_fn=None,
    materialize: bool = False,
) -> jnp.ndarray:
    """Generic radix-weighted accumulation over bit-plane partial products.

    ``O = sum_{i,j} (Xp_i . Wp_j) << ((i + j) * slice_bits)`` with planes
    indexed LSB-first — the PWAB generalized to ``len(x_planes) *
    len(w_planes)`` partials grouped into ``i + j`` radix lanes (each lane is
    one homodyne sum, shifted once).  ``dot_fn`` defaults to the plain int32
    contraction; MoE passes its expert-batched dot here so the radix logic
    lives in exactly one place.  ``materialize=True`` pins every partial as a
    real buffer (the DEAS prior-work baseline).
    """
    dot = dot_fn or _dot_i32
    lanes: dict[int, list] = {}
    for i, xp in enumerate(x_planes):
        for j, wp in enumerate(w_planes):
            lanes.setdefault(i + j, []).append(dot(xp, wp))
    if materialize:
        flat = [p for lane in sorted(lanes) for p in lanes[lane]]
        flat = list(jax.lax.optimization_barrier(tuple(flat)))
        for lane in sorted(lanes):
            lanes[lane] = [flat.pop(0) for _ in lanes[lane]]
    acc = None
    for lane in sorted(lanes):
        group = lanes[lane][0]
        for p in lanes[lane][1:]:
            group = group + p
        term = group << (lane * slice_bits) if lane else group
        acc = term if acc is None else acc + term
    return acc


def sliced_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    n_x_slices: int = 2,
    n_w_slices: int = 2,
    slice_bits: int = RADIX_BITS,
    materialize: bool = False,
) -> jnp.ndarray:
    """Bit-sliced integer GEMM with arbitrary plane counts, int32 out.

    ``(2, 2, 4)`` is the paper's SPOGA W8A8 dataflow; ``(2, 1, 4)`` runs
    4-bit weights against int8 activations with half the partial products;
    ``(4, 4, 4)`` carries int16 operands on the same nibble hardware.
    Exact vs. :func:`direct_matmul` in int32 (mod-2^32 on overflow, which
    wraps identically in both).
    """
    xp = slice_planes(x, n_x_slices, slice_bits)
    wp = slice_planes(w, n_w_slices, slice_bits)
    return sliced_dot_planes(xp, wp, slice_bits, materialize=materialize)


def deas_matmul(x: jnp.ndarray, w: jnp.ndarray, *, encoding: str = "tc") -> jnp.ndarray:
    """Prior-work baseline: 4 separate INT4 GEMMs, materialized, then DEAS.

    ``optimization_barrier`` pins each intermediate matrix as a real buffer
    (4 x M x N x int32 of extra HBM traffic), reproducing the
    ADC-conversion + memory round-trip structure the paper eliminates.
    """
    xm, xl = slice_nibbles(x, encoding)
    wm, wl = slice_nibbles(w, encoding)
    # Four independent "photonic cores", each producing an intermediate
    # int32 matrix that must be stored before post-processing.
    partials = (_dot_i32(xm, wm), _dot_i32(xm, wl), _dot_i32(xl, wm), _dot_i32(xl, wl))
    mm, ml, lm, ll = jax.lax.optimization_barrier(partials)
    # DEAS: digital shift-and-add over the stored intermediates.
    return (mm << (2 * RADIX_BITS)) + ((ml + lm) << RADIX_BITS) + ll


def quantized_matmul(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    mode: str = "int8_spoga",
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """W8A8 GEMM with dequantizing epilogue.

    ``x_q``: (..., K) int8, row-wise scale ``x_scale`` (..., 1)
    ``w_q``: (K, N) int8, per-output-channel scale ``w_scale`` (N,) or (1, N)

    Dispatch goes through the :mod:`repro.backends` registry (imported
    lazily — backends builds on this module), so the same mode strings that
    configure model layers select the dataflow here.
    """
    from repro.backends import gemm_int  # lazy: avoids the import cycle

    acc = gemm_int(x_q, w_q, quant_mode=mode)
    return (acc.astype(jnp.float32) * x_scale * jnp.reshape(w_scale, (1,) * (acc.ndim - 1) + (-1,))).astype(out_dtype)
