"""Transaction-level simulator for photonic GEMM accelerators — paper Fig. 5.

Reimplements the paper's evaluation methodology ("a custom, transaction-level
Python-based simulator", Sec. IV-B): map every Im2Col GEMM of a CNN trace
onto a photonic accelerator, count time steps and electronic events, and
report FPS, FPS/W and FPS/W/mm2 for

* ``SPOGA``       (MWA organization, the paper's design),
* ``HOLYLIGHT``   (MAW organization, ref [3] baseline),
* ``DEAPCNN``     (AMW organization, ref [9] baseline),

each at 1 / 5 / 10 GS/s.  Core geometry (N, M) comes from the calibrated
link budget in ``photonic_model`` (paper Table I).

Comparison normalization — equal **GEMM-group count** per accelerator
(paper Fig. 2a): one SPOGA core processes INT8 natively, while a prior-work
"group" needs **four** INT4 slice cores (Core_1..Core_4) plus the DEAS
post-processing pipeline, exactly as drawn in the paper.

Dataflow semantics (Sec. III):

* SPOGA streams one K-chunk of weights and inputs per time step; the BPCA
  **integrates charge across the ceil(K/N) chunks** of a dot product, so
  exactly one ADC conversion fires per completed dot product and no
  intermediate value is ever stored (3 O/E + 1 ADC per result).
* Prior-work slice cores convert **every lane, every step, every slice**
  (TIA receivers have no temporal memory): 4 ADC conversions per chunk per
  result, an SRAM write+read round trip for each intermediate value, and a
  DEAS shift-add pass to combine the four intermediate matrices.  The DEAS
  SRAM must be sized to buffer the four int32 intermediate matrices of the
  largest layer — the dominant area overhead SPOGA eliminates.

Both stream weights at the photonic data rate (weight-stationary mapping is
incompatible with temporal K-accumulation), so both pay DR-class DACs on
the weight path; SPOGA simply needs far fewer conversions downstream.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import energy_model as em
from repro.core.photonic_model import max_vector_length
from repro.core.workloads import GemmShape, cnn_gemm_trace

__all__ = ["AccelConfig", "SimResult", "simulate", "fig5_comparison", "ACCELS"]


@dataclasses.dataclass(frozen=True)
class AccelConfig:
    name: str
    org: str                  # "MWA" (SPOGA) | "MAW" (HOLYLIGHT) | "AMW" (DEAPCNN)
    datarate_gs: float
    laser_dbm: float = 10.0
    n_groups: int = 8         # SPOGA cores, or 4-slice-core groups (Fig. 2a)

    @property
    def geometry(self) -> tuple[int, int]:
        """(N, M): vector length x dot-product lanes per core."""
        return max_vector_length(self.org, self.laser_dbm, self.datarate_gs)

    @property
    def is_spoga(self) -> bool:
        return self.org == "MWA"


@dataclasses.dataclass
class SimResult:
    name: str
    workload: str
    time_s: float
    energy_j: float
    power_w: float
    area_mm2: float
    adc_samples: float
    sram_bytes: float
    deas_ops: float

    @property
    def fps(self) -> float:
        return 1.0 / self.time_s

    @property
    def fps_per_w(self) -> float:
        return self.fps / self.power_w

    @property
    def fps_per_w_mm2(self) -> float:
        return self.fps_per_w / self.area_mm2


# ---------------------------------------------------------------------------
# Component inventory per GEMM group
# ---------------------------------------------------------------------------

def _group_inventory(cfg: AccelConfig) -> dict:
    """Component counts for one SPOGA core / one 4-slice-core group."""
    n, m = cfg.geometry
    c = em.CONST
    if cfg.is_spoga:
        # One core: N OAMEs x M DPUs. 4 wavelengths per DPU (homodyne fan-in
        # across OAMEs). Input nibbles modulated once per core (shared by
        # all DPUs): 2N DR-class DACs driving 4N modulator rings; per-DPU
        # weight banks: 4N rings fed by 2N DR-class DACs each (streaming).
        # 4 lasers per core: the M-way DPU fanout loss is part of the MWA
        # fixed link-budget lump (photonic_model calibration), so each
        # wavelength needs exactly one source.
        return dict(
            rings=4 * n * (m + 1),
            lasers=4,
            dacs_fast=2 * n + 2 * n * m,   # input + streaming weight DACs
            dacs_slow=0,
            adcs=m,                        # one per DPU (PWAB output)
            oe_receivers=3 * m,            # 3 BPCAs per DPU
            deas_lanes=0,
            sram_kb=4.0 * m,               # output staging only
        )
    # Prior-work group: 4 INT4 slice cores (n x n) + DEAS + intermediate SRAM.
    # The four slice cores process the same operands' nibbles on identical
    # wavelength grids, so the group shares one n-laser comb (split 4 ways).
    # AMW (DEAPCNN) aggregates wavelengths *before* modulation, so every
    # waveguide carries its own n-modulator array (n*n input DACs/core);
    # MAW (HOLYLIGHT) modulates once per core before the split (n DACs).
    mods = n * n if cfg.org == "AMW" else n
    return dict(
        rings=4 * (n * n + n + mods),
        lasers=n,
        dacs_fast=4 * (mods + n * n),      # input + streaming weight DACs
        dacs_slow=0,
        adcs=4 * n,                        # one per waveguide per slice core
        oe_receivers=4 * n,
        deas_lanes=n,
        sram_kb=0.0,                       # sized per workload (intermediates)
    )


def _intermediate_sram_kb(cfg: AccelConfig, trace: list[GemmShape]) -> float:
    """Prior work stores the 4 int32 intermediate matrices in digital memory
    ("these matrices have to be ... stored in digital memory and accessed
    from the memory", Sec. II-D) — sized for the largest layer.
    """
    if cfg.is_spoga:
        return 0.0
    biggest = max(g.m * g.n for g in trace)
    return 4 * biggest * 4 / 1024.0


def _static_power_w(cfg: AccelConfig, inv: dict) -> float:
    c = em.CONST
    mw = (
        em.laser_wall_power_mw(cfg.laser_dbm, inv["lasers"])
        + inv["rings"] * c.mrr_tuning_mw
        + inv["oe_receivers"] * (c.tia_mw + (c.bpca_cap_bank_mw if cfg.is_spoga else 0.0))
        + c.control_mw_per_core * (1 if cfg.is_spoga else 4)
    )
    return mw / 1e3


def _area_mm2(cfg: AccelConfig, inv: dict, sram_kb: float) -> float:
    c = em.CONST
    adc_a, _ = em.adc(cfg.datarate_gs)
    dac_a, _ = em.dac(cfg.datarate_gs)
    return (
        inv["rings"] * c.mrr_area_mm2
        + inv["lasers"] * c.laser_area_mm2
        + inv["dacs_fast"] * dac_a
        + inv["adcs"] * adc_a
        + inv["oe_receivers"] * c.tia_area_mm2
        + inv["deas_lanes"] * c.deas_lane_area_mm2
        + (inv["sram_kb"] + sram_kb) * c.sram_mm2_per_kb
    )


# ---------------------------------------------------------------------------
# Transaction-level execution of one GEMM trace
# ---------------------------------------------------------------------------

def _run_trace(cfg: AccelConfig, trace: list[GemmShape]) -> tuple[float, dict]:
    """-> (time_steps, event counts) for one frame."""
    n, m = cfg.geometry
    groups = cfg.n_groups
    steps = 0.0
    ev = dict(adc=0.0, dac_fast=0.0, sram_bytes=0.0, deas=0.0, oe=0.0)

    for g in trace:
        inst = g.groups * g.repeat
        dots = g.dots * inst                      # results to produce
        if cfg.is_spoga:
            # K INT8 elements per dot; one DPU retires a dot every `chunks`
            # steps (BPCA temporal integration), M dots in flight per core.
            chunks = math.ceil(g.k / n)
            waves = math.ceil(dots / (groups * m))
            steps += waves * chunks
            ev["adc"] += dots                      # single ADC per dot
            ev["oe"] += 3 * dots                   # 3 BPCA transductions
            # DR-class DAC events: inputs 2N per core-step + weights 2N per
            # DPU-step (both stream every step).
            ev["dac_fast"] += waves * chunks * groups * (2 * n + 2 * n * m)
            ev["sram_bytes"] += dots * 4           # final output write only
        else:
            # 4 INT4 slice GEMMs in parallel on the group's 4 cores.
            chunks = math.ceil(g.k / n)
            waves = math.ceil(dots / (groups * n))  # n lanes per slice core
            # The ADC -> SRAM -> DEAS pipeline sustains `post_gops` results
            # per lane per second; above that the photonic front end stalls
            # (the paper's "sluggish DEAS" bottleneck, Sec. II-D). SPOGA
            # never stalls: one conversion per completed dot product.
            throttle = max(1.0, cfg.datarate_gs / em.CONST.post_gops_per_lane)
            steps += waves * chunks * throttle
            conv = dots * chunks * 4               # ADC every chunk x slice
            ev["adc"] += conv
            ev["oe"] += conv
            ev["dac_fast"] += waves * chunks * groups * 4 * (n + n * n)
            # intermediate write + read for DEAS, 4 B each way
            ev["sram_bytes"] += conv * 8 + dots * 4
            ev["deas"] += conv + dots              # shift-adds + final combine
    return steps, ev


def simulate(cfg: AccelConfig, workload: str) -> SimResult:
    trace = cnn_gemm_trace(workload)
    inv = _group_inventory(cfg)
    sram_kb = _intermediate_sram_kb(cfg, trace)
    c = em.CONST

    steps, ev = _run_trace(cfg, trace)
    time_s = steps / (cfg.datarate_gs * 1e9)

    _, adc_mw = em.adc(cfg.datarate_gs)
    _, dac_mw = em.dac(cfg.datarate_gs)
    adc_j = adc_mw * 1e-3 / (cfg.datarate_gs * 1e9)   # energy per sample
    dac_j = dac_mw * 1e-3 / (cfg.datarate_gs * 1e9)

    dyn_j = (
        ev["adc"] * adc_j
        + ev["dac_fast"] * dac_j
        + ev["sram_bytes"] * c.sram_pj_per_byte * 1e-12
        + ev["deas"] * c.deas_pj_per_op * 1e-12
    )
    static_w = cfg.n_groups * _static_power_w(cfg, inv)
    energy_j = dyn_j + static_w * time_s
    power_w = energy_j / time_s
    area = cfg.n_groups * _area_mm2(cfg, inv, sram_kb)

    return SimResult(cfg.name, workload, time_s, energy_j, power_w, area,
                     ev["adc"], ev["sram_bytes"], ev["deas"])


# ---------------------------------------------------------------------------
# Fig. 5 — full comparison
# ---------------------------------------------------------------------------

ACCELS = {
    f"{name}_{int(dr)}": AccelConfig(f"{name}_{int(dr)}", org, dr)
    for name, org in (("SPOGA", "MWA"), ("HOLYLIGHT", "MAW"), ("DEAPCNN", "AMW"))
    for dr in (1.0, 5.0, 10.0)
}

WORKLOADS = ("mobilenet_v2", "shufflenet_v2", "resnet50", "googlenet")


def _gmean(xs) -> float:
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def fig5_comparison(workloads=WORKLOADS, accels=None) -> dict:
    """-> {accel: {workload: SimResult, "gmean": {fps, fps_per_w, ...}}}"""
    out: dict[str, dict] = {}
    for name, cfg in (accels or ACCELS).items():
        rows = {w: simulate(cfg, w) for w in workloads}
        out[name] = {
            **rows,
            "gmean": {
                "fps": _gmean(r.fps for r in rows.values()),
                "fps_per_w": _gmean(r.fps_per_w for r in rows.values()),
                "fps_per_w_mm2": _gmean(r.fps_per_w_mm2 for r in rows.values()),
            },
        }
    return out


# Paper Sec. IV-C headline ratios (geometric mean over the four CNNs).
PAPER_RATIOS = {
    ("fps", "SPOGA_10", "DEAPCNN_10"): 14.4,
    ("fps", "SPOGA_10", "HOLYLIGHT_10"): 11.1,
    ("fps_per_w", "SPOGA_10", "DEAPCNN_10"): 2.0,
    ("fps_per_w", "SPOGA_10", "HOLYLIGHT_10"): 1.3,
    ("fps_per_w_mm2", "SPOGA_1", "DEAPCNN_1"): 28.5,
    ("fps_per_w_mm2", "SPOGA_1", "HOLYLIGHT_1"): 22.2,
}


def headline_ratios(comparison=None) -> dict:
    comp = comparison or fig5_comparison()
    out = {}
    for (metric, a, b), paper in PAPER_RATIOS.items():
        ours = comp[a]["gmean"][metric] / comp[b]["gmean"][metric]
        out[f"{metric}: {a} / {b}"] = {"paper": paper, "ours": round(ours, 2)}
    return out
