"""Flight recorder: capture a serving run into a replayable bundle.

Arm it with ``ObsConfig(record_path=DIR)`` (``serve --record DIR``).  The
bundle is a plain directory, self-contained enough for
``repro.obs.replay`` to rebuild the engine offline and reproduce the run
bitwise:

``manifest.json``
    Config fingerprint: ``RuntimeConfig.to_dict()``, the arch name, the
    ``LLM`` seed, the resolved engine geometry (``EngineConfig`` as a
    dict — cache length, prefill buckets, page budget...), plus
    environment provenance (git SHA, jax/jaxlib versions, backend
    platform, python).  Provenance mismatches at replay are *warnings*,
    config mismatches are what the differ exists to find.
``arrivals.jsonl``
    One line per ``add_request``: prompt tokens, ``max_new_tokens``,
    ``SamplingParams`` (the per-request PRNG seed lives here), priority,
    resolved EOS token, and the engine step at which the request was
    submitted — the replay schedule.
``journal.jsonl``
    The ``EventLog`` stream (the per-step decision journal).  The
    recorder hands its path to ``ObsConfig.build`` so the engine's
    normal event emission IS the recording — no second code path.
``outputs.jsonl``
    Per finished request: the token stream and finish reason — the
    bitwise ground truth replay is checked against.
``clock.jsonl``
    The decision-clock tape: every wall-time reading that can influence
    a scheduling decision (submit stamps, deadline shedding/preemption,
    admission lateness), one float per line in read order.  Replay
    scripts these readings back, so time-dependent decisions reproduce
    exactly even though the replay runs at a different wall time.

Everything here is host-side bookkeeping on paths the engine already
executes per request (not per token), so an armed recorder adds no device
syncs and leaves every jaxpr untouched; disarmed, the engine holds
``recorder=None`` and pays nothing.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
import warnings
from typing import Callable, Optional

BUNDLE_VERSION = 1

MANIFEST = "manifest.json"
ARRIVALS = "arrivals.jsonl"
JOURNAL = "journal.jsonl"
OUTPUTS = "outputs.jsonl"
CLOCK = "clock.jsonl"


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def environment_fingerprint() -> dict:
    """Provenance for the manifest: versions, backend, git SHA."""
    fp = {
        "python": sys.version.split()[0],
        "git_sha": _git_sha(),
        "recorded_at": time.time(),
    }
    try:
        import jax

        fp["jax"] = jax.__version__
        fp["backend"] = jax.default_backend()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        fp["jax"] = None
        fp["backend"] = None
    return fp


class FlightRecorder:
    """Writes one run's bundle; owned by ``Observability``.

    The engine calls ``record_arrival`` / ``record_finish`` on its
    per-request paths and routes its decision clock through
    ``wrap_clock``; the ``LLM`` facade stamps run identity via
    ``set_run_info``; ``record_engine`` pins the resolved geometry.
    Files are flushed eagerly (arrivals are rare relative to steps), so
    a crashed run still leaves a loadable bundle.
    """

    def __init__(self, path: str):
        self.path = path
        os.makedirs(path, exist_ok=True)
        self._manifest: dict = {
            "version": BUNDLE_VERSION,
            "fingerprint": environment_fingerprint(),
        }
        self._arrivals = open(os.path.join(path, ARRIVALS), "w")
        self._outputs = open(os.path.join(path, OUTPUTS), "w")
        self._clock = open(os.path.join(path, CLOCK), "w")
        self._closed = False
        self._write_manifest()

    # -- paths -------------------------------------------------------------
    @property
    def journal_path(self) -> str:
        return os.path.join(self.path, JOURNAL)

    # -- manifest ----------------------------------------------------------
    def _write_manifest(self) -> None:
        tmp = os.path.join(self.path, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(self._manifest, f, indent=1, sort_keys=True)
        os.replace(tmp, os.path.join(self.path, MANIFEST))

    def set_run_info(self, *, arch: Optional[str], runtime,
                     seed: int, checkpoint_dir: Optional[str]) -> None:
        """Stamp the LLM-level identity needed to rebuild the model."""
        self._manifest.update(
            arch=arch,
            seed=int(seed),
            checkpoint_dir=checkpoint_dir,
            runtime=runtime.to_dict(),
        )
        self._write_manifest()

    def record_engine(self, engine_cfg) -> None:
        """Pin the resolved engine geometry (cache_len, buckets, ...).

        ``LLM`` may rebuild the engine when request shapes outgrow the
        current geometry; a bundle replays against ONE geometry, so a
        mid-record rebuild is recorded (latest wins) but warned about.
        """
        d = dataclasses.asdict(engine_cfg)
        if d.get("prefill_buckets") is not None:
            d["prefill_buckets"] = list(d["prefill_buckets"])
        prev = self._manifest.get("engine")
        if prev is not None and prev != d:
            self._manifest["engine_rebuilds"] = (
                self._manifest.get("engine_rebuilds", 0) + 1)
            warnings.warn(
                "flight recorder: engine rebuilt mid-record; the bundle "
                "keeps the newest geometry and earlier decisions may not "
                "replay", stacklevel=2)
        self._manifest["engine"] = d
        self._write_manifest()

    # -- decision clock ----------------------------------------------------
    def wrap_clock(self, base: Callable[[], float] = time.perf_counter,
                   ) -> Callable[[], float]:
        """A clock whose every reading is appended to the tape."""
        fh = self._clock

        def clock() -> float:
            t = base()
            fh.write(repr(t) + "\n")
            return t

        return clock

    # -- per-request streams -----------------------------------------------
    def record_arrival(self, req, step: int) -> None:
        rec = {
            "req_id": int(req.req_id),
            "step": int(step),
            "submit_t": req.submit_time,
            "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": int(req.max_new_tokens),
            "sampling": dataclasses.asdict(req.sampling),
            "priority": int(req.priority),
            "eos_token": None if req.eos_token is None else int(req.eos_token),
        }
        self._arrivals.write(json.dumps(rec) + "\n")
        self._arrivals.flush()

    def record_finish(self, req) -> None:
        rec = {
            "req_id": int(req.req_id),
            "tokens": [int(t) for t in req.output_tokens],
            "reason": req.finish_reason,
        }
        self._outputs.write(json.dumps(rec) + "\n")
        self._outputs.flush()

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for fh in (self._arrivals, self._outputs, self._clock):
            fh.flush()
            fh.close()
        self._write_manifest()
