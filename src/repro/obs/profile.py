"""``jax.profiler`` hooks: wrap N engine steps in a device profiler trace.

The span tracer (``obs.trace``) times *dispatches* from the host; when the
question is what the device itself was doing inside one (kernel timings,
HLO-level breakdown, transfer stalls), that is ``jax.profiler``'s job.
``StepProfiler`` arms it over the engine loop: the first ``step_begin``
after construction starts a trace into ``log_dir``, and after
``n_steps`` completed steps the trace stops and the profiler goes inert —
so a ``--profile DIR`` serve run captures a bounded window instead of an
unboundedly-growing trace.  View with TensorBoard's profile plugin or
``xprof`` (the trace also contains a Perfetto-loadable ``.trace.json.gz``
under ``plugins/profile/``).

``NullStepProfiler`` is the disabled twin: both hooks are no-ops, so the
engine calls them unconditionally at zero cost.
"""

from __future__ import annotations


class StepProfiler:
    def __init__(self, log_dir: str, n_steps: int = 20):
        if n_steps < 1:
            raise ValueError("n_steps must be >= 1")
        self.log_dir = log_dir
        self.n_steps = n_steps
        self.active = False
        self.done = False
        self._steps_seen = 0

    def step_begin(self) -> None:
        if self.done or self.active:
            return
        import jax

        jax.profiler.start_trace(self.log_dir)
        self.active = True

    def step_end(self) -> None:
        if not self.active:
            return
        self._steps_seen += 1
        if self._steps_seen >= self.n_steps:
            self.close()

    def close(self) -> None:
        """Stop the trace if still running (idempotent; also the engine's
        end-of-run hook so short runs flush a partial window)."""
        if self.active:
            import jax

            jax.profiler.stop_trace()
            self.active = False
            self.done = True


class NullStepProfiler:
    """Disabled profiler: hooks are no-ops."""

    active = False
    done = False

    def step_begin(self) -> None:
        pass

    def step_end(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_PROFILER = NullStepProfiler()
