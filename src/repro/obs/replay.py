"""Deterministic replay of a flight-recorder bundle.

``replay_bundle(path)`` (surfaced as ``LLM.replay`` and
``python -m repro.launch.replay``) rebuilds the engine from the bundle's
config fingerprint, re-feeds the recorded arrivals on the recorded step
schedule, scripts the recorded decision-clock readings back through the
scheduler, and checks two things bitwise:

- every recorded request's greedy token stream, and
- the decision journal, event by event.

When they differ, ``diff_journals`` walks recorded-vs-replayed journals
to the *first* divergent decision and reports both contexts::

    replay diverged at event 412 (recorded seq 412):
      recorded admitted(req=7, mode=prefix, pages=[3, 9], ...)
      replayed rejected(req=7, reason=pages, ...)

Fields that legitimately differ between runs — timestamps and the
latency-derived metrics (``t``, ``wall``, ``queue_wait_s``, ...) — are
stripped before comparison; everything else (slots, lanes, page
assignments, chunk offsets, spec acceptance counts, reasons) must match
exactly.  ``replay_bundle(runtime_transform=...)`` deliberately perturbs
the rebuilt config (e.g. a smaller page pool) to ask "which decision goes
first?" — the debugging workflow the recorder exists for.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Optional

from repro.obs.recorder import (
    ARRIVALS,
    CLOCK,
    JOURNAL,
    MANIFEST,
    OUTPUTS,
)

# event fields that depend on when the run happened rather than on what
# the engine decided: excluded from the journal diff (the decision clock
# is replayed, but metric timestamps intentionally stay on real time)
VOLATILE_FIELDS = frozenset({
    "t", "wall", "queue_wait_s", "ttft_s", "latency_s", "waited_s",
    "deadline_hit",
})


# ---------------------------------------------------------------------------
# bundle loading
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Bundle:
    path: str
    manifest: dict
    arrivals: list[dict]
    journal: list[dict]
    outputs: list[dict]
    clock: list[float]


def _read_jsonl(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def load_bundle(path: str) -> Bundle:
    mpath = os.path.join(path, MANIFEST)
    if not os.path.exists(mpath):
        raise FileNotFoundError(f"not a flight-recorder bundle: {mpath}")
    with open(mpath) as f:
        manifest = json.load(f)
    # the journal stream may have rotated once: <path>.1 holds the older half
    journal = (_read_jsonl(os.path.join(path, JOURNAL + ".1"))
               + _read_jsonl(os.path.join(path, JOURNAL)))
    clock: list[float] = []
    cpath = os.path.join(path, CLOCK)
    if os.path.exists(cpath):
        with open(cpath) as f:
            clock = [float(line) for line in f if line.strip()]
    return Bundle(
        path=path,
        manifest=manifest,
        arrivals=_read_jsonl(os.path.join(path, ARRIVALS)),
        journal=journal,
        outputs=_read_jsonl(os.path.join(path, OUTPUTS)),
        clock=clock,
    )


# ---------------------------------------------------------------------------
# the scripted decision clock
# ---------------------------------------------------------------------------

class ReplayClock:
    """Replays the recorded decision-clock tape reading by reading.

    Every decision-relevant wall-time read the recorded engine made was
    taped in order; a bitwise replay makes exactly the same reads, so
    popping the tape reproduces every time-dependent decision (deadline
    sheds, preemptions, lateness stamps).  If the replay diverges into
    *extra* reads the tape holds at its final instant — deadline math
    stays finite and the journal differ reports the real divergence.
    """

    def __init__(self, tape):
        self._tape = list(tape)
        self._i = 0
        self.exhausted_reads = 0

    def __call__(self) -> float:
        if self._i < len(self._tape):
            t = self._tape[self._i]
            self._i += 1
            return t
        self.exhausted_reads += 1
        return self._tape[-1] if self._tape else 0.0


# ---------------------------------------------------------------------------
# journal diffing
# ---------------------------------------------------------------------------

def canonical_event(ev: dict) -> dict:
    """An event with volatile fields stripped, JSON-normalized (tuples
    become lists, exactly as the recorded journal was serialized)."""
    ev = {k: v for k, v in ev.items() if k not in VOLATILE_FIELDS
          and k != "seq"}
    return json.loads(json.dumps(ev))


def _describe(ev: Optional[dict]) -> str:
    if ev is None:
        return "<journal ended>"
    kind = ev.get("kind", "?")
    rid = ev.get("req_id")
    skip = VOLATILE_FIELDS | {"kind", "req_id", "seq"}
    rest = {k: v for k, v in sorted(ev.items()) if k not in skip}
    parts = ([f"req={rid}"] if rid is not None else [])
    parts += [f"{k}={v}" for k, v in rest.items()]
    return f"{kind}({', '.join(parts)})"


@dataclasses.dataclass
class Divergence:
    """The first recorded-vs-replayed journal mismatch."""

    index: int                      # position in the (merged) journal
    recorded: Optional[dict]        # raw recorded event (or None: replay ran long)
    replayed: Optional[dict]        # raw replayed event (or None: replay ended early)

    def format(self) -> str:
        seq = (self.recorded or {}).get("seq", self.index)
        return (f"replay diverged at event {self.index} (recorded seq {seq}):\n"
                f"  recorded {_describe(self.recorded)}\n"
                f"  replayed {_describe(self.replayed)}")


def diff_journals(recorded: list[dict], replayed: list[dict],
                  ) -> Optional[Divergence]:
    """First divergent decision between two journals, or None if equal."""
    n = max(len(recorded), len(replayed))
    for i in range(n):
        a = recorded[i] if i < len(recorded) else None
        b = replayed[i] if i < len(replayed) else None
        if a is None or b is None:
            return Divergence(index=i, recorded=a, replayed=b)
        if canonical_event(a) != canonical_event(b):
            return Divergence(index=i, recorded=a, replayed=b)
    return None


# ---------------------------------------------------------------------------
# the replayer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplayResult:
    bundle: str
    ok: bool
    n_requests: int
    n_recorded_events: int
    n_replayed_events: int
    token_mismatches: list[dict]
    divergence: Optional[Divergence]
    warnings: list[str]
    error: Optional[str] = None

    def summary(self) -> str:
        lines = [f"[replay] bundle {self.bundle}: {self.n_requests} "
                 f"request(s), {self.n_recorded_events} recorded / "
                 f"{self.n_replayed_events} replayed journal events"]
        for w in self.warnings:
            lines.append(f"[replay] warning: {w}")
        if self.error:
            lines.append(f"[replay] replay errored: {self.error}")
        for m in self.token_mismatches:
            lines.append(
                f"[replay] token mismatch req={m['req_id']}: recorded "
                f"{m['recorded']} vs replayed {m['replayed']}")
        if self.divergence is not None:
            lines.append(self.divergence.format())
        if self.ok:
            lines.append("[replay] bitwise identical: tokens and decision "
                         "journal reproduce the recorded run")
        return "\n".join(lines)


def _fingerprint_warnings(manifest: dict) -> list[str]:
    from repro.obs.recorder import environment_fingerprint

    recorded = manifest.get("fingerprint") or {}
    here = environment_fingerprint()
    warns = []
    for key in ("git_sha", "jax", "backend", "python"):
        a, b = recorded.get(key), here.get(key)
        if a is not None and b is not None and a != b:
            warns.append(f"{key} differs: recorded {a!r}, replaying on {b!r}")
    return warns


def replay_bundle(path: str,
                  runtime_transform: Optional[Callable] = None,
                  max_steps: int = 100_000) -> ReplayResult:
    """Rebuild the recorded engine, re-run the schedule, compare bitwise.

    ``runtime_transform(runtime) -> runtime`` perturbs the rebuilt config
    on purpose (the differ then names the first decision that changed);
    leave it None for a fidelity check.
    """
    from repro.api import LLM, RuntimeConfig
    from repro.api.config import ObsConfig
    from repro.serving.sampling import SamplingParams

    bundle = load_bundle(path)
    man = bundle.manifest
    if man.get("arch") is None or man.get("runtime") is None:
        raise ValueError(
            f"bundle {path} has no arch/runtime in its manifest (the "
            "recording LLM was built from a raw config=; replay needs a "
            "registry arch name)")

    rt = RuntimeConfig.from_dict(man["runtime"])
    eng = man.get("engine") or {}
    # pin the resolved geometry: the recorded run sized cache_len/buckets
    # from its workload hints, which the bundle no longer carries
    if eng.get("cache_len") is not None:
        rt = dataclasses.replace(
            rt, kv=dataclasses.replace(rt.kv, cache_len=eng["cache_len"]))
    buckets = eng.get("prefill_buckets")
    if buckets is not None:
        rt = dataclasses.replace(
            rt, scheduler=dataclasses.replace(
                rt.scheduler, prefill_buckets=tuple(buckets)))
    # replay observes in memory only: no recorder, no sinks, no server
    rt = dataclasses.replace(rt, obs=ObsConfig(enabled=True))
    if runtime_transform is not None:
        rt = runtime_transform(rt)

    warns = _fingerprint_warnings(man)
    if man.get("engine_rebuilds"):
        warns.append(f"recorded engine was rebuilt "
                     f"{man['engine_rebuilds']} time(s) mid-record; only "
                     f"the final geometry replays")

    llm = LLM(arch=man["arch"], runtime=rt, seed=man.get("seed", 0),
              checkpoint_dir=man.get("checkpoint_dir"))
    error = None
    reqs: dict[int, object] = {}
    try:
        engine = llm.engine
        clock = ReplayClock(bundle.clock)
        engine.set_clock(clock)
        pending = sorted(bundle.arrivals,
                         key=lambda a: (a["step"], a["req_id"]))
        if pending:
            # req_ids must line up with the recorded journal
            engine._next_id = pending[0]["req_id"]
        i = 0
        steps = 0
        # mirror engine.run's arrival loop: feed each request at its
        # recorded step, jump idle gaps, cap steps so a divergent replay
        # (e.g. a perturbed pool that can never admit) still terminates
        while (i < len(pending) or engine.has_work) and steps < max_steps:
            while i < len(pending) and pending[i]["step"] <= engine._step_idx:
                a = pending[i]
                req = engine.add_request(
                    a["prompt"], a["max_new_tokens"],
                    sampling=SamplingParams(**a["sampling"]),
                    eos_token=a["eos_token"],
                    priority=a.get("priority", 0))
                reqs[req.req_id] = req
                i += 1
            if not engine.has_work:
                engine._step_idx = pending[i]["step"]
                continue
            engine.step()
            steps += 1
        if engine._pending:
            engine._flush([])
        if steps >= max_steps:
            warns.append(f"replay stopped at max_steps={max_steps} with "
                         f"work still queued")
        if clock.exhausted_reads:
            warns.append(f"decision-clock tape exhausted "
                         f"({clock.exhausted_reads} extra reads) — the "
                         f"replay made more time-dependent decisions than "
                         f"the recording")
    except Exception as e:  # noqa: BLE001 - a perturbed replay may crash;
        error = f"{type(e).__name__}: {e}"  # report it with the journal diff

    token_mismatches = []
    for out in bundle.outputs:
        rep = reqs.get(out["req_id"])
        got = [int(t) for t in rep.output_tokens] if rep is not None else None
        if got != out["tokens"]:
            token_mismatches.append({"req_id": out["req_id"],
                                     "recorded": out["tokens"],
                                     "replayed": got})

    replayed_events = [dict(ev) for ev in llm.obs.events.events]
    if bundle.journal and bundle.journal[0].get("seq", 0) > 0:
        # the recorded stream rotated more than once: the head is gone.
        # seq is contiguous per run, so align the replayed journal to the
        # surviving suffix and diff from there.
        start = bundle.journal[0]["seq"]
        warns.append(f"recorded journal starts at seq {start} (older "
                     f"rotations discarded); diffing the suffix")
        replayed_events = replayed_events[start:]
    divergence = diff_journals(bundle.journal, replayed_events)
    llm.close()
    return ReplayResult(
        bundle=path,
        ok=(error is None and not token_mismatches and divergence is None),
        n_requests=len(bundle.arrivals),
        n_recorded_events=len(bundle.journal),
        n_replayed_events=len(replayed_events),
        token_mismatches=token_mismatches,
        divergence=divergence,
        warnings=warns,
        error=error,
    )
