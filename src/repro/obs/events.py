"""Structured scheduler event log.

Every scheduling *decision* the engine takes — queued / admitted /
rejected (with the reason) / chunk fed / promoted / first token / CoW fork
/ prefix hit / defrag / spec fallback / finished — lands here as one
dict: a monotonic ``seq``, a wall-clock ``t`` (``time.perf_counter``, the
same clock every ``Request`` timestamp uses), the ``kind``, an optional
``req_id``, and free-form fields.  ``to_jsonl`` writes one JSON object
per line; ``timeline(req_id)`` reassembles one request's
queued → admitted → chunks → first-token → finished history, which the
API surfaces on ``RequestOutput.timeline``.

This is the layer that answers "why wasn't this request admitted" — the
question a means-only metrics dataclass structurally cannot: rejections
carry the vetoing reason (pool capacity, with the page deficit), evictions
carry theirs (budget vs EOS), and spec fallbacks say what disqualified
the batch.

``NullEventLog`` is the zero-overhead disabled twin: ``emit`` discards
everything without building state.
"""

from __future__ import annotations

import json
import time
from collections import defaultdict
from typing import Optional


class EventLog:
    def __init__(self):
        self.events: list[dict] = []
        self._by_req: dict[int, list[dict]] = defaultdict(list)
        self._seq = 0

    def emit(self, kind: str, req_id: Optional[int] = None, **fields) -> dict:
        ev = {"seq": self._seq, "t": time.perf_counter(), "kind": kind}
        self._seq += 1
        if req_id is not None:
            ev["req_id"] = int(req_id)
            self._by_req[int(req_id)].append(ev)
        ev.update(fields)
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)

    # -- queries -----------------------------------------------------------
    def timeline(self, req_id: int) -> list[dict]:
        """One request's events in emission order."""
        return list(self._by_req.get(int(req_id), ()))

    def kinds(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for ev in self.events:
            counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
        return counts

    # -- export ------------------------------------------------------------
    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return path


class NullEventLog:
    """Disabled event log: emits vanish, queries are empty."""

    events: tuple = ()

    def emit(self, kind: str, req_id: Optional[int] = None, **fields) -> None:
        return None

    def __len__(self) -> int:
        return 0

    def timeline(self, req_id: int) -> list:
        return []

    def kinds(self) -> dict:
        return {}

    def to_jsonl(self, path: str) -> Optional[str]:
        return None


NULL_EVENTS = NullEventLog()
