"""Structured scheduler event log.

Every scheduling *decision* the engine takes — queued / admitted /
rejected (with the reason) / chunk fed / promoted / first token / CoW fork
/ prefix hit / defrag / spec fallback / finished — lands here as one
dict: a monotonic ``seq`` (the replay total order — it keeps counting
across JSONL rotation, so a rotated stream stays contiguous), a
monotonic-clock ``t`` (``time.perf_counter``, the same clock every
``Request`` timestamp uses), a wall-clock ``wall`` (``time.time``, for
correlating with external logs), the ``kind``, an optional ``req_id``,
and free-form fields.  ``to_jsonl`` writes one JSON object
per line; ``timeline(req_id)`` reassembles one request's
queued → admitted → chunks → first-token → finished history, which the
API surfaces on ``RequestOutput.timeline``.

This is the layer that answers "why wasn't this request admitted" — the
question a means-only metrics dataclass structurally cannot: rejections
carry the vetoing reason (pool capacity, with the page deficit), evictions
carry theirs (budget vs EOS), and spec fallbacks say what disqualified
the batch.

``NullEventLog`` is the zero-overhead disabled twin: ``emit`` discards
everything without building state.

**Streaming mode.**  A long-lived server can't buffer its event history
unbounded in memory.  ``EventLog(stream_path=...)`` appends each event
to a JSONL file as it is emitted and keeps only a bounded in-memory
window (a deque) for ``timeline``/``kinds`` queries; when the file
exceeds ``max_bytes`` it is rotated once (renamed to ``<path>.1``) and
writing restarts, so disk usage is bounded at ~2x ``max_bytes``.
"""

from __future__ import annotations

import json
import os
import time
from collections import defaultdict, deque
from typing import Optional


class EventLog:
    def __init__(self, stream_path: Optional[str] = None,
                 max_bytes: int = 64 * 2 ** 20, keep: int = 4096):
        if stream_path is not None and max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        self.stream_path = stream_path
        self.max_bytes = max_bytes
        # streaming: bounded window; buffered: the full history
        self.events = deque(maxlen=keep) if stream_path else []
        self._by_req: dict[int, list[dict]] = defaultdict(list)
        self._seq = 0
        self._fh = open(stream_path, "w") if stream_path else None
        self._bytes = 0
        self.rotations = 0

    def emit(self, kind: str, req_id: Optional[int] = None, **fields) -> dict:
        ev = {"seq": self._seq, "t": time.perf_counter(),
              "wall": time.time(), "kind": kind}
        self._seq += 1
        if req_id is not None:
            ev["req_id"] = int(req_id)
            self._by_req[int(req_id)].append(ev)
        ev.update(fields)
        self.events.append(ev)
        if self._fh is not None:
            line = json.dumps(ev) + "\n"
            self._fh.write(line)
            self._bytes += len(line)
            if self._bytes >= self.max_bytes:
                self._rotate()
        return ev

    def _rotate(self) -> None:
        self._fh.close()
        os.replace(self.stream_path, self.stream_path + ".1")
        self._fh = open(self.stream_path, "w")
        self._bytes = 0
        self.rotations += 1

    def __len__(self) -> int:
        return len(self.events)

    # -- queries -----------------------------------------------------------
    def timeline(self, req_id: int) -> list[dict]:
        """One request's events in emission order."""
        return list(self._by_req.get(int(req_id), ()))

    def kinds(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for ev in self.events:
            counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
        return counts

    def tail(self, n: int) -> list[dict]:
        """The newest ``n`` events from the in-memory window."""
        if n <= 0:
            return []
        evs = list(self.events)
        return evs[-n:]

    # -- export ------------------------------------------------------------
    def to_jsonl(self, path: str) -> str:
        if self._fh is not None and path == self.stream_path:
            # streaming already wrote everything; just make it durable
            self._fh.flush()
            return path
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return path

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            self._fh.close()
            self._fh = None


class NullEventLog:
    """Disabled event log: emits vanish, queries are empty."""

    events: tuple = ()

    def emit(self, kind: str, req_id: Optional[int] = None, **fields) -> None:
        return None

    def __len__(self) -> int:
        return 0

    def timeline(self, req_id: int) -> list:
        return []

    def kinds(self) -> dict:
        return {}

    def tail(self, n: int) -> list:
        return []

    def to_jsonl(self, path: str) -> Optional[str]:
        return None

    def close(self) -> None:
        pass


NULL_EVENTS = NullEventLog()
