"""Metrics primitives: counters, gauges, log-bucketed histograms.

``MetricsRegistry`` is the event-style backbone ``serving.EngineMetrics``
is refactored onto: engine code *emits* (``inc`` / ``set`` / ``observe``)
and summaries are *derived* (``report`` reads values and percentiles)
instead of the old scheme where 30 dataclass fields were poked directly
from half the engine.

``Histogram`` buckets observations geometrically (``base * growth**i``
edges), the standard shape for latency distributions whose interesting
structure spans orders of magnitude (a 100us decode step and a 2s prefill
land in well-separated buckets; linear buckets would waste all their
resolution on one end).  Raw observations are retained alongside the
bucket counts — serving runs observe one value per request or per engine
step, so the memory is trivial and percentile queries (``p50/p95/p99``)
are exact instead of bucket-interpolated.  ``bucket_percentile`` gives the
interpolated estimate for callers that drop samples.
"""

from __future__ import annotations

import math
import threading
from typing import Optional


def labeled(name: str, **labels) -> str:
    """Build a registry key carrying Prometheus-style labels.

    ``labeled("watchdog_act_sat", layer="decode.00")`` ->
    ``watchdog_act_sat{layer="decode.00"}``.  The exposition renderer
    groups series by the base name (everything before ``{``), so one
    metric family can hold many labeled series in a flat registry.
    """
    if not labels:
        return name
    body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{body}}}"


def split_labels(key: str) -> tuple[str, dict[str, str]]:
    """Inverse of :func:`labeled`: registry key -> (base name, labels)."""
    base, brace, rest = key.partition("{")
    if not brace:
        return key, {}
    labels: dict[str, str] = {}
    for part in rest.rstrip("}").split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        labels[k.strip()] = v.strip().strip('"')
    return base, labels


class Counter:
    """Monotonic accumulator (ints stay ints; timers add floats)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Last-value (or running-max) metric."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value=0):
        self.name = name
        self.value = value

    def set(self, value) -> None:
        self.value = value

    def set_max(self, value) -> None:
        if value > self.value:
            self.value = value


class Histogram:
    """Log-bucketed histogram with exact percentiles from retained samples.

    Bucket ``0`` holds values ``<= base``; bucket ``i >= 1`` holds
    ``(base * growth**(i-1), base * growth**i]``; the last bucket is
    open-ended.  Defaults cover 1 microsecond .. ~3.9 hours at
    ``growth=2``.
    """

    __slots__ = ("name", "base", "growth", "counts", "samples",
                 "total", "sum", "min", "max")

    def __init__(self, name: str, base: float = 1e-6, growth: float = 2.0,
                 n_buckets: int = 44):
        if base <= 0 or growth <= 1 or n_buckets < 2:
            raise ValueError("need base > 0, growth > 1, n_buckets >= 2")
        self.name = name
        self.base = base
        self.growth = growth
        self.counts = [0] * n_buckets
        self.samples: list[float] = []
        self.total = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    @property
    def n_buckets(self) -> int:
        return len(self.counts)

    def bucket_index(self, value: float) -> int:
        if value <= self.base:
            return 0
        i = 1 + math.floor(math.log(value / self.base, self.growth))
        # a value sitting exactly on edge base*growth**(i-1) belongs to
        # bucket i-1 (edges are inclusive upper bounds); float log can
        # round either way, so fix up against the true edges
        while i > 0 and value <= self.edge(i - 1):
            i -= 1
        while value > self.edge(i) and i < self.n_buckets - 1:
            i += 1
        return min(i, self.n_buckets - 1)

    def edge(self, i: int) -> float:
        """Inclusive upper edge of bucket ``i``."""
        return self.base * self.growth ** i

    def observe(self, value) -> None:
        value = float(value)
        self.counts[self.bucket_index(value)] += 1
        self.samples.append(value)
        self.total += 1
        self.sum += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    def percentile(self, q: float) -> float:
        """Exact q-th percentile (linear interpolation, numpy-style).
        0.0 when nothing was observed."""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = math.floor(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def bucket_percentile(self, q: float) -> float:
        """Bucket-interpolated percentile (what a sample-free histogram
        could report): the upper edge-weighted position inside the bucket
        the q-th observation falls in."""
        if not self.total:
            return 0.0
        target = (q / 100.0) * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c:
                lo = 0.0 if i == 0 else self.edge(i - 1)
                hi = self.edge(i)
                frac = (target - seen) / c
                return lo + (hi - lo) * frac
            seen += c
        return self.edge(self.n_buckets - 1)


class MetricsRegistry:
    """Named counters / gauges / histograms, created on first touch.

    Get-or-create and whole-registry reads take ``lock`` so a metrics
    server thread can iterate the families while the engine thread is
    still creating new ones.  Updates to an existing metric are plain
    attribute pokes — atomic enough under the GIL for monitoring reads.
    """

    def __init__(self):
        self.lock = threading.Lock()
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    # -- get-or-create -----------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            with self.lock:
                c = self.counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            with self.lock:
                g = self.gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str, **kw) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            with self.lock:
                h = self.histograms.get(name)
                if h is None:
                    h = self.histograms[name] = Histogram(name, **kw)
        return h

    # -- event-style emission ---------------------------------------------
    def inc(self, name: str, n=1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, value) -> None:
        self.gauge(name).set(value)

    def set_max(self, name: str, value) -> None:
        self.gauge(name).set_max(value)

    def observe(self, name: str, value) -> None:
        self.histogram(name).observe(value)

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-dict dump: counter/gauge values, histogram summaries."""
        with self.lock:
            counters = list(self.counters.items())
            gauges = list(self.gauges.items())
            histograms = list(self.histograms.items())
        return {
            "counters": {k: c.value for k, c in counters},
            "gauges": {k: g.value for k, g in gauges},
            "histograms": {
                k: {
                    "count": h.total,
                    "mean": h.mean,
                    "min": h.min,
                    "max": h.max,
                    "p50": h.percentile(50),
                    "p95": h.percentile(95),
                    "p99": h.percentile(99),
                }
                for k, h in histograms
            },
        }
