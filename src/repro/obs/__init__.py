"""``repro.obs`` — tracing, metrics and profiling for the serving stack.

The paper's headline numbers are throughput/latency/efficiency, and every
comparative photonic-accelerator claim rests on per-stage timing
attribution — so the serving engine gets a first-class observability
layer instead of a bag of mean-only counters:

* ``trace``   — nested spans over engine dispatches (prefill / chunk /
  decode / verify / defrag), optionally fenced with ``block_until_ready``
  so they measure device work, exported as Chrome trace-event JSON
  (Perfetto-loadable).
* ``metrics`` — counters, gauges and log-bucketed histograms with exact
  percentile queries; the registry ``serving.EngineMetrics`` is built on.
* ``events``  — the structured scheduler event log: every admit / reject /
  evict / CoW-fork / defrag / spec-fallback decision with its reason,
  reassembled per-request as a queued→admitted→chunks→first-token→finished
  timeline (surfaced on ``api.RequestOutput``).
* ``profile`` — ``jax.profiler`` hooks wrapping N engine steps in a
  device trace (``--profile DIR``).
* ``config``  — ``ObsConfig`` (the ``RuntimeConfig.obs`` layer) and the
  ``Observability`` bundle the engine consumes; ``DISABLED`` is the
  shared null bundle.
* ``server``  — the live telemetry frontend: Prometheus text exposition
  over ``MetricsRegistry`` (histograms as native ``_bucket/_sum/_count``
  series), a grammar validator, and the stdlib ``MetricsServer`` serving
  ``/metrics`` + ``/healthz`` + ``/snapshot`` from a daemon thread.
* ``recorder`` / ``replay`` — the flight recorder: arm with
  ``ObsConfig(record_path=DIR)`` to capture a run (config fingerprint,
  arrival schedule, decision journal, token outputs, decision-clock
  tape) into a bundle that ``replay_bundle``/``LLM.replay``/``python -m
  repro.launch.replay`` reproduces bitwise offline, diffing any
  divergence to the first bad decision.  (``repro.obs.replay`` imports
  the api layer, so it is imported lazily, not re-exported here.)
* ``watchdog`` — the numerics watchdog: per-layer saturation / amax /
  quant-error / accumulator-headroom stats from every quantized GEMM,
  staged in-jit through ``jax.debug.callback`` (off: zero overhead; on:
  bitwise output-invisible).

Two invariants, test-asserted in ``tests/test_obs.py``: disabled
observability adds **zero overhead** (null sinks, no extra host syncs on
the decode path), and enabled observability is **output-invisible**
(greedy token streams stay bitwise identical with tracing on).
"""

from repro.obs import watchdog
from repro.obs.config import DISABLED, Observability, ObsConfig
from repro.obs.events import NULL_EVENTS, EventLog, NullEventLog
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               labeled, split_labels)
from repro.obs.profile import NULL_PROFILER, NullStepProfiler, StepProfiler
from repro.obs.recorder import FlightRecorder
from repro.obs.server import (MetricsServer, render_exposition,
                              validate_exposition)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Counter",
    "DISABLED",
    "EventLog",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsServer",
    "NULL_EVENTS",
    "NULL_PROFILER",
    "NULL_TRACER",
    "NullEventLog",
    "NullStepProfiler",
    "NullTracer",
    "ObsConfig",
    "Observability",
    "Span",
    "StepProfiler",
    "Tracer",
    "labeled",
    "render_exposition",
    "split_labels",
    "validate_exposition",
    "watchdog",
]
