"""``ObsConfig``: the frozen, JSON-round-trippable observability surface.

One sub-config of ``repro.api.RuntimeConfig`` (the same layering as
``KVConfig``/``SchedulerConfig``): every knob maps onto one field, and
``build()`` turns the config into the live ``Observability`` bundle the
engine consumes.  With everything unset the build returns null sinks —
the zero-overhead disabled mode the hot-path invariant demands.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.events import NULL_EVENTS, EventLog
from repro.obs.profile import NULL_PROFILER, StepProfiler
from repro.obs.trace import NULL_TRACER, Tracer


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability knobs (all off by default).

    ``enabled=None`` means *auto*: tracing/events turn on iff a sink path
    (or ``debug_invariants``/``fence_spans``) asks for them, so setting
    ``trace="out.json"`` is sufficient.  ``enabled=True`` collects in
    memory even without file sinks (read via ``llm.obs``);
    ``enabled=False`` forces everything off regardless of paths.
    """

    enabled: Optional[bool] = None
    # Chrome trace-event JSON output path (None = don't write a file)
    trace: Optional[str] = None
    # scheduler event-log JSONL output path (None = don't write a file)
    events: Optional[str] = None
    # block_until_ready-fence spans so they bracket device work instead of
    # async dispatch (serializes the decode pipeline — measurement mode)
    fence_spans: bool = False
    # jax.profiler: wrap profile_steps engine steps in a device trace
    # written under this directory (None = no profiling)
    profile_dir: Optional[str] = None
    profile_steps: int = 20
    # run PageManager.check_invariants() every engine step and emit a
    # structured violation event (then raise) instead of relying on tests
    debug_invariants: bool = False
    # serve a live /metrics (Prometheus) + /healthz + /snapshot endpoint
    # on this port (0 = ephemeral).  Polls registries only; does not turn
    # the tracer/event sinks on and never touches the dispatch path.
    metrics_port: Optional[int] = None
    # streaming event sink rotation threshold: when the --events JSONL
    # file passes this size it is rotated once to <path>.1
    events_max_mb: float = 64.0
    # numerics watchdog: per-layer saturation/amax/quant-error stats from
    # every quantized GEMM (threaded onto ModelConfig so jits re-key)
    watchdog: bool = False
    # flight recorder: write a replayable bundle (manifest + arrivals +
    # decision journal + outputs + decision-clock tape) into this
    # directory; replay with `python -m repro.launch.replay DIR`.
    # Arming it forces events on — the journal IS the event stream.
    record_path: Optional[str] = None

    def __post_init__(self):
        if self.profile_steps < 1:
            raise ValueError("ObsConfig.profile_steps must be >= 1")
        if self.metrics_port is not None and not (
                0 <= self.metrics_port <= 65535):
            raise ValueError("ObsConfig.metrics_port must be in [0, 65535]")
        if self.events_max_mb <= 0:
            raise ValueError("ObsConfig.events_max_mb must be positive")

    @property
    def resolved_enabled(self) -> bool:
        if self.enabled is not None:
            return self.enabled
        return bool(self.trace or self.events or self.fence_spans
                    or self.debug_invariants or self.record_path)

    def build(self) -> "Observability":
        """The live bundle this config describes (null sinks when off)."""
        on = self.resolved_enabled
        recorder = None
        if self.record_path is not None:
            from repro.obs.recorder import FlightRecorder

            recorder = FlightRecorder(self.record_path)
        if not on:
            events = NULL_EVENTS
        elif recorder is not None:
            # the recorder owns the stream: the decision journal is the
            # event log, written straight into the bundle (an --events
            # sink, if also set, gets the in-memory window via save())
            events = EventLog(stream_path=recorder.journal_path,
                              max_bytes=int(self.events_max_mb * 2 ** 20))
        elif self.events:
            # a file sink streams incrementally with bounded memory
            events = EventLog(stream_path=self.events,
                              max_bytes=int(self.events_max_mb * 2 ** 20))
        else:
            events = EventLog()
        return Observability(
            tracer=Tracer(fence_spans=self.fence_spans) if on else NULL_TRACER,
            events=events,
            profiler=(StepProfiler(self.profile_dir, self.profile_steps)
                      if self.profile_dir else NULL_PROFILER),
            debug_invariants=self.debug_invariants,
            enabled=on,
            config=self,
            recorder=recorder,
        )


@dataclasses.dataclass
class Observability:
    """The engine-facing bundle: tracer + event log + profiler + flags.

    Engine code calls into these unconditionally; the disabled singleton
    (``repro.obs.DISABLED``) makes every call a no-op, which is what keeps
    the invariant 'zero overhead, zero extra host syncs when off' literal
    rather than aspirational.
    """

    tracer: object = NULL_TRACER
    events: object = NULL_EVENTS
    profiler: object = NULL_PROFILER
    debug_invariants: bool = False
    enabled: bool = False
    config: Optional[ObsConfig] = None
    # armed flight recorder (repro.obs.recorder.FlightRecorder) or None;
    # the engine checks `is not None` on host-side request paths only
    recorder: object = None

    def save(self, trace_path: Optional[str] = None,
             events_path: Optional[str] = None) -> list[str]:
        """Write the configured (or explicitly passed) file sinks; returns
        the paths written.  Also flushes a still-armed profiler."""
        self.profiler.close()
        written = []
        tp = trace_path or (self.config.trace if self.config else None)
        ep = events_path or (self.config.events if self.config else None)
        if tp and self.tracer.save(tp):
            written.append(tp)
        if ep and self.events.to_jsonl(ep):
            written.append(ep)
        return written

    def close(self) -> None:
        self.profiler.close()
        self.events.close()
        if self.recorder is not None:
            self.recorder.close()


# the shared disabled bundle: stateless null sinks, safe to share between
# engines (module singleton so the default costs nothing per engine)
DISABLED = Observability()
