"""Numerics watchdog: runtime visibility into quantization headroom.

SPOGA's physical constraint is analog dynamic range — operands wider
than ~4 bits saturate the optical signal chain, which is why the kernels
bit-slice byte-size integers and why ``effective_bits`` shrinks operand
widths until the int32 accumulator cannot wrap.  This module is the
software mirror of that wall: when enabled, every quantized GEMM in the
pipeline reports how hard the workload is actually pushing against the
clamp — per-layer at-rail occupancy (fraction of quantized values
sitting on the ±qmax rail), activation ``amax``, relative quantization
error, and the accumulator-magnitude bound in bits — into a module-level
:class:`MetricsRegistry` that the ``/metrics`` server exposes alongside
the engine registry.

Mechanics: enablement is a **trace-time** thread-local context.  Model
entry points (``prefill`` / ``decode_step`` / ``verify_step`` /
``forward``) enter :func:`watching` when ``ModelConfig.numerics_watchdog``
is set; ``quantized_linear`` consults :func:`trace_ctx` while JAX is
tracing and, when active, stages its stats through ``jax.debug.callback``
into :func:`record`.  Because the flag lives on the (hashable, frozen)
``ModelConfig``, every jit cache in the engine re-keys automatically —
a toggled watchdog can never reuse a trace compiled without callbacks.
Off means the context is never entered: zero callbacks staged, zero
host syncs, identical jaxprs.  On, ``jax.debug.callback`` is effectful
but does not feed back into the computation, so outputs stay bitwise
identical (both properties are test-asserted).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Iterator, Optional

from repro.obs.metrics import MetricsRegistry, labeled

_LOCK = threading.Lock()
_REGISTRY: Optional[MetricsRegistry] = None
_TLS = threading.local()


def registry() -> MetricsRegistry:
    """The watchdog's registry, created on first use."""
    global _REGISTRY
    if _REGISTRY is None:
        with _LOCK:
            if _REGISTRY is None:
                _REGISTRY = MetricsRegistry()
    return _REGISTRY


def peek_registry() -> Optional[MetricsRegistry]:
    """The registry if any watchdog stats were recorded, else None."""
    return _REGISTRY


def reset() -> None:
    """Drop all recorded stats (tests; fresh serving sessions)."""
    global _REGISTRY
    with _LOCK:
        _REGISTRY = None


class _Ctx:
    __slots__ = ("tag", "n")

    def __init__(self, tag: str):
        self.tag = tag
        self.n = 0


@contextmanager
def watching(tag: Optional[str]) -> Iterator[None]:
    """Enable the watchdog for quantized GEMMs traced in this scope.

    ``tag`` names the entry point (``prefill`` / ``decode`` / ...);
    ``None`` is a no-op so call sites can pass
    ``"decode" if cfg.numerics_watchdog else None`` unconditionally.
    """
    if tag is None:
        yield
        return
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = _Ctx(tag)
    try:
        yield
    finally:
        _TLS.ctx = prev


def trace_ctx() -> Optional[_Ctx]:
    """The active trace-time context, if any (consulted while tracing)."""
    return getattr(_TLS, "ctx", None)


def next_label(ctx: Optional[_Ctx], k: int, n: int) -> str:
    """Stable per-trace-site layer label: ``<tag>.<idx>.k<K>n<N>``.

    The index is a trace-time counter, so re-tracing the same entry
    point reproduces the same labels.  Under ``lax.scan`` the layer body
    traces once — scanned layers share one label whose counters then
    accumulate across all scan iterations at runtime.
    """
    if ctx is None:
        return f"direct.k{k}n{n}"
    i = ctx.n
    ctx.n += 1
    return f"{ctx.tag}.{i:02d}.k{k}n{n}"


def record(label: str, spec_name: str, stats) -> None:
    """Host-side sink for one GEMM's in-jit stats vector.

    Called via ``jax.debug.callback``; ``stats`` arrives as an ndarray
    ``[act_rail_hits, w_rail_hits, act_elems, w_elems, amax, rel_err,
    acc_bits, bits_lost]``.  Looked up dynamically (module-level) so a
    compiled trace never captures a stale registry.
    """
    act_sat, w_sat, a_n, w_n, amax, err, acc_bits, lost = (
        float(v) for v in stats)
    reg = registry()
    lab = {"layer": label, "mode": spec_name}
    reg.inc(labeled("watchdog_calls", **lab))
    reg.inc(labeled("watchdog_act_sat", **lab), int(act_sat))
    reg.inc(labeled("watchdog_w_sat", **lab), int(w_sat))
    reg.inc(labeled("watchdog_act_elems", **lab), int(a_n))
    reg.inc(labeled("watchdog_w_elems", **lab), int(w_n))
    if lost:
        reg.inc(labeled("watchdog_bits_clamped", **lab), int(lost))
    reg.observe(labeled("watchdog_amax", **lab), amax)
    reg.observe(labeled("watchdog_quant_err", **lab), err)
    reg.observe(labeled("watchdog_acc_bits", **lab), acc_bits)
    reg.set_max(labeled("watchdog_acc_bits_peak", **lab), acc_bits)


def saturation_report() -> dict:
    """Per-layer at-rail occupancy summary (activation side), for quick
    programmatic checks: ``{layer_key: fraction_at_rail}``."""
    reg = peek_registry()
    if reg is None:
        return {}
    out = {}
    with reg.lock:
        keys = list(reg.counters)
    for key in keys:
        if not key.startswith("watchdog_act_sat"):
            continue
        suffix = key[len("watchdog_act_sat"):]
        n = reg.counters.get("watchdog_act_elems" + suffix)
        if n is None or not n.value:
            continue
        out[suffix.strip("{}")] = reg.counters[key].value / n.value
    return out
