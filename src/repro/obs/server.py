"""Live telemetry frontend: Prometheus text exposition + stdlib HTTP server.

Three pieces, all dependency-free:

- :func:`render_exposition` turns one or more :class:`MetricsRegistry`
  instances (plus optional derived gauges) into Prometheus text
  exposition format 0.0.4 — counters as ``<name>_total``, gauges as-is,
  and the log-bucketed histograms as native cumulative
  ``_bucket{le=...}`` / ``_sum`` / ``_count`` series whose ``le`` edges
  are the histogram's own geometric bucket edges.  Registry keys may
  carry labels (``watchdog_amax{layer="decode.00"}``, built with
  :func:`repro.obs.metrics.labeled`); series sharing a base name are
  grouped into one ``# TYPE``-declared family.

- :func:`validate_exposition` is a grammar + semantics checker for that
  format (used by the tests and the CI smoke): line shapes, names,
  label syntax, TYPE-before-samples, histogram ``le`` monotonicity,
  cumulative bucket counts, and ``+Inf`` bucket == ``_count``.

- :class:`MetricsServer` serves ``/metrics`` (exposition), ``/healthz``
  and ``/snapshot`` (JSON) from a daemon thread.  It *polls*: the
  handler calls a collector closure that reads live registries; nothing
  on the engine dispatch path knows the server exists.
"""

from __future__ import annotations

import json
import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Iterable, Mapping, Optional, Sequence
from urllib.parse import parse_qs

from repro.obs.metrics import MetricsRegistry, split_labels

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SANITIZE_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    name = _SANITIZE_RE.sub("_", name)
    if not name or not _NAME_RE.match(name):
        name = "_" + name
    return name


def _fmt(value) -> str:
    v = float(value)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _escape_label(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_str(labels: Mapping[str, str]) -> str:
    if not labels:
        return ""
    body = ",".join(f'{_sanitize(k)}="{_escape_label(str(v))}"'
                    for k, v in sorted(labels.items()))
    return "{" + body + "}"


def _families(items: Iterable[tuple[str, object]], prefix: str):
    """Group registry entries by sanitized family name, splitting labels."""
    fams: dict[str, list[tuple[dict, object]]] = {}
    for key, obj in items:
        base, labels = split_labels(key)
        fam = _sanitize(f"{prefix}_{base}" if prefix else base)
        fams.setdefault(fam, []).append((labels, obj))
    return sorted(fams.items())


def render_exposition(registries: Sequence[MetricsRegistry],
                      extra_gauges: Optional[Mapping[str, float]] = None,
                      prefix: str = "repro") -> str:
    """Render registries (+ derived scalar gauges) as Prometheus text."""
    lines: list[str] = []
    counters: list[tuple[str, object]] = []
    gauges: list[tuple[str, object]] = []
    histograms: list[tuple[str, object]] = []
    for reg in registries:
        with reg.lock:
            counters.extend(reg.counters.items())
            gauges.extend(reg.gauges.items())
            histograms.extend(reg.histograms.items())

    for fam, series in _families(counters, prefix):
        name = fam + "_total"
        lines.append(f"# TYPE {name} counter")
        for labels, c in series:
            lines.append(f"{name}{_labels_str(labels)} {_fmt(c.value)}")

    gauge_items = list(gauges)
    for k, v in (extra_gauges or {}).items():
        gauge_items.append((k, _Scalar(v)))
    for fam, series in _families(gauge_items, prefix):
        lines.append(f"# TYPE {fam} gauge")
        for labels, g in series:
            lines.append(f"{fam}{_labels_str(labels)} {_fmt(g.value)}")

    for fam, series in _families(histograms, prefix):
        lines.append(f"# TYPE {fam} histogram")
        for labels, h in series:
            # counts/sum are mutated by the engine thread while we read;
            # snapshot the list once so cumulative sums stay consistent
            counts = list(h.counts)
            cum = 0
            for i, c in enumerate(counts):
                cum += c
                le = ("+Inf" if i == len(counts) - 1
                      else _fmt(h.edge(i)))
                ls = _labels_str({**labels, "le": le})
                lines.append(f"{fam}_bucket{ls} {cum}")
            ls = _labels_str(labels)
            lines.append(f"{fam}_sum{ls} {_fmt(h.sum)}")
            lines.append(f"{fam}_count{ls} {cum}")

    return "\n".join(lines) + "\n"


class _Scalar:
    __slots__ = ("value",)

    def __init__(self, value):
        self.value = value


# ---------------------------------------------------------------------------
# exposition grammar validator (for tests + the CI schema check)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^{}]*\})?"
    r" (?P<value>[-+]?(?:[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?|Inf)|NaN)"
    r"(?: [0-9]+)?$")
_LABEL_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
_TYPE_RE = re.compile(
    r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) "
    r"(counter|gauge|histogram|summary|untyped)$")
_HELP_RE = re.compile(r"^# HELP ([a-zA-Z_:][a-zA-Z0-9_:]*) .*$")


def _parse_labels(text: str) -> Optional[dict[str, str]]:
    body = text[1:-1].rstrip(",")
    if not body:
        return {}
    labels: dict[str, str] = {}
    pos = 0
    while pos < len(body):
        m = _LABEL_RE.match(body, pos)
        if m is None:
            return None
        labels[m.group(1)] = m.group(2)
        pos = m.end()
        if pos < len(body):
            if body[pos] != ",":
                return None
            pos += 1
    return labels


def validate_exposition(text: str) -> list[str]:
    """Check Prometheus text exposition; return a list of problems.

    Enforces line grammar, TYPE declarations preceding their samples,
    histogram family completeness (``_bucket``/``_sum``/``_count``),
    ``le`` monotonicity, cumulative bucket counts, and the ``+Inf``
    bucket agreeing with ``_count``.  Empty list == valid.
    """
    errors: list[str] = []
    types: dict[str, str] = {}
    # histogram bookkeeping: (family, frozenset of non-le labels) ->
    # {"buckets": [(le, value)], "count": v, "sum": seen}
    hists: dict[tuple, dict] = {}

    for n, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        if line.startswith("#"):
            m = _TYPE_RE.match(line)
            if m:
                if m.group(1) in types:
                    errors.append(f"line {n}: duplicate TYPE for {m.group(1)}")
                types[m.group(1)] = m.group(2)
                continue
            if _HELP_RE.match(line):
                continue
            errors.append(f"line {n}: malformed comment: {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            errors.append(f"line {n}: malformed sample: {line!r}")
            continue
        name = m.group("name")
        labels = _parse_labels(m.group("labels")) if m.group("labels") else {}
        if labels is None:
            errors.append(f"line {n}: malformed labels: {line!r}")
            continue
        family, suffix = name, ""
        for sfx in ("_bucket", "_sum", "_count", "_total"):
            if name.endswith(sfx) and name[: -len(sfx)] in types:
                family, suffix = name[: -len(sfx)], sfx
                break
        declared = types.get(name) or types.get(family)
        if declared is None:
            errors.append(f"line {n}: sample {name} has no TYPE declaration")
            continue
        if declared == "histogram" and suffix in ("_bucket", "_sum", "_count"):
            key = (family, frozenset((k, v) for k, v in labels.items()
                                     if k != "le"))
            h = hists.setdefault(key, {"buckets": [], "count": None,
                                       "sum": False})
            value = float(m.group("value").replace("Inf", "inf"))
            if suffix == "_bucket":
                if "le" not in labels:
                    errors.append(f"line {n}: histogram bucket without le")
                    continue
                le = labels["le"]
                le_v = math.inf if le == "+Inf" else float(le)
                h["buckets"].append((le_v, value, n))
            elif suffix == "_count":
                h["count"] = value
            else:
                h["sum"] = True
        elif declared == "counter":
            if float(m.group("value").replace("Inf", "inf")) < 0:
                errors.append(f"line {n}: negative counter {name}")

    for (family, _labels), h in hists.items():
        edges = h["buckets"]
        if not edges:
            errors.append(f"histogram {family}: no buckets")
            continue
        for (a, ca, _), (b, cb, ln) in zip(edges, edges[1:]):
            if b <= a:
                errors.append(f"line {ln}: {family} le not increasing")
            if cb < ca:
                errors.append(f"line {ln}: {family} buckets not cumulative")
        if not math.isinf(edges[-1][0]):
            errors.append(f"histogram {family}: missing +Inf bucket")
        if h["count"] is None:
            errors.append(f"histogram {family}: missing _count")
        elif math.isinf(edges[-1][0]) and edges[-1][1] != h["count"]:
            errors.append(f"histogram {family}: +Inf bucket "
                          f"{edges[-1][1]} != _count {h['count']}")
        if not h["sum"]:
            errors.append(f"histogram {family}: missing _sum")
    return errors


# ---------------------------------------------------------------------------
# the HTTP frontend
# ---------------------------------------------------------------------------

# collector contract: () -> (registries, derived_gauges)
Collector = Callable[[], tuple[Sequence[MetricsRegistry],
                               Mapping[str, float]]]


class MetricsServer:
    """Daemon-thread HTTP server for live scraping.

    ``collect`` is called per request and must return
    ``(registries, derived_gauges)`` — typically a closure over the LLM
    that reads whatever engine is currently live.  ``port=0`` binds an
    ephemeral port (read it back from ``.port``).

    ``events`` (optional) returns the live ``EventLog``; when given, the
    server also answers ``/events?n=N`` with the newest N scheduler
    decisions from the in-memory window as JSON — a fleet scrape can grab
    recent decisions without tailing the JSONL sink.
    """

    def __init__(self, collect: Collector, port: int = 0,
                 host: str = "127.0.0.1", events=None):
        self._collect = collect
        self._events = events
        self._host = host
        self._requested_port = port
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self.port: Optional[int] = None

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self.port}"

    def start(self) -> "MetricsServer":
        if self._httpd is not None:
            return self
        collect = self._collect
        events = self._events

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # noqa: N802 - stdlib name
                pass  # scrapes should not spam the serving console

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):  # noqa: N802 - stdlib name
                path, _, query = self.path.partition("?")
                try:
                    if path == "/metrics":
                        regs, gauges = collect()
                        body = render_exposition(regs, gauges)
                        self._send(200, body.encode(), CONTENT_TYPE)
                    elif path == "/healthz":
                        self._send(200, b"ok\n", "text/plain; charset=utf-8")
                    elif path == "/snapshot":
                        regs, gauges = collect()
                        doc = {"registries": [r.snapshot() for r in regs],
                               "derived": dict(gauges)}
                        self._send(200, json.dumps(doc).encode(),
                                   "application/json")
                    elif path == "/events" and events is not None:
                        n = 100
                        qs = parse_qs(query)
                        if "n" in qs:
                            try:
                                n = int(qs["n"][0])
                            except (ValueError, IndexError):
                                self._send(400, b"bad n\n",
                                           "text/plain; charset=utf-8")
                                return
                        log = events()
                        tail = log.tail(n) if log is not None else []
                        doc = {"events": tail, "returned": len(tail),
                               "window": len(log) if log is not None else 0}
                        self._send(200, json.dumps(doc).encode(),
                                   "application/json")
                    else:
                        self._send(404, b"not found\n",
                                   "text/plain; charset=utf-8")
                except Exception as e:  # a broken scrape must not kill serving
                    try:
                        self._send(500, f"collect failed: {e}\n".encode(),
                                   "text/plain; charset=utf-8")
                    except OSError:
                        pass

        self._httpd = ThreadingHTTPServer((self._host, self._requested_port),
                                          Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="repro-metrics-server",
                                        daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
            if self._thread is not None:
                self._thread.join(timeout=5)
                self._thread = None
