"""Span-based tracer exporting Chrome trace-event JSON.

``Tracer.span("decode_step", step=12)`` opens a nested, context-managed
span; closed spans accumulate as Chrome trace *complete events* (``"ph":
"X"``) that ``save()`` writes as a ``{"traceEvents": [...]}`` document —
drop it onto https://ui.perfetto.dev (or ``chrome://tracing``) and the
engine's prefill/chunk/decode/verify/defrag dispatches render as a
timeline.

Two properties the serving engine depends on:

* **Async-dispatch honesty.**  JAX dispatches return before the device
  finishes, so a bare span measures *enqueue* time, not device work.  A
  span may register device values with ``sp.fence(x)``; when the tracer
  was built with ``fence_spans=True`` the span blocks on them
  (``jax.block_until_ready``) before stamping its end timestamp, so the
  span brackets the device computation.  With ``fence_spans=False`` the
  fence call is free and **no extra host sync ever happens** — the
  engine's lazy decode pipelining is untouched.
* **Zero overhead when disabled.**  ``NULL_TRACER`` (a ``NullTracer``)
  hands out one shared no-op span: no event list grows, no timestamps are
  taken, nothing is fenced.  Engine code traces unconditionally and the
  null objects make the disabled path vanish.

Spans nest by call structure: the tracer keeps a stack, stamps each span
with its ``depth``, and Perfetto reconstructs the hierarchy from timestamp
containment on the single engine thread (``tid`` 1).

**Per-lane tracks.**  A span may additionally name the engine lanes
(slots) it covers — ``tracer.span("decode", lanes=running)`` or
``span("prefill", lane=slot)``.  The span still lands on the engine
track, and a copy is emitted per lane at ``tid = slot + 2`` (tid 1 is
the engine stack), with ``thread_name`` metadata so Perfetto renders one
track per lane: batched decode/verify dispatches show up as concurrent
bars across every participating request instead of one engine-thread
stack.
"""

from __future__ import annotations

import json
import time
from typing import Optional


def _now_us() -> float:
    return time.perf_counter_ns() / 1000.0


class Span:
    """One in-flight span; use via ``with tracer.span(...) as sp``."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_fences", "_depth",
                 "_lanes")

    def __init__(self, tracer: "Tracer", name: str, args: dict,
                 lanes: tuple = ()):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._fences: list = []
        self._depth = 0
        self._lanes = lanes

    def fence(self, *values) -> None:
        """Register device values the span must wait on before closing
        (only honoured when the tracer fences; otherwise free)."""
        if self._tracer.fence_spans:
            self._fences.extend(values)

    def set(self, **kw) -> None:
        """Attach (or update) span args after entry."""
        self.args.update(kw)

    def __enter__(self) -> "Span":
        self._depth = len(self._tracer._stack)
        self._tracer._stack.append(self)
        self._t0 = _now_us()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._fences:
            import jax

            jax.block_until_ready(self._fences)
        t1 = _now_us()
        stack = self._tracer._stack
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._emit(self, t1)
        return False


class _NullSpan:
    """Shared do-nothing span: the disabled tracer's entire hot path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def fence(self, *values) -> None:
        pass

    def set(self, **kw) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans / instants; exports Chrome trace-event JSON."""

    enabled = True

    def __init__(self, fence_spans: bool = False):
        self.fence_spans = fence_spans
        # finished events, already in Chrome trace-event dict form
        self.events: list[dict] = []
        self._stack: list[Span] = []
        self._epoch_us = _now_us()
        self._lane_tids: set = set()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, lanes=None, lane=None, **args) -> Span:
        """Open a span.  ``lanes``/``lane`` name the engine slots the
        dispatch covers; the span is mirrored onto each lane's track."""
        if lane is not None:
            lanes = (lane,)
        return Span(self, name, args,
                    tuple(lanes) if lanes is not None else ())

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker (Chrome ``ph: "i"``)."""
        ev = {"name": name, "ph": "i", "ts": _now_us() - self._epoch_us,
              "pid": 1, "tid": 1, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def _emit(self, span: Span, t1_us: float) -> None:
        ev = {
            "name": span.name,
            "ph": "X",
            "ts": span._t0 - self._epoch_us,
            "dur": t1_us - span._t0,
            "pid": 1,
            "tid": 1,
            "cat": "engine",
        }
        args = dict(span.args)
        args["depth"] = span._depth
        ev["args"] = args
        self.events.append(ev)
        # mirror onto per-lane tracks (tid = slot + 2; tid 1 = engine)
        for slot in span._lanes:
            tid = int(slot) + 2
            self._lane_tids.add(tid)
            lane_ev = dict(ev)
            lane_ev["tid"] = tid
            lane_ev["cat"] = "lane"
            lane_ev["args"] = {**args, "lane": int(slot)}
            self.events.append(lane_ev)

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> dict:
        """The full Chrome trace-event document (Perfetto-loadable)."""
        meta = [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 1,
             "args": {"name": "repro.serving"}},
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": 1,
             "args": {"name": "engine"}},
        ]
        for tid in sorted(self._lane_tids):
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": tid, "args": {"name": f"lane {tid - 2}"}})
        return {"traceEvents": meta + self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


class NullTracer:
    """Disabled tracer: every span is the shared no-op span."""

    enabled = False
    fence_spans = False
    events: tuple = ()

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def save(self, path: str) -> Optional[str]:
        return None


NULL_TRACER = NullTracer()
