"""Pure-jnp oracles for the Pallas kernels (exact int32 arithmetic)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_int8_gemm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Ground-truth INT8 GEMM with int32 accumulation.

    Every bit-sliced strategy (SPOGA fused, DEAS materialized) must equal
    this exactly: bit-slicing is an identity in integer arithmetic.
    """
    return jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def ref_spoga_gemm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Algebraic twin of the fused kernel (nibble slices + radix combine)."""
    xm = jnp.right_shift(x, 4)
    xl = jnp.bitwise_and(x, 15)
    wm = jnp.right_shift(w, 4)
    wl = jnp.bitwise_and(w, 15)
    d = lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return (d(xm, wm) << 8) + ((d(xm, wl) + d(xl, wm)) << 4) + d(xl, wl)


def ref_spoga_gemm_dequant(x, w, x_scale, w_scale):
    """W8A8 with dequantizing epilogue: (x @ w) * x_scale * w_scale (f32)."""
    acc = ref_int8_gemm(x, w)
    return acc.astype(jnp.float32) * x_scale * w_scale
