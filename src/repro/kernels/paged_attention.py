"""Paged decode attention over block tables — Pallas TPU kernel + jnp twin.

The serving engine's paged KV cache (repro/paging/) stores K/V in a global
page pool ``(n_pages, page_size, H_kv, D)`` shared by every lane; a lane's
logical sequence is the concatenation of the physical pages its block
table names.  The kernel streams those pages straight from the pool —
``PrefetchScalarGridSpec`` hands the block table to the BlockSpec index
maps, so page ``j`` of lane ``b`` is DMA'd from ``tables[b, j]`` without
ever materializing the gathered (B, S, H, D) view that the jnp twin
builds.  A flash-style running softmax (per-lane max / denominator / value
accumulator in VMEM scratch) folds the pages into the output in one pass.

The int8 byte-size variant fuses page dequantization: int8 payloads ride
the dot products and the per-(position, head) scales multiply the scores /
probabilities — the paper's byte-size operand stream applied to decode's
dominant HBM traffic, in the same shape as ``spoga_gemm_dequant`` fuses
the epilogue.

Layouts (G = query heads per KV head):

    q        (B, H_kv, G, D)        bf16/f32
    kp, vp   (n_pages, page_size, H_kv, D)   bf16 | int8
    k_scale, v_scale  (n_pages, page_size, H_kv) f32 (int8 variant)
    tables   (B, P) int32 physical page ids
    lengths  (B,)   int32 valid rows per lane (pos + 1 at decode)
    out      (B, H_kv, G, D) f32

CI runs the kernel through the Pallas interpreter (``interpret=True``),
mirroring the ``pallas_interpret`` GEMM backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spoga_gemm import CompilerParams

NEG_INF = -1e30


def _kernel(tables_ref, lengths_ref, q_ref, kp_ref, vp_ref, *rest,
            page_size: int, n_tbl: int, int8: bool):
    if int8:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    k = kp_ref[0, :, 0, :].astype(jnp.float32)             # (page_size, D)
    d = q.shape[-1]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * (d ** -0.5)                                        # (G, page_size)
    if int8:
        s = s * ks_ref[0, :, 0][None, :]                   # fused dequant (K)
    kpos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < lengths_ref[b], s, NEG_INF)

    # flash update: m/l scratches are (G, 128) lane-replicated scalars
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new[:, :1])                       # (G, page_size)
    l_ref[...] = alpha * l_prev + jnp.sum(pexp, axis=1, keepdims=True)
    m_ref[...] = m_new
    if int8:
        pexp = pexp * vs_ref[0, :, 0][None, :]             # fused dequant (V)
    v = vp_ref[0, :, 0, :].astype(jnp.float32)             # (page_size, D)
    pv = jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + pv

    @pl.when(p == n_tbl - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...] / l_ref[...][:, :1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, kp, vp, tables, lengths, *, k_scale=None,
                    v_scale=None, interpret: bool = False):
    """Flash decode attention over paged KV. See module docstring for
    layouts. ``k_scale``/``v_scale`` select the fused-int8-dequant variant;
    both or neither must be given."""
    if (k_scale is None) != (v_scale is None):
        raise ValueError("int8 paged attention needs both k_scale and v_scale")
    b, hkv, g, d = q.shape
    page_size = kp.shape[1]
    n_tbl = tables.shape[1]
    int8 = k_scale is not None

    def q_idx(bi, hi, pi, tbl, ln):
        return (bi, hi, 0, 0)

    def kv_idx(bi, hi, pi, tbl, ln):
        return (tbl[bi, pi], 0, hi, 0)

    def scale_idx(bi, hi, pi, tbl, ln):
        return (tbl[bi, pi], 0, hi)

    in_specs = [
        pl.BlockSpec((1, 1, g, d), q_idx),
        pl.BlockSpec((1, page_size, 1, d), kv_idx),
        pl.BlockSpec((1, page_size, 1, d), kv_idx),
    ]
    operands = [q, kp, vp]
    if int8:
        in_specs += [
            pl.BlockSpec((1, page_size, 1), scale_idx),
            pl.BlockSpec((1, page_size, 1), scale_idx),
        ]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_tbl),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), q_idx),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),   # running max
            pltpu.VMEM((g, 128), jnp.float32),   # running denominator
            pltpu.VMEM((g, d), jnp.float32),     # value accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, n_tbl=n_tbl, int8=int8),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)


def paged_attention_ref(q, kp, vp, tables, lengths, *, k_scale=None,
                        v_scale=None):
    """jnp gather twin (exact softmax) — the reference the kernel is tested
    against, and the lowering the engine uses off-TPU."""
    b, hkv, g, d = q.shape
    page_size = kp.shape[1]
    smax = tables.shape[1] * page_size

    def gather(pool):
        return pool[tables].reshape((b, smax) + pool.shape[2:])

    k_all, v_all = gather(kp), gather(vp)
    qf = q.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k_all.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    if k_scale is not None:
        scores = scores * gather(k_scale).transpose(0, 2, 1)[:, :, None, :]
    valid = jnp.arange(smax)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        probs = probs * gather(v_scale).transpose(0, 2, 1)[:, :, None, :]
    return jnp.einsum("bhgs,bshd->bhgd", probs, v_all.astype(jnp.float32))
