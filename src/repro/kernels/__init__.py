"""Pallas TPU kernels for the SPOGA GEMM hot-spot.

``spoga_gemm``  — the paper's fused bit-sliced dataflow (one kernel).
``deas_gemm``   — prior-work baseline with materialized slice partials.
``paged_attention`` — block-table decode attention (fused int8 dequant).
``ops``         — jit'd dispatch (TPU kernel / interpret / jnp fallback).
``ref``         — pure-jnp exact oracles.
"""

from repro.kernels.ops import int8_gemm, int8_gemm_dequant
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.kernels.spoga_gemm import spoga_gemm
from repro.kernels.spoga_gemm_dequant import spoga_gemm_dequant
from repro.kernels.deas_gemm import deas_gemm

__all__ = [
    "int8_gemm",
    "int8_gemm_dequant",
    "paged_attention",
    "paged_attention_ref",
    "spoga_gemm",
    "spoga_gemm_dequant",
    "deas_gemm",
]
