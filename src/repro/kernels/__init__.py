"""Pallas TPU kernels for the SPOGA GEMM hot-spot.

``spoga_gemm``  — the paper's fused bit-sliced dataflow (one kernel).
``deas_gemm``   — prior-work baseline with materialized slice partials.
``ops``         — jit'd dispatch (TPU kernel / interpret / jnp fallback).
``ref``         — pure-jnp exact oracles.
"""

from repro.kernels.ops import int8_gemm, int8_gemm_dequant
from repro.kernels.spoga_gemm import spoga_gemm
from repro.kernels.spoga_gemm_dequant import spoga_gemm_dequant
from repro.kernels.deas_gemm import deas_gemm

__all__ = [
    "int8_gemm",
    "int8_gemm_dequant",
    "spoga_gemm",
    "spoga_gemm_dequant",
    "deas_gemm",
]
