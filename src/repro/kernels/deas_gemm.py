"""Prior-work (DEAS) bit-sliced INT8 GEMM — Pallas baseline kernels.

Faithful kernel-level model of the Fig. 2(a) pipeline that SPOGA replaces:

* ``nibble_gemm`` runs ONE INT4-slice GEMM and writes its int32
  intermediate matrix to HBM — one photonic core + its per-time-step
  ADC conversions + intermediate memory store;
* four such calls produce the four intermediate matrices;
* ``deas_combine_kernel`` is the Digital Electronic Shifter-and-Adder: it
  re-reads all four intermediates from HBM and shift-adds them.

Compared to the fused SPOGA kernel this moves an extra
``4 write + 4 read = 8 x M x N x 4`` bytes of int32 HBM traffic per GEMM —
exactly the overhead class the paper eliminates (Sec. II-D), now visible to
``cost_analysis()`` in the benchmarks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spoga_gemm import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_M,
    DEFAULT_BLOCK_N,
    RADIX_BITS,
    CompilerParams,
    _dot_i32,
    _slice_tc,
)


def _nibble_gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k_tiles: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _dot_i32(x_ref[...], w_ref[...])

    @pl.when(pl.program_id(2) == n_k_tiles - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


def _nibble_gemm(x, w, bm, bn, bk, interpret):
    m, k = x.shape
    _, n = w.shape
    gm, gn, gk = m // bm, n // bn, k // bk
    return pl.pallas_call(
        functools.partial(_nibble_gemm_kernel, n_k_tiles=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)


def _deas_combine_kernel(mm_ref, ml_ref, lm_ref, ll_ref, o_ref):
    o_ref[...] = (
        (mm_ref[...] << (2 * RADIX_BITS))
        + ((ml_ref[...] + lm_ref[...]) << RADIX_BITS)
        + ll_ref[...]
    )


def _deas_combine(mm, ml, lm, ll, bm, bn, interpret):
    m, n = mm.shape
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _deas_combine_kernel,
        grid=(m // bm, n // bn),
        in_specs=[spec] * 4,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(mm, ml, lm, ll)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def deas_gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """(M, K) int8 @ (K, N) int8 -> (M, N) int32 via 4 materialized slices."""
    if x.dtype != jnp.int8 or w.dtype != jnp.int8:
        raise TypeError("deas_gemm expects int8 operands")
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w

    xm, xl = _slice_tc(xp)
    wm, wl = _slice_tc(wp)
    # Four separate cores -> four HBM-resident intermediate matrices.
    partials = (
        _nibble_gemm(xm, wm, bm, bn, bk, interpret),
        _nibble_gemm(xm, wl, bm, bn, bk, interpret),
        _nibble_gemm(xl, wm, bm, bn, bk, interpret),
        _nibble_gemm(xl, wl, bm, bn, bk, interpret),
    )
    mm, ml, lm, ll = jax.lax.optimization_barrier(partials)
    out = _deas_combine(mm, ml, lm, ll, bm, bn, interpret)
    return out[:m, :n] if (pm or pn) else out
