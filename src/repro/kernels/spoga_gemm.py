"""SPOGA fused bit-sliced INT8 GEMM — Pallas TPU kernel.

TPU-native adaptation of the SPOGA DPU (paper Fig. 3):

* an (bm x bk) x (bk x bn) tile pair plays the role of a bank of OAMEs:
  both int8 tiles are nibble-sliced *in VMEM* and the four INT4 partial
  products are computed back-to-back on the MXU
  (``dot_general(..., preferred_element_type=int32)``);
* the radix-position weighting happens **inside the accumulator update**
  (``<< 8``, ``<< 4``, ``<< 0``) — the in-transduction capacitor trick —
  so no per-slice intermediate matrix ever exists outside VMEM;
* the K-grid loop accumulating into the VMEM ``acc_ref`` scratch is the
  homodyne charge accumulation over up-to-249 OAMEs;
* exactly one output write per (bm x bn) tile = the single ADC per dot
  product.

Tile defaults are MXU-aligned (multiples of 128 on the lane dim) and sized
so the working set (x, w tiles int8 + int32 accumulator + 4 partial tiles)
stays well under ~16 MB of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RADIX_BITS = 4

# jax renamed TPUCompilerParams -> CompilerParams across releases; take
# whichever this installation provides.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 512


def _slice_tc(t):
    # Two's-complement nibble slicing, elementwise on the VMEM tile (VPU).
    msn = jnp.right_shift(t, RADIX_BITS)      # signed high nibble in [-8, 7]
    lsn = jnp.bitwise_and(t, (1 << RADIX_BITS) - 1)  # unsigned low nibble
    return msn, lsn


def _slice_planes_tile(t, n_slices: int, slice_bits: int):
    """In-VMEM generalization of ``_slice_tc``: n planes, LSB first.

    Planes are cast to int8 (they fit for slice_bits <= 7 when the operand
    honors its n_slices * slice_bits budget) so every partial product runs
    on the MXU's byte path regardless of the source operand width.
    """
    mask = (1 << slice_bits) - 1
    planes = [
        jnp.bitwise_and(jnp.right_shift(t, j * slice_bits), mask).astype(jnp.int8)
        for j in range(n_slices - 1)
    ]
    planes.append(jnp.right_shift(t, (n_slices - 1) * slice_bits).astype(jnp.int8))
    return planes


def _dot_i32(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _radix_accumulate(x_planes, w_planes, slice_bits: int):
    """All plane-pair MXU partials, grouped into i+j radix lanes (PWAB)."""
    lanes: dict[int, jnp.ndarray] = {}
    for i, xp in enumerate(x_planes):
        for j, wp in enumerate(w_planes):
            d = _dot_i32(xp, wp)
            lanes[i + j] = lanes[i + j] + d if (i + j) in lanes else d
    acc = None
    for lane, group in sorted(lanes.items()):
        term = group << (lane * slice_bits) if lane else group
        acc = term if acc is None else acc + term
    return acc


def spoga_gemm_kernel(
    x_ref, w_ref, o_ref, acc_ref, *,
    n_k_tiles: int, n_x_slices: int = 2, n_w_slices: int = 2,
    slice_bits: int = RADIX_BITS,
):
    """One grid step: slice tiles, plane-pair MXU partials, fused radix
    accumulate.  The default (2, 2, 4) configuration is the paper's four
    "wavelengths" with the 16^1 cross terms sharing one radix lane."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xp = _slice_planes_tile(x_ref[...], n_x_slices, slice_bits)
    wp = _slice_planes_tile(w_ref[...], n_w_slices, slice_bits)

    # PWAB: positional weighting fused into the charge accumulation.
    acc_ref[...] += _radix_accumulate(xp, wp, slice_bits)

    @pl.when(pl.program_id(2) == n_k_tiles - 1)
    def _emit():
        o_ref[...] = acc_ref[...]  # the single "ADC" per output tile


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "interpret",
        "n_x_slices", "n_w_slices", "slice_bits",
    ),
)
def spoga_gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    n_x_slices: int = 2,
    n_w_slices: int = 2,
    slice_bits: int = RADIX_BITS,
    interpret: bool = False,
) -> jnp.ndarray:
    """(M, K) @ (K, N) signed-int -> (M, N) int32, SPOGA fused dataflow.

    Slice counts are per operand: ``(2, 2, 4)`` is the paper's W8A8 kernel,
    ``(2, 1, 4)`` runs int4 weights in one plane (half the MXU partials),
    ``(4, 4, 4)`` carries int16 operands on the same 4-bit hardware model.
    """
    if x.dtype not in (jnp.int8, jnp.int16) or w.dtype not in (jnp.int8, jnp.int16):
        raise TypeError(f"spoga_gemm expects int8/int16 operands, got {x.dtype}, {w.dtype}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    # Pad to tile multiples; zero padding is exact for integer GEMM.
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(
            spoga_gemm_kernel, n_k_tiles=gk, n_x_slices=n_x_slices,
            n_w_slices=n_w_slices, slice_bits=slice_bits,
        ),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n] if (pm or pn) else out
