"""SPOGA fused W8A8 GEMM with dequantizing epilogue — Pallas TPU kernel.

Extends ``spoga_gemm`` with the full quantized-linear semantics in ONE
kernel: the int32 radix-fused accumulator is scaled by the per-row
activation scale and per-column weight scale during the single output
write.  This is the PWAB + "final digital result" of the paper's DPU
(Fig. 3c) with the dequantization folded into the same transduction step —
on TPU it saves a full (M, N) int32 round trip to HBM versus running the
GEMM and the epilogue as two ops.

Layout: x (M, K) int8 with x_scale (M, 1) f32; w (K, N) int8 with
w_scale (1, N) f32; out (M, N) f32 = (x @ w) * x_scale * w_scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spoga_gemm import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_M,
    DEFAULT_BLOCK_N,
    RADIX_BITS,
    CompilerParams,
    _radix_accumulate,
    _slice_planes_tile,
)


def _kernel(
    x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *,
    n_k_tiles: int, n_x_slices: int, n_w_slices: int, slice_bits: int,
):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xp = _slice_planes_tile(x_ref[...], n_x_slices, slice_bits)
    wp = _slice_planes_tile(w_ref[...], n_w_slices, slice_bits)
    acc_ref[...] += _radix_accumulate(xp, wp, slice_bits)

    @pl.when(pl.program_id(2) == n_k_tiles - 1)
    def _emit():
        # dequantizing epilogue fused into the single output write
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * xs_ref[...] * ws_ref[...]
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "interpret",
        "n_x_slices", "n_w_slices", "slice_bits",
    ),
)
def spoga_gemm_dequant(
    x: jnp.ndarray,
    w: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    n_x_slices: int = 2,
    n_w_slices: int = 2,
    slice_bits: int = RADIX_BITS,
    interpret: bool = False,
) -> jnp.ndarray:
    """(M,K) @ (K,N) int * (M,1)f32 * (1,N)f32 -> (M,N)f32, one fused pass.

    Slice counts per operand as in :func:`spoga_gemm`; (2, 2, 4) is W8A8,
    (2, 1, 4) serves ``w4a8`` layers with half the partial products.
    """
    if x.dtype not in (jnp.int8, jnp.int16) or w.dtype not in (jnp.int8, jnp.int16):
        raise TypeError("spoga_gemm_dequant expects int8/int16 operands")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and x_scale.shape == (m, 1) and w_scale.shape == (1, n)

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk))) if (pm or pk) else x
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w
    xsp = jnp.pad(x_scale, ((0, pm), (0, 0))) if pm else x_scale
    wsp = jnp.pad(w_scale, ((0, 0), (0, pn))) if pn else w_scale
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(
            _kernel, n_k_tiles=gk, n_x_slices=n_x_slices,
            n_w_slices=n_w_slices, slice_bits=slice_bits,
        ),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wp, xsp, wsp)
    return out[:m, :n] if (pm or pn) else out
