"""Jit'd dispatch wrappers: Pallas TPU kernels with a jnp fallback.

``int8_gemm(x, w, mode=...)`` is the single entry point the model layers
call.  On TPU backends the Pallas kernels run natively; elsewhere (CPU
dry-run / tests) either ``interpret=True`` executes the kernel body in
Python, or the algebraically identical jnp path is lowered so that pjit
sharding and cost analysis still see the same dataflow structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import spoga as _spoga
from repro.kernels.deas_gemm import deas_gemm
from repro.kernels.spoga_gemm import spoga_gemm

MODES = ("int8_spoga", "int8_deas", "int8_direct")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas", "interpret"))
def int8_gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    mode: str = "int8_spoga",
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """INT8 (M,K) @ (K,N) -> int32 (M,N) under the selected dataflow."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if use_pallas is None:
        use_pallas = _on_tpu()
    if mode == "int8_direct":
        return _spoga.direct_matmul(x, w)
    if use_pallas or interpret:
        fn = spoga_gemm if mode == "int8_spoga" else deas_gemm
        return fn(x, w, interpret=interpret or not _on_tpu())
    fn = _spoga.spoga_matmul if mode == "int8_spoga" else _spoga.deas_matmul
    return fn(x, w)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def int8_gemm_dequant(
    x: jnp.ndarray,
    w: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """W8A8 GEMM + dequantizing epilogue in one fused pass (f32 out).

    TPU: the ``spoga_gemm_dequant`` Pallas kernel (saves the (M, N) int32
    HBM round trip between GEMM and epilogue); elsewhere the jnp twin.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        from repro.kernels.spoga_gemm_dequant import spoga_gemm_dequant

        return spoga_gemm_dequant(x, w, x_scale, w_scale,
                                  interpret=interpret or not _on_tpu())
    acc = _spoga.spoga_matmul(x, w)
    return acc.astype(jnp.float32) * x_scale * w_scale
