"""Jit'd dispatch wrappers over the GEMM backend registry.

``int8_gemm(x, w, mode=...)`` / ``int8_gemm_dequant(...)`` keep their seed
signatures but no longer carry their own mode->function tables: they map
the call onto a registered :class:`repro.backends.GemmBackend` and let the
registry own strategy selection.  On TPU the Pallas kernels run natively;
elsewhere either ``interpret=True`` executes the kernel body in Python, or
the algebraically identical jnp path is lowered so that pjit sharding and
cost analysis still see the same dataflow structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

MODES = ("int8_spoga", "int8_deas", "int8_direct")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _backend_name(mode: str, use_pallas: bool, interpret: bool) -> str:
    """Registry name for a legacy (mode, use_pallas, interpret) triple."""
    family = mode.rsplit("_", 1)[-1]
    if family == "direct":
        return "direct"
    if interpret:  # kernel bodies forced through the interpreter
        return {"spoga": "pallas_interpret", "deas": "pallas_deas_interpret"}[family]
    if use_pallas:
        return {"spoga": "pallas_spoga", "deas": "pallas_deas"}[family]
    return {"spoga": "jnp_spoga", "deas": "jnp_deas"}[family]


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas", "interpret"))
def int8_gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    mode: str = "int8_spoga",
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """INT8 (M,K) @ (K,N) -> int32 (M,N) under the selected dataflow."""
    # Lazy import: repro.backends imports repro.kernels for its Pallas impls.
    from repro.backends import gemm_int

    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if use_pallas is None:
        use_pallas = _on_tpu()
    return gemm_int(
        x, w, quant_mode=mode,
        backend=_backend_name(mode, use_pallas, interpret),
    )


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def int8_gemm_dequant(
    x: jnp.ndarray,
    w: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """W8A8 GEMM + dequantizing epilogue in one fused pass (f32 out).

    TPU: the ``spoga_gemm_dequant`` Pallas kernel (saves the (M, N) int32
    HBM round trip between GEMM and epilogue); elsewhere the jnp twin.
    """
    from repro.backends import resolve_backend

    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret:
        name = "pallas_interpret"
    elif use_pallas:
        name = "pallas_spoga_dequant"
    else:
        name = "jnp_spoga"
    backend, spec = resolve_backend("int8_spoga", name)
    if backend.gemm_dequant is not None:
        return backend.gemm_dequant(x, w, x_scale, w_scale, spec)
    acc = backend.gemm(x, w, spec)
    return acc.astype(jnp.float32) * x_scale * w_scale
