"""Fault-tolerant checkpointing: atomic, resharding-on-restore, async.

Format: one directory per step containing

* ``arrays.npz``  — every leaf, flattened to ``path/to/leaf`` keys,
  stored as full (unsharded) host arrays;
* ``meta.json``   — step, leaf order, and user metadata.

Atomicity: written to ``<dir>/tmp.<step>`` then ``os.replace``d to
``<dir>/step_<n>`` — a crash mid-write never corrupts the latest
checkpoint (restart-safe).

Elastic restore: arrays are host-resident and unsharded, so restoring onto
a *different* mesh (more/fewer hosts after a failure) is just
``jax.device_put(leaf, new_sharding)`` — exercised by
tests/test_checkpoint.py::test_elastic_reshard.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, metadata: dict | None = None):
    """Atomic synchronous save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_names(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    # npz cannot store ml_dtypes (bf16 etc.) — persist them as same-width
    # unsigned-int BIT VIEWS and record the true dtype for restore.
    dtypes = [l.dtype.name for l in host_leaves]
    stored = [
        l.view(f"u{l.dtype.itemsize}") if l.dtype.kind == "V" or l.dtype.name
        not in np.sctypeDict else l
        for l in host_leaves
    ]
    np.savez(os.path.join(tmp, "arrays.npz"), **dict(zip(names, stored)))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "names": names, "dtypes": dtypes,
                   "metadata": metadata or {}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore_checkpoint(directory: str, step: int | None, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the given shardings pytree (elastic resharding)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    names, leaves, treedef = _flatten_with_names(like_tree)
    assert names == meta["names"], "checkpoint structure mismatch"
    restored = [data[n] for n in names]
    if "dtypes" in meta:  # undo the bit-view for ml_dtypes leaves
        import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy

        restored = [
            a if a.dtype.name == d else a.view(np.dtype(d))
            for a, d in zip(restored, meta["dtypes"])
        ]
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set") or hasattr(x, "mesh")
        )
        restored = [jax.device_put(a, s) for a, s in zip(restored, shard_leaves)]
    else:
        restored = [jax.numpy.asarray(a) for a in restored]
    # cast back to the reference dtypes (npz roundtrips bf16 as f32-safe views)
    ref_dtypes = [l.dtype for l in leaves]
    restored = [
        r if r.dtype == d else jax.numpy.asarray(r).astype(d)
        for r, d in zip(restored, ref_dtypes)
    ]
    return meta["step"], jax.tree_util.tree_unflatten(treedef, restored), meta["metadata"]


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")
    ]
    return max(steps) if steps else None


class CheckpointManager:
    """Keeps the last ``keep_n`` checkpoints; optional async (background
    thread) saves — the training loop only pays for the host snapshot."""

    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree, metadata=None):
        host_tree = jax.tree_util.tree_map(lambda l: np.asarray(jax.device_get(l)), tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree, metadata), daemon=True
            )
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree, metadata)

    def _save_and_gc(self, step, host_tree, metadata):
        save_checkpoint(self.directory, step, host_tree, metadata)
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def restore_latest(self, like_tree, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, None, like_tree, shardings)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
