"""Sharded serving: thread a device mesh through the engine.

The seed shipped the three ingredients — ``runtime/sharding.py`` (the
Megatron DP/TP/EP PartitionSpec rules), ``launch/mesh.py`` (mesh
factories) and ``runtime/collectives.py`` — without wiring any of them
into the request path.  This package is that wiring:

* ``build_mesh(MeshConfig)`` turns the runtime config into a live
  ``jax.sharding.Mesh`` (or ``None`` when sharding is off);
* ``shard_params`` resolves the per-arch param specs into
  ``NamedSharding``s and commits the weights (``jax.device_put``) at
  ``LLM`` init — serving uses pure TP (``fsdp=False``): there is no
  optimizer step to amortize a ZeRO all-gather against;
* ``pool_shardings`` does the same for the paged KV pool (heads over
  the "model" axis, block tables replicated so the host-side
  ``PageManager`` stays the one source of truth);
* ``make_host_mesh`` (re-exported, now device-count-validated) is the
  test/CI factory — CPU runs force devices with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Correctness contract (test-asserted in ``tests/test_shard.py``): at
``tp=1`` the mesh adds size-1 axes only, every constraint is trivial and
greedy outputs are **bitwise identical** to the unsharded engine; at
``tp>1`` the row-parallel reductions change accumulation order, so
outputs are allclose (and greedy token streams are compared for parity,
not logits for equality).
"""

from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.shard.core import (
    build_mesh,
    mesh_axis_size,
    pool_shardings,
    shard_params,
    validate_mesh_config,
)
from repro.shard.memory import describe_mesh, tree_device_bytes

__all__ = [
    "build_mesh",
    "describe_mesh",
    "make_host_mesh",
    "make_production_mesh",
    "mesh_axis_size",
    "pool_shardings",
    "shard_params",
    "tree_device_bytes",
    "validate_mesh_config",
]
