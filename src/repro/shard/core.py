"""Mesh construction + param/pool sharding for the serving path."""

from __future__ import annotations

from typing import Optional

import jax

from repro.launch.mesh import _make_mesh
from repro.runtime.sharding import named, param_specs, pool_specs


def validate_mesh_config(mesh_cfg) -> None:
    """Static sanity checks on a ``MeshConfig`` (no jax device access)."""
    if mesh_cfg.tp < 1 or mesh_cfg.dp < 1:
        raise ValueError(f"mesh axes must be >= 1, got dp={mesh_cfg.dp} "
                         f"tp={mesh_cfg.tp}")
    if len(mesh_cfg.axes) != 2 or len(set(mesh_cfg.axes)) != 2:
        raise ValueError(f"mesh axes must be two distinct names, got "
                         f"{mesh_cfg.axes!r}")


def build_mesh(mesh_cfg) -> Optional[jax.sharding.Mesh]:
    """``MeshConfig`` -> live mesh, or None when sharding is off.

    ``enable=True`` at ``tp=1`` builds a genuine 1x1 mesh: the whole
    sharded path (committed params, pool shardings, trace-time
    constraints) runs with every axis size 1 — the bitwise-equality
    configuration the tests pin against the unsharded engine.
    """
    if mesh_cfg is None or not mesh_cfg.enabled:
        return None
    validate_mesh_config(mesh_cfg)
    # the sharding rules key on the literal axis names "data"/"model";
    # MeshConfig defaults to those and validate() in api/config warns off
    # renames that would silently disable TP
    return _make_mesh((mesh_cfg.dp, mesh_cfg.tp), tuple(mesh_cfg.axes))


def mesh_axis_size(mesh, axis: str) -> int:
    return mesh.shape.get(axis, 1) if mesh is not None else 1


def shard_params(params, mesh, cfg):
    """Commit the weights to their TP layout (Megatron rules, no FSDP).

    ``device_put`` with a NamedSharding makes every leaf *committed*:
    downstream pjit calls see the layout as an input constraint instead
    of re-deciding it per dispatch, which is what keeps decode a single
    stable program.  Serving shards pure-TP (``fsdp=False``) — weights
    are read-only, so ZeRO-style data-axis sharding would only add
    per-step all-gathers.
    """
    specs = param_specs(params, mesh, cfg, fsdp=False)
    return jax.device_put(params, named(specs, mesh))


def pool_shardings(cache_shapes_tree, mesh):
    """NamedShardings for a paged pool tree (see ``sharding.pool_specs``)."""
    return named(pool_specs(cache_shapes_tree, mesh), mesh)
