"""Per-device footprint math for sharded trees.

Analytic, not measured: given abstract shapes (``jax.eval_shape``) and
their PartitionSpecs, compute what one device holds.  This is how the
acceptance test checks a ``mistral_large_123b``-scale config fits a tp=4
mesh (per-device params + KV < unsharded/2) without allocating 123B
params, and how the serve CLI prints the mesh memory plan.
"""

from __future__ import annotations

import math

import jax


def _axis_product(mesh, entry) -> int:
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for n in names:
        size *= mesh.shape.get(n, 1)
    return size


def shard_denominator(spec, shape, mesh) -> int:
    """How many ways this leaf is split across the mesh (1 = replicated)."""
    denom = 1
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            continue
        size = _axis_product(mesh, entry)
        if size > 1 and shape[i] % size == 0:
            denom *= size
    return denom


def leaf_device_bytes(leaf, spec, mesh) -> int:
    total = math.prod(leaf.shape) * jax.numpy.dtype(leaf.dtype).itemsize
    return total // shard_denominator(spec, leaf.shape, mesh)


def tree_device_bytes(shapes_tree, specs_tree, mesh) -> int:
    """Bytes ONE device holds for the tree under the given specs."""
    leaves = jax.tree_util.tree_leaves(shapes_tree)
    specs = jax.tree_util.tree_leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    if len(leaves) != len(specs):
        raise ValueError(f"shape/spec trees disagree: {len(leaves)} leaves "
                         f"vs {len(specs)} specs")
    return sum(leaf_device_bytes(l, s, mesh) for l, s in zip(leaves, specs))


def describe_mesh(mesh) -> str:
    if mesh is None:
        return "unsharded (no mesh)"
    shape = dict(mesh.shape)
    return (f"mesh {shape} over {mesh.size} device(s): "
            + ", ".join(f"{a}={n}" for a, n in shape.items()))
