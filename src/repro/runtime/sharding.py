"""Sharding rules: DP / TP / EP / ZeRO-1 PartitionSpecs for every pytree.

Megatron-style tensor parallelism over the "model" axis:

* embeddings / unembedding     -> vocab-sharded
* attention q/k/v projections  -> output (head) dim sharded; wo row-sharded
* MLP in projections           -> column-sharded; down/out row-sharded
* MoE experts                  -> expert-parallel over "model" when the
  expert count divides the axis, otherwise TP inside each expert
* recurrent cells              -> state width sharded

Data parallelism over ("pod", "data") — the "pod" axis only ever carries
pure DP, which is what makes the multi-pod mesh trivially correct.
Divisibility is checked leaf-by-leaf; anything unshardable is replicated
(never an error — the dry-run must pass for every cell).

ZeRO-1 (`zero1_specs`): optimizer moments additionally shard their largest
replicated dim over "data".
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh, names) -> int:
    size = 1
    for n in names if isinstance(names, tuple) else (names,):
        size *= mesh.shape[n]
    return size


def _div(dim: int, mesh, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


# ---------------------------------------------------------------------------
# Parameter specs (name-based Megatron rules)
# ---------------------------------------------------------------------------

_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_uq", "w_uk", "w_uv",
        "w_gate_branch", "w_x_branch", "w_rec_gate", "w_in_gate", "w_ogate",
        "w_zifo")
_ROW = ("wo", "w_down", "w_out")
_VOCAB = ("embed", "head")
_REPL = ("norm1", "norm2", "final_norm", "enc_final_norm", "norm_x", "q_norm",
         "kv_norm", "gamma", "beta", "router", "w_dq", "w_dkv", "w_kr",
         "w_igate", "w_fgate")


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _path_names(path) -> tuple:
    return tuple(
        str(getattr(e, "key", getattr(e, "name", ""))) for e in path
    )


def _param_rule(path, shape, mesh, cfg: ModelConfig, fsdp: bool) -> P:
    name = _leaf_name(path)
    names = _path_names(path)
    nd = len(shape)
    stacked = 1 if ("blocks" in names or "cross_blocks" in names or
                    "enc_blocks" in names) and name not in _VOCAB else 0
    # effective (un-stacked) shape
    eff = shape[stacked:]
    pre = (None,) * stacked
    has_data = "data" in mesh.axis_names

    def spec(*axes):
        return P(*(pre + axes))

    def maybe_fsdp(dim: int):
        """FSDP (ZeRO-3): shard this dim over "data" if enabled+divisible."""
        return "data" if (fsdp and has_data and _div(dim, mesh, "data")) else None

    if name in ("experts_gate", "experts_up", "experts_down"):
        e, d_in, d_out = eff
        if _div(e, mesh, "model"):                     # expert parallelism
            return spec("model", maybe_fsdp(d_in), None)
        if name == "experts_down" and _div(d_in, mesh, "model"):
            return spec(maybe_fsdp(e), "model", None)  # TP inside experts
        if name != "experts_down" and _div(d_out, mesh, "model"):
            return spec(maybe_fsdp(e), None, "model")
        return spec(None, None, None)
    if name == "r_zifo":                               # (4, H, dh, dh)
        return spec(None, None, None, "model") if _div(eff[-1], mesh, "model") else spec(
            None, None, None, None
        )
    if name == "lam":
        return spec("model") if _div(eff[0], mesh, "model") else spec(None)
    if name == "conv_w":
        return spec(None, "model") if _div(eff[-1], mesh, "model") else spec(None, None)
    if name in _VOCAB and nd - stacked == 2:
        v, d = eff
        if _div(v, mesh, "model"):
            return spec("model", maybe_fsdp(d))
        if _div(d, mesh, "model"):
            return spec(maybe_fsdp(v), "model")
        return spec(None, None)
    if name in _COL and nd - stacked == 2:
        if _div(eff[1], mesh, "model"):
            return spec(maybe_fsdp(eff[0]), "model")
        return spec(None, None)
    if name in _ROW and nd - stacked == 2:
        if _div(eff[0], mesh, "model"):
            return spec("model", maybe_fsdp(eff[1]))
        return spec(None, None)
    return P(*((None,) * nd))


def param_specs(params_or_shapes, mesh, cfg: ModelConfig, fsdp: bool = True):
    """Pytree of PartitionSpec mirroring the params tree.

    ``fsdp=True`` (default) additionally shards weights over the "data"
    axis (ZeRO-3): at 123B params, TP-16 alone leaves ~30 GiB fp32 of
    replicated master weights per device — FSDP brings it to ~1.9 GiB.
    The "pod" axis stays pure-DP (params replicated across pods; FSDP
    all-gathers stay inside a pod's ICI domain).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(path, leaf.shape, mesh, cfg, fsdp),
        params_or_shapes,
    )


# ---------------------------------------------------------------------------
# Batch / cache / optimizer specs
# ---------------------------------------------------------------------------

def batch_pspec(shape, mesh) -> P:
    """Shard dim0 (global batch) over DP axes when divisible, else replicate;
    shard the trailing (feature) dim over model when large & divisible."""
    dp = data_axes(mesh)
    first = dp if shape[0] % _axis_size(mesh, dp) == 0 else None
    rest = [None] * (len(shape) - 1)
    if len(shape) >= 3 and shape[-1] % _axis_size(mesh, "model") == 0 and shape[-1] >= 1024:
        rest[-1] = "model"
    return P(first, *rest)


def batch_specs_tree(batch_shapes, mesh):
    return jax.tree_util.tree_map(lambda s: batch_pspec(s.shape, mesh), batch_shapes)


def cache_specs(cache_shapes_tree, mesh):
    """KV/state caches: batch over DP if divisible, last dim over model."""
    dp = data_axes(mesh)
    dp_size = _axis_size(mesh, dp)
    model_size = _axis_size(mesh, "model")

    def rule(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        names = _path_names(path)
        stacked = 1 if ("blocks" in names or "cross_kv" in names) else 0
        if name == "pos":
            return P(None)
        axes = [None] * len(shape)
        bdim = stacked  # batch dim after the layer-stack dim
        if len(shape) > bdim and shape[bdim] % dp_size == 0 and shape[bdim] > 1:
            axes[bdim] = dp
        if len(shape) - stacked >= 2 and shape[-1] % model_size == 0:
            axes[-1] = "model"
        return P(*axes)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes_tree)


def opt_state_specs(opt_shapes, p_specs, mesh, zero1: bool = True):
    """Adam moments inherit the param spec; ZeRO-1 adds "data" sharding on
    the largest still-replicated dim."""
    dp_size = mesh.shape.get("data", 1)

    def moment_spec(pspec, leaf):
        spec = list(pspec) + [None] * (len(leaf.shape) - len(pspec))
        if zero1 and "data" not in spec:  # FSDP may already consume "data"
            best, best_dim = -1, -1
            for i, (ax, d) in enumerate(zip(spec, leaf.shape)):
                if ax is None and d % dp_size == 0 and d > best:
                    best, best_dim = d, i
            if best_dim >= 0 and best >= dp_size:
                spec[best_dim] = "data"
        return P(*spec)

    out = {
        "step": P(),
        "m": jax.tree_util.tree_map(moment_spec, p_specs, opt_shapes["m"]),
        "v": jax.tree_util.tree_map(moment_spec, p_specs, opt_shapes["v"]),
    }
    if "master" in opt_shapes:
        out["master"] = jax.tree_util.tree_map(
            moment_spec, p_specs, opt_shapes["master"]
        )
    return out


# ---------------------------------------------------------------------------
# Paged-pool specs (serving: repro/paging/cache.py page pools)
# ---------------------------------------------------------------------------

# pool leaves carrying a KV-head axis: kp/vp are (n_pages, page_size, Hkv,
# head_dim) (stacked variants prepend n_periods), their int8 scales drop
# the trailing head_dim
_POOL_HEAD_AXIS = {"kp": -2, "vp": -2, "kp_scale": -1, "vp_scale": -1}


def pool_specs(cache_shapes_tree, mesh):
    """PartitionSpecs for a paged serving cache (``paged_cache_shapes``).

    Page pools shard their KV-head axis over "model" — the axis the
    attention shards its heads over, so each device's pool slice feeds its
    own head shard with no gather traffic.  MLA latent pools (``ckvp``)
    shard the latent rank; the shared rope pool (``krp``) is replicated
    (every head shard reads all rope dims).  ``pos`` and ``block_tables``
    replicate: the host-side ``PageManager`` stays the single source of
    truth and every device sees the same table.  Per-lane leaves
    (recurrent state, local-attention rings) shard their trailing width.
    Any non-divisible dim falls back to replication — never an error.
    """
    model = _axis_size(mesh, "model")

    def rule(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        nd = len(shape)
        axes = [None] * nd
        if name in ("pos", "block_tables") or nd < 2:
            return P(*axes)
        if name in _POOL_HEAD_AXIS:
            dim = nd + _POOL_HEAD_AXIS[name]
            if shape[dim] % model == 0:
                axes[dim] = "model"
            return P(*axes)
        if name == "krp":
            return P(*axes)
        # ckvp latent pools and per-lane leaves (recurrent h/conv/C/n/c,
        # local-attn rings): trailing width over "model" when divisible
        if shape[-1] % model == 0:
            axes[-1] = "model"
        return P(*axes)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes_tree)


def _sp_constrain(x, seq_axis):
    """Internal: pin (B, S, d) to batch-over-DP with the given seq sharding."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty or x.ndim != 3:
            return x
        dp = tuple(a for a in m.axis_names if a in ("pod", "data"))
        dp_size = 1
        for a in dp:
            dp_size *= m.shape[a]
        model_size = m.shape.get("model", 1)
        first = dp if (dp and x.shape[0] % dp_size == 0) else None
        second = seq_axis if (seq_axis is None or x.shape[1] % model_size == 0) else None
        return jax.lax.with_sharding_constraint(x, P(first, second, None))
    except Exception:  # pragma: no cover
        return x


def sp_enter(x):
    """Megatron-SP boundary INTO attention/MLP: all-gather the sequence dim.

    Activations stay seq-sharded over "model" between layers (smallest
    resident form); entering a TP region each rank needs the full sequence
    for its head/column shard.  Without this explicit constraint XLA's
    SPMD partitioner prefers to UN-shard the TP weights instead —
    measured 87 GiB/device/layer-step of f32 weight all-gathers at 123B
    vs ~1.6 GiB of activation gathers (EXPERIMENTS.md Perf A-log)."""
    return _sp_constrain(x, None)


def sp_exit(x):
    """Megatron-SP boundary OUT of attention/MLP: reduce-scatter the row-
    parallel output back to seq-sharded."""
    return _sp_constrain(x, "model")


def constrain_activations(x):
    """Megatron-SP: pin (B, S, d) activations at layer boundaries to
    batch-over-DP x sequence-over-"model" sharding.  The scan-over-layers
    carry (saved for backward) is what dominates HBM at 100B scale; without
    this it is replicated over the model axis (16x larger).

    No-op outside a mesh context (CPU unit tests) or when dims don't
    divide.  XLA SPMD re-gathers inside attention/MLP as needed.
    """
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty or x.ndim != 3:
            return x
        dp = tuple(a for a in m.axis_names if a in ("pod", "data"))
        dp_size = 1
        for a in dp:
            dp_size *= m.shape[a]
        model_size = m.shape.get("model", 1)
        first = dp if (dp and x.shape[0] % dp_size == 0) else None
        second = "model" if x.shape[1] % model_size == 0 else None
        return jax.lax.with_sharding_constraint(x, P(first, second, None))
    except Exception:  # pragma: no cover — never fail a model for sharding
        return x


def constrain_decode_carry(x):
    """Serving decode/verify activations (B, 1..k, d): batch over DP,
    sequence and features replicated.  One decode row per lane is too
    narrow to seq-shard; pinning the carry keeps XLA's SPMD partitioner
    from round-tripping it through "model"-sharded layouts between the
    row-parallel reduce of one layer and the column-parallel matmul of the
    next.  No-op outside a mesh context (the unsharded engine)."""
    return _sp_constrain(x, None)


def named(tree_of_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
