from repro.runtime.sharding import (
    param_specs,
    batch_pspec,
    cache_specs,
    opt_state_specs,
    named,
    data_axes,
)

__all__ = [
    "param_specs",
    "batch_pspec",
    "cache_specs",
    "opt_state_specs",
    "named",
    "data_axes",
]
