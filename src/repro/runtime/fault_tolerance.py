"""Fault tolerance & straggler mitigation for 1000+ node jobs.

Three cooperating mechanisms (all exercised by tests/test_fault_tolerance.py):

* **Checkpoint/restart** — ``run_with_restart`` wraps the training loop;
  on any worker exception it restores the latest atomic checkpoint and
  resumes.  The data pipeline is stateless (step-indexed PRNG), so a
  restarted run replays the *exact* token stream: resume is bit-exact.

* **Elastic scaling** — checkpoints are unsharded host arrays; on restart
  with a different healthy-device count the restore path simply
  device_puts onto the new mesh (see checkpoint.py).  ``ElasticPlan``
  picks the largest (dp x model) mesh that fits the surviving devices.

* **Straggler detection** — ``StragglerMonitor`` tracks per-host step
  durations with an EWMA and flags hosts slower than ``threshold`` x the
  fleet median; the launcher's response at scale is to evict + restart
  elastically (here: recorded + surfaced in metrics).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    threshold: float = 2.0
    alpha: float = 0.3  # EWMA weight

    def __post_init__(self):
        self._ewma = np.zeros(self.n_hosts)
        self._seen = np.zeros(self.n_hosts, bool)

    def record(self, host: int, duration_s: float):
        if not self._seen[host]:
            self._ewma[host] = duration_s
            self._seen[host] = True
        else:
            self._ewma[host] = self.alpha * duration_s + (1 - self.alpha) * self._ewma[host]

    def stragglers(self) -> list[int]:
        if not self._seen.any():
            return []
        med = float(np.median(self._ewma[self._seen]))
        return [
            h for h in range(self.n_hosts)
            if self._seen[h] and self._ewma[h] > self.threshold * max(med, 1e-9)
        ]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Largest viable (dp, model) mesh for the surviving device count."""
    dp: int
    model: int

    @staticmethod
    def plan(healthy_devices: int, model_parallel: int) -> "ElasticPlan":
        if healthy_devices < model_parallel:
            # degrade TP too (restore handles resharding either way)
            model_parallel = max(
                m for m in range(1, healthy_devices + 1) if healthy_devices % m == 0
            )
        dp = healthy_devices // model_parallel
        return ElasticPlan(dp=dp, model=model_parallel)


class WorkerFailure(RuntimeError):
    """Raised by fault-injection hooks in tests."""


def run_with_restart(
    make_state,
    train_one_step,
    ckpt_manager,
    n_steps: int,
    checkpoint_every: int = 10,
    max_failures: int = 3,
    on_restart=None,
):
    """Generic restartable loop.

    ``make_state()`` -> initial (step, state); ``train_one_step(step, state)``
    -> state (may raise).  Returns ((final_step, final_state), n_restarts).
    """
    failures = 0
    step, state = make_state()
    try:
        latest = ckpt_manager.restore_latest(state)
        step, state = latest[0], latest[1]
    except FileNotFoundError:
        pass

    while step < n_steps:
        try:
            state = train_one_step(step, state)
            step += 1
            if step % checkpoint_every == 0 or step == n_steps:
                ckpt_manager.save(step, state, metadata={"wallclock": time.time()})
        except WorkerFailure:
            failures += 1
            if failures > max_failures:
                raise
            if on_restart is not None:
                on_restart(failures)
            # restore-from-latest: may come back on a different mesh.  A
            # failure before the first checkpoint restarts from scratch.
            try:
                step, state, _ = ckpt_manager.restore_latest(state)
            except FileNotFoundError:
                step, state = make_state()
    return (step, state), failures
