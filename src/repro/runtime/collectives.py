"""Distributed-optimization collectives: int8-compressed gradient all-reduce.

``compressed_psum_mean`` reuses the SPOGA quantization machinery at the
collective layer: each shard quantizes its local gradient to int8 against a
globally agreed scale (psum-max), all-reduces the int8 payload with int32
accumulation (>=16-bit accumulation, the paper's rule), and dequantizes —
4x less gradient traffic than fp32 and 2x less than bf16, with an error
bounded by the quantization step.  Used inside ``shard_map`` data-parallel
training when TrainConfig.grad_compression is set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# jax.shard_map graduated from jax.experimental on newer releases (and
# renamed check_rep -> check_vma); export a version-stable alias for tests
# and launch code.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)

INT8_MAX = 127.0


def compressed_psum_mean(tree, axis_name: str, stochastic_key=None):
    """All-reduce-mean a gradient pytree with int8 compression."""

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = (
        jax.random.split(stochastic_key, len(leaves))
        if stochastic_key is not None
        else [None] * len(leaves)
    )

    def one(g, key):
        gf = g.astype(jnp.float32)
        # agree on a global scale: max |g| across shards
        absmax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(absmax, 1e-12) / INT8_MAX
        scaled = gf / scale
        if key is not None:  # stochastic rounding: unbiased compression
            noise = jax.random.uniform(key, scaled.shape, jnp.float32) - 0.5
            q = jnp.round(scaled + noise)
        else:
            q = jnp.round(scaled)
        q = jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)
        # int32 accumulation across the axis, then dequant + mean
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        return (total.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(g.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(g, k) for g, k in zip(leaves, keys)]
    )


def psum_mean(tree, axis_name: str):
    """Uncompressed reference."""
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return jax.tree_util.tree_map(lambda g: jax.lax.psum(g, axis_name) / n, tree)
