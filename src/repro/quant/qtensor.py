"""Quantized tensor container + symmetric integer quantization.

Symmetric per-axis scaling: ``x ~= data * scale`` with ``data`` a signed
integer array and ``scale = absmax / qmax`` where ``qmax = 2^(bits-1) - 1``
(127 for the default int8).  Registered as a pytree so QTensors flow
through jit/pjit/shard_map and checkpoints unchanged.  ``bits`` follows
the backend registry's QuantSpec widths: 8 -> int8 storage (the paper's
byte-size operands), 4 -> int4-in-int8 (one 4-bit slice plane), 16 ->
int16 storage (four planes on nibble hardware).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def qmax_for_bits(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def storage_dtype(bits: int):
    return jnp.int8 if bits <= 8 else jnp.int16


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """signed-int data + broadcastable fp32 scale (``x ~= data * scale``)."""

    data: jnp.ndarray   # int8 (bits <= 8) or int16
    scale: jnp.ndarray  # fp32, broadcastable against ``data``

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def dequantize(self, dtype=jnp.float32):
        return (self.data.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.data, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _absmax_scale(x: jnp.ndarray, axis, qmax: float = INT8_MAX) -> jnp.ndarray:
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    return jnp.maximum(absmax, 1e-8) / qmax


def quantize(
    x: jnp.ndarray, axis=None, scale: jnp.ndarray | None = None, bits: int = 8
) -> QTensor:
    """Symmetric integer quantization.

    ``axis``: reduction axis/axes for the absmax (e.g. ``0`` for
    per-output-channel weights ``(K, N)``; ``-1`` for per-row activations).
    ``None`` means per-tensor.  A precomputed calibration ``scale`` wins.
    ``bits``: operand width; values clip to ±(2^(bits-1)-1).
    """
    qmax = qmax_for_bits(bits)
    if scale is None:
        if axis is None:
            axis = tuple(range(x.ndim))
        scale = _absmax_scale(x, axis, qmax)
    data = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return QTensor(data.astype(storage_dtype(bits)), scale)


def dequantize(q: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    return q.dequantize(dtype)


def rail_hits(data: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Count of quantized values sitting on the ±qmax rail.

    With dynamic absmax scaling the largest-magnitude element maps
    *exactly* onto ±qmax, so true clipping never occurs — but at-rail
    occupancy is the saturation signal anyway: a distribution crowding
    the rail is one re-quantization (or one calibrated static scale)
    away from clipping, the software mirror of driving an analog channel
    against its dynamic-range ceiling.  Used by the numerics watchdog.
    """
    qmax = qmax_for_bits(bits)
    return jnp.sum(jnp.abs(data.astype(jnp.float32)) >= qmax)
