"""Quantized tensor container + symmetric int8 quantization.

Symmetric per-axis scaling: ``x ~= data * scale`` with ``data`` int8 and
``scale = absmax / 127``.  Registered as a pytree so QTensors flow through
jit/pjit/shard_map and checkpoints unchanged.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """int8 data + broadcastable fp32 scale (``x ~= data * scale``)."""

    data: jnp.ndarray   # int8
    scale: jnp.ndarray  # fp32, broadcastable against ``data``

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def dequantize(self, dtype=jnp.float32):
        return (self.data.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.data, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _absmax_scale(x: jnp.ndarray, axis) -> jnp.ndarray:
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    return jnp.maximum(absmax, 1e-8) / INT8_MAX


def quantize(x: jnp.ndarray, axis=None, scale: jnp.ndarray | None = None) -> QTensor:
    """Symmetric int8 quantization.

    ``axis``: reduction axis/axes for the absmax (e.g. ``0`` for
    per-output-channel weights ``(K, N)``; ``-1`` for per-row activations).
    ``None`` means per-tensor.  A precomputed calibration ``scale`` wins.
    """
    if scale is None:
        if axis is None:
            axis = tuple(range(x.ndim))
        scale = _absmax_scale(x, axis)
    data = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -INT8_MAX, INT8_MAX)
    return QTensor(data.astype(jnp.int8), scale)


def dequantize(q: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    return q.dequantize(dtype)
