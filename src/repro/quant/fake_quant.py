"""QAT fake quantization with straight-through gradients.

The paper motivates byte-size GEMM with *training* (>=8-bit operands,
>=16-bit accumulation, [26][27]).  ``fake_quant`` simulates the SPOGA int8
datapath in the forward pass while passing gradients straight through, so a
model can be trained "on" the accelerator numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtensor import INT8_MAX, _absmax_scale


def fake_quant(x: jnp.ndarray, axis=-1) -> jnp.ndarray:
    """Round-trip x through symmetric int8; identity gradient (STE)."""
    scale = jax.lax.stop_gradient(_absmax_scale(x, axis))
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX)
    dq = q * scale
    return x + jax.lax.stop_gradient(dq - x)
