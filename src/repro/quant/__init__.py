from repro.quant.qtensor import QTensor, quantize, dequantize
from repro.quant.fake_quant import fake_quant
from repro.quant.calibrate import absmax_calibrate, percentile_calibrate

__all__ = [
    "QTensor",
    "quantize",
    "dequantize",
    "fake_quant",
    "absmax_calibrate",
    "percentile_calibrate",
]
