"""PTQ calibration: derive activation scales from sample batches."""

from __future__ import annotations

import jax.numpy as jnp

from repro.quant.qtensor import INT8_MAX


def absmax_calibrate(samples: list[jnp.ndarray], axis=None) -> jnp.ndarray:
    """Max-abs over every calibration batch -> symmetric scale."""
    if axis is None:
        absmax = max(float(jnp.max(jnp.abs(s))) for s in samples)
        return jnp.asarray(max(absmax, 1e-8) / INT8_MAX, jnp.float32)
    per_batch = [jnp.max(jnp.abs(s.astype(jnp.float32)), axis=axis, keepdims=True) for s in samples]
    absmax = jnp.max(jnp.stack(per_batch), axis=0)
    return jnp.maximum(absmax, 1e-8) / INT8_MAX


def percentile_calibrate(samples: list[jnp.ndarray], pct: float = 99.9) -> jnp.ndarray:
    """Clip-at-percentile scale (robust to activation outliers)."""
    flat = jnp.concatenate([jnp.abs(s.astype(jnp.float32)).reshape(-1) for s in samples])
    absmax = jnp.percentile(flat, pct)
    return jnp.maximum(absmax, 1e-8) / INT8_MAX
