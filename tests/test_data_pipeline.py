"""Data pipeline: determinism, host sharding, token validity."""

import numpy as np

from repro.data.pipeline import SyntheticTokenPipeline


def test_deterministic_across_instances():
    a = SyntheticTokenPipeline(vocab_size=128, seq_len=16, global_batch=8, seed=7)
    b = SyntheticTokenPipeline(vocab_size=128, seq_len=16, global_batch=8, seed=7)
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(a.global_batch_at(i)), np.asarray(b.global_batch_at(i)))


def test_different_steps_differ():
    p = SyntheticTokenPipeline(vocab_size=128, seq_len=16, global_batch=8, seed=0)
    assert not np.array_equal(
        np.asarray(p.global_batch_at(0)), np.asarray(p.global_batch_at(1)))


def test_tokens_in_vocab():
    p = SyntheticTokenPipeline(vocab_size=97, seq_len=33, global_batch=5, seed=1)
    t = np.asarray(p.global_batch_at(0))
    assert t.shape == (5, 33)
    assert t.min() >= 0 and t.max() < 97


def test_host_slices_partition_global_batch():
    """The per-host shards, concatenated in host order, equal the global
    batch — the multi-host data-loading invariant."""
    g = SyntheticTokenPipeline(vocab_size=64, seq_len=8, global_batch=12, seed=2)
    full = np.asarray(g.global_batch_at(5))
    parts = []
    for h in range(4):
        ph = SyntheticTokenPipeline(vocab_size=64, seq_len=8, global_batch=12,
                                    seed=2, n_hosts=4, host_id=h)
        parts.append(np.asarray(ph.host_batch_at(5)))
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_not_iid_uniform():
    """The stream is structured (learnable), not iid uniform — a bigram
    model must beat the unigram entropy floor."""
    p = SyntheticTokenPipeline(vocab_size=64, seq_len=256, global_batch=16, seed=3)
    t = np.asarray(p.global_batch_at(0))
    pairs = {}
    for row in t:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    # average conditional entropy < log2(vocab) by a clear margin
    ents = []
    for a, nxt in pairs.items():
        vals, counts = np.unique(nxt, return_counts=True)
        q = counts / counts.sum()
        ents.append(-(q * np.log2(q)).sum())
    assert np.mean(ents) < 0.8 * np.log2(64)
