"""The ``repro.api`` facade: layered RuntimeConfig (validation, dict
round-trip, resolution), the LLM entrypoint (bitwise-exact vs the solo
``serve_batch`` baseline across cache modes), engine policies (stacked
admission, threshold defrag), detokenization hooks, and the deprecation
shims that keep the pre-facade surface importable and behavior-equal.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    LLM,
    BucketBatchedAdmission,
    FIFOAdmission,
    KVConfig,
    QuantRuntime,
    RequestOutput,
    RuntimeConfig,
    SamplingDefaults,
    SamplingParams,
    SchedulerConfig,
    ThresholdDefrag,
    serve_batch,
)
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving import EngineConfig, ServingEngine
from repro.serving.policies import NeverDefrag


# ---------------------------------------------------------------------------
# RuntimeConfig: validation + serialization + resolution
# ---------------------------------------------------------------------------

def test_runtime_config_roundtrip_default_and_custom():
    for rc in (
        RuntimeConfig(),
        RuntimeConfig(
            quant=QuantRuntime(mode="w4a8", gemm_backend="pallas_interpret"),
            kv=KVConfig(mode="paged", dtype="int8", cache_len=64, page_size=8,
                        n_pages=11, paged_attn_impl="pallas_interpret"),
            scheduler=SchedulerConfig(n_slots=3, max_prefills_per_step=2,
                                      prefill_buckets=(8, 16),
                                      prefill_chunk=8,
                                      defrag_threshold=0.25),
            sampling=SamplingDefaults(greedy=False, temperature=0.7, top_k=40,
                                      seed=7),
            max_new_tokens=32,
            eos_token=2,
            reduced=True,
        ),
        RuntimeConfig(scheduler=SchedulerConfig(prefill_buckets="auto",
                                                defrag_threshold=None)),
    ):
        blob = json.dumps(rc.to_dict())  # must be plain JSON
        assert RuntimeConfig.from_dict(json.loads(blob)) == rc


def test_runtime_config_from_partial_dict():
    # missing keys take defaults, so serialized configs survive field growth
    rc = RuntimeConfig.from_dict({"kv": {"mode": "paged"}, "max_new_tokens": 4})
    assert rc.kv.mode == "paged" and rc.kv.dtype == "bf16"
    assert rc.max_new_tokens == 4 and rc.scheduler == SchedulerConfig()


@pytest.mark.parametrize("bad", [
    dict(quant=dict(mode="w3a9z")),
    dict(kv=dict(mode="virtual")),
    dict(kv=dict(dtype="fp8")),
    dict(kv=dict(cache_len=0)),
    dict(kv=dict(n_pages=8)),                      # n_pages without paged
    dict(kv=dict(mode="paged", n_pages=1)),        # trash page needs >= 2
    dict(kv=dict(paged_attn_impl="triton")),
    dict(scheduler=dict(n_slots=0)),
    dict(scheduler=dict(prefill_buckets="buckets")),
    dict(scheduler=dict(defrag_threshold=1.5)),
    dict(scheduler=dict(prefill_chunk=8)),         # chunking without paged
    dict(max_new_tokens=0),
])
def test_runtime_config_validation_errors(bad):
    def build(cls, kw):
        return cls(**kw) if kw else cls()

    with pytest.raises((ValueError, KeyError)):
        RuntimeConfig(
            quant=build(QuantRuntime, bad.get("quant")),
            kv=build(KVConfig, bad.get("kv")),
            scheduler=build(SchedulerConfig, bad.get("scheduler")),
            max_new_tokens=bad.get("max_new_tokens", 16),
        )


def test_runtime_config_cross_validation():
    with pytest.raises(ValueError, match="multiple of"):
        RuntimeConfig(kv=KVConfig(mode="paged", page_size=8),
                      scheduler=SchedulerConfig(prefill_chunk=12))
    with pytest.raises(ValueError, match="bucket"):
        RuntimeConfig(kv=KVConfig(cache_len=16),
                      scheduler=SchedulerConfig(prefill_buckets=(8, 32)))
    # stacked admission now works in BOTH cache modes (paged groups
    # scatter per-lane pages) — the old paged rejection is gone
    RuntimeConfig(kv=KVConfig(mode="paged"),
                  scheduler=SchedulerConfig(batched_admission=True))
    # the prefix cache lives in the page pool
    with pytest.raises(ValueError, match="prefix_cache"):
        KVConfig(mode="slot", prefix_cache=True)
    with pytest.raises(ValueError, match="prefix_min_pages"):
        KVConfig(mode="paged", prefix_cache=True, prefix_min_pages=0)
    # priority ordering and FIFO bucket-stacking are mutually exclusive
    with pytest.raises(ValueError, match="batched_admission"):
        SchedulerConfig(admission="priority", batched_admission=True)
    with pytest.raises(ValueError, match="admission"):
        SchedulerConfig(admission="sjf")


def test_runtime_config_resolution():
    base = reduced(get_config("llama3.2-1b")).with_(remat=False)
    rc = RuntimeConfig(
        quant=QuantRuntime(mode="int8_spoga"),
        kv=KVConfig(mode="paged", dtype="int8", cache_len=48, page_size=8),
        scheduler=SchedulerConfig(n_slots=3, prefill_chunk=8),
        eos_token=5,
    )
    model_cfg, ecfg = rc.resolve(base)
    # model side: ordinary frozen ModelConfig (jit-hash behaviour unchanged)
    assert type(model_cfg) is type(base) and hash(model_cfg) is not None
    assert model_cfg.quant_mode == "int8_spoga"
    assert model_cfg.kv_cache_dtype == "int8"
    assert model_cfg.scan_layers == base.scan_layers  # untouched fields survive
    # engine side: the legacy EngineConfig, fully derived
    assert ecfg == EngineConfig(n_slots=3, cache_len=48, prefill_buckets=None,
                                eos_token=5, cache_mode="paged", page_size=8,
                                prefill_chunk=8)
    # workload-derived sizing + auto buckets
    rc2 = RuntimeConfig(scheduler=SchedulerConfig(prefill_buckets="auto"))
    ecfg2 = rc2.resolve_engine(base, prompt_len=32, gen_tokens=16)
    assert ecfg2.cache_len == 32 + 16 + 8  # default_cache_len policy
    assert ecfg2.prefill_buckets == (8, 16, 32)
    with pytest.raises(ValueError, match="cache"):
        rc2.resolve_engine(base)  # no cache_len, no hints
    # auto buckets are dropped for recurrent stacks (padding pollutes state)
    xl = reduced(get_config("xlstm-125m"))
    assert rc2.resolve_engine(xl, prompt_len=32, gen_tokens=8).prefill_buckets is None


def test_build_policies_mapping():
    from repro.api import PriorityAdmission, SharedPrefix

    p = RuntimeConfig().build_policies()
    assert isinstance(p.admission, FIFOAdmission)
    assert isinstance(p.defrag, ThresholdDefrag)
    assert isinstance(p.prefix, SharedPrefix)
    p2 = RuntimeConfig(scheduler=SchedulerConfig(
        batched_admission=True, defrag_threshold=None)).build_policies()
    assert isinstance(p2.admission, BucketBatchedAdmission)
    assert isinstance(p2.defrag, NeverDefrag)
    p3 = RuntimeConfig(scheduler=SchedulerConfig(
        admission="priority")).build_policies()
    assert isinstance(p3.admission, PriorityAdmission)
    p4 = RuntimeConfig(kv=KVConfig(mode="paged", prefix_cache=True,
                                   prefix_min_pages=3)).build_policies()
    assert p4.prefix.min_pages == 3


# ---------------------------------------------------------------------------
# Preset registry + --runtime loading (PR 4 follow-up)
# ---------------------------------------------------------------------------

def test_presets_roundtrip_and_resolve():
    from repro.api import get_preset, list_presets

    base = reduced(get_config("llama3.2-1b")).with_(remat=False)
    assert "prefix-interactive" in list_presets()
    for name in list_presets():
        rt = get_preset(name)
        # every built-in preset is JSON round-trippable and resolvable
        assert RuntimeConfig.from_dict(
            json.loads(json.dumps(rt.to_dict()))) == rt
        model_cfg, ecfg = rt.resolve(base, prompt_len=16, gen_tokens=8)
        assert ecfg.cache_len >= 24
    assert get_preset("prefix-interactive").kv.prefix_cache
    with pytest.raises(KeyError, match="unknown runtime preset"):
        get_preset("nope")


def test_register_preset_guard():
    from repro.api import get_preset, register_preset

    register_preset("test-tmp", RuntimeConfig(max_new_tokens=3))
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_preset("test-tmp", RuntimeConfig())
        register_preset("test-tmp", RuntimeConfig(max_new_tokens=4),
                        overwrite=True)
        assert get_preset("test-tmp").max_new_tokens == 4
        with pytest.raises(TypeError):
            register_preset("test-bad", {"max_new_tokens": 4})
    finally:
        from repro.api.config import _PRESETS
        _PRESETS.pop("test-tmp", None)


def test_load_runtime_from_file_and_preset(tmp_path):
    from repro.api import get_preset, load_runtime

    rt = RuntimeConfig(kv=KVConfig(mode="paged", page_size=8,
                                   prefix_cache=True),
                       max_new_tokens=5)
    path = tmp_path / "runtime.json"
    path.write_text(json.dumps(rt.to_dict()))
    assert load_runtime(str(path)) == rt
    assert load_runtime("paged-server") is get_preset("paged-server")
    with pytest.raises(ValueError, match="neither"):
        load_runtime("definitely-not-a-preset")


# ---------------------------------------------------------------------------
# LLM.generate: bitwise-exact vs the solo serve_batch baseline
# ---------------------------------------------------------------------------

def _solo(llm, prompt, gen):
    out, _ = serve_batch(llm.config, llm.params,
                         {"tokens": jnp.asarray([prompt], jnp.int32)},
                         cache_len=llm.engine.engine_cfg.cache_len,
                         gen_tokens=gen)
    return np.asarray(out)[0].tolist()


LLM_CASES = [
    ("slot-bf16", KVConfig()),
    ("paged-bf16", KVConfig(mode="paged", page_size=8)),
    ("paged-int8", KVConfig(mode="paged", dtype="int8", page_size=8)),
]


@pytest.mark.parametrize("name,kv", LLM_CASES, ids=[c[0] for c in LLM_CASES])
def test_llm_generate_matches_solo(name, kv):
    """Acceptance: LLM.generate greedy tokens are bitwise the solo
    serve_batch stream in slot and paged modes, including int8 KV."""
    llm = LLM(arch="llama3.2-1b",
              runtime=RuntimeConfig(reduced=True, kv=kv,
                                    scheduler=SchedulerConfig(n_slots=2)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, llm.config.vocab_size, n).tolist()
               for n in (5, 13, 3)]
    outs = llm.generate(prompts, max_new_tokens=5)
    assert [o.request_id for o in outs] == [0, 1, 2]
    for out, prompt in zip(outs, prompts):
        assert out.token_ids == _solo(llm, prompt, 5), name
        assert out.finish_reason == "length"
        assert out.prompt_token_ids == list(prompt)
        assert out.ttft_s > 0 and out.latency_s > 0


def test_llm_generate_single_prompt_and_eos():
    llm = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(reduced=True))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, llm.config.vocab_size, 6).tolist()
    out, = llm.generate(prompt, max_new_tokens=4)   # flat list = one prompt
    ref = _solo(llm, prompt, 4)
    assert out.token_ids == ref
    # eos on the stream's own repeated token -> early stop + "stop" reason
    eos_llm = LLM(arch="llama3.2-1b", runtime=dataclasses.replace(
        RuntimeConfig(reduced=True), eos_token=ref[0]))
    out2, = eos_llm.generate(prompt, max_new_tokens=4)
    assert out2.finish_reason == "stop" and out2.token_ids == ref[:1]


def test_build_engine_anchors_auto_buckets_to_prompt_len():
    """The CLI path: with 'auto' buckets, build_engine's workload hints
    must anchor the ladder at the nominal prompt length (the pre-facade
    behaviour), not at cache_len."""
    from repro.api import auto_buckets

    rc = RuntimeConfig(reduced=True,
                       kv=KVConfig(cache_len=48),
                       scheduler=SchedulerConfig(prefill_buckets="auto"))
    llm = LLM(arch="llama3.2-1b", runtime=rc)
    engine = llm.build_engine(24, 16)
    assert engine.buckets == auto_buckets(24) == (8, 16, 24)
    assert engine.engine_cfg.cache_len == 48


def test_llm_engine_grows_between_calls():
    llm = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(reduced=True))
    rng = np.random.default_rng(2)
    llm.generate(rng.integers(0, llm.config.vocab_size, 4).tolist(),
                 max_new_tokens=2)
    small = llm.engine.engine_cfg.cache_len
    held = llm.metrics
    llm.generate(rng.integers(0, llm.config.vocab_size, 40).tolist(),
                 max_new_tokens=8)
    assert llm.engine.engine_cfg.cache_len > small
    # metrics accumulate across the rebuild (held references stay live)
    assert llm.metrics is held
    assert llm.metrics.prefills == 2 and len(llm.metrics.finished) == 2
    with pytest.raises(RuntimeError, match="engine not built"):
        LLM(arch="llama3.2-1b", runtime=RuntimeConfig(reduced=True)).engine


# ---------------------------------------------------------------------------
# Policies: stacked admission + threshold defrag (through the facade)
# ---------------------------------------------------------------------------

def test_batched_admission_stacks_and_matches_solo():
    """Satellite: >=2 same-bucket queued prompts admit as ONE stacked
    prefill dispatch — fewer dispatches, bitwise-identical tokens."""
    rc = RuntimeConfig(reduced=True, scheduler=SchedulerConfig(
        n_slots=4, batched_admission=True, prefill_buckets=(8, 16)))
    llm = LLM(arch="llama3.2-1b", runtime=rc)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, llm.config.vocab_size, n).tolist()
               for n in (5, 7, 12, 6)]
    outs = llm.generate(prompts, max_new_tokens=6)
    m = llm.metrics
    assert m.prefills == 4
    assert m.prefill_dispatches < m.prefills   # bucket-8 prompts stacked
    assert m.stacked_prefills >= 2
    for out, prompt in zip(outs, prompts):
        assert out.token_ids == _solo(llm, prompt, 6)


def test_batched_admission_respects_slot_limit():
    # 2 slots, 3 same-bucket prompts: the stack is capped by free lanes
    rc = RuntimeConfig(reduced=True, scheduler=SchedulerConfig(
        n_slots=2, batched_admission=True, prefill_buckets=(8,)))
    llm = LLM(arch="llama3.2-1b", runtime=rc)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, llm.config.vocab_size, 5).tolist()
               for _ in range(3)]
    outs = llm.generate(prompts, max_new_tokens=3)
    assert len(outs) == 3
    for out, prompt in zip(outs, prompts):
        assert out.token_ids == _solo(llm, prompt, 3)


def test_defrag_policy_triggers_and_is_output_invisible():
    """Satellite: the engine loop now drives PagedCache.defrag() through a
    fragmentation-threshold policy and reports defrag_count — and the
    compaction never changes tokens."""
    def run(threshold):
        rc = RuntimeConfig(
            reduced=True,
            kv=KVConfig(mode="paged", page_size=8, cache_len=32),
            scheduler=SchedulerConfig(n_slots=3, defrag_threshold=threshold))
        llm = LLM(arch="llama3.2-1b", runtime=rc)
        rng = np.random.default_rng(0)
        # short request finishes early, freeing LOW pages while later lanes
        # still hold HIGH ones -> holes -> fragmentation
        arrivals = [(0, rng.integers(0, llm.config.vocab_size, 14).tolist(), 2),
                    (0, rng.integers(0, llm.config.vocab_size, 12).tolist(), 10),
                    (1, rng.integers(0, llm.config.vocab_size, 9).tolist(), 8)]
        llm.engine.run(arrivals)
        return llm, {r.req_id: r.output_tokens for r in llm.metrics.finished}

    llm_on, toks_on = run(threshold=0.05)
    llm_off, toks_off = run(threshold=None)
    assert llm_on.metrics.defrag_count >= 1
    assert llm_on.metrics.defrag_pages_moved >= 1
    assert llm_off.metrics.defrag_count == 0
    assert toks_on == toks_off  # compaction is output-invisible


def test_threshold_defrag_unit():
    from repro.paging import PageManager

    mgr = PageManager(n_pages=9, page_size=4, n_lanes=2, max_pages_per_lane=4)
    mgr.admit(0, 8), mgr.alloc(0, 2)       # pages 1, 2
    mgr.admit(1, 8), mgr.alloc(1, 2)       # pages 3, 4
    pol = ThresholdDefrag(threshold=0.3)
    assert not pol.should_defrag(mgr)      # contiguous: frag = 0
    mgr.free_lane(0)                       # holes at 1, 2; span 4, used 2
    assert pol.should_defrag(mgr)          # frag = 0.5 > 0.3
    assert not ThresholdDefrag(threshold=0.6).should_defrag(mgr)
    mgr.defrag()
    assert not pol.should_defrag(mgr)      # compacted back to frag = 0


# ---------------------------------------------------------------------------
# Detokenization hooks / streaming text
# ---------------------------------------------------------------------------

def test_llm_stream_detokenize():
    """Satellite: Request.on_text + pluggable tokenizer surfaced as
    LLM.stream(..., detokenize=True); fragments concatenate to the full
    decode and match the token stream one-to-one here (each id maps to one
    fragment under the default detokenizer)."""
    llm = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(reduced=True))
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, llm.config.vocab_size, 6).tolist()
    toks = list(llm.stream(prompt, max_new_tokens=4))
    pieces = list(llm.stream(prompt, max_new_tokens=4, detokenize=True))
    assert toks == _solo(llm, prompt, 4)
    assert pieces == [f"<{t}>" for t in toks]

    # pluggable tokenizer: a custom decode drives both stream + outputs
    vocab_llm = LLM(arch="llama3.2-1b", runtime=RuntimeConfig(reduced=True),
                    tokenizer=lambda ids: " ".join(f"w{t}" for t in ids))
    text = "".join(vocab_llm.stream(prompt, max_new_tokens=4, detokenize=True))
    assert text == " ".join(f"w{t}" for t in toks)
    out, = vocab_llm.generate(prompt, max_new_tokens=4, detokenize=True)
    assert out.text == text and out.token_ids == toks


def test_on_text_hook_direct():
    from repro.serving.request import Request

    got = []
    req = Request(req_id=0, prompt=[1], max_new_tokens=3,
                  on_text=got.append,
                  detokenizer=lambda ids: "".join(f"[{t}]" for t in ids))
    for t in (7, 8, 9):
        req.append_token(t)
    assert got == ["[7]", "[8]", "[9]"]
    assert req.decode_text() == "[7][8][9]"


# ---------------------------------------------------------------------------
# Deprecation shims: importable and behavior-equal
# ---------------------------------------------------------------------------

def test_serve_batch_shim_from_launch():
    from repro.launch.serve import serve_batch as legacy

    assert legacy is serve_batch  # same object: behavior-equal by identity


def test_fifo_scheduler_shim():
    from repro.serving import FIFOScheduler, Request, Scheduler

    with pytest.warns(DeprecationWarning):
        sched = FIFOScheduler(n_slots=2, max_prefills_per_step=1)
    assert isinstance(sched, Scheduler)
    reqs = [Request(req_id=i, prompt=[1], max_new_tokens=1) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    # the legacy schedule() surface behaves exactly as before
    assert [(r.req_id, s) for r, s in sched.schedule()] == [(0, 0)]
    assert [(r.req_id, s) for r, s in sched.schedule()] == [(1, 1)]
    assert sched.schedule() == []


def test_engine_legacy_constructor():
    # the pre-facade 3-arg constructor (no policies) still works and still
    # produces solo-exact streams
    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, EngineConfig(n_slots=2, cache_len=32))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 5).tolist()
    metrics = engine.run([(0, prompt, 4)])
    solo, _ = serve_batch(cfg, params,
                          {"tokens": jnp.asarray([prompt], jnp.int32)},
                          cache_len=32, gen_tokens=4)
    assert metrics.finished[0].output_tokens == np.asarray(solo)[0].tolist()


def test_request_output_fields():
    from repro.serving.request import Request

    req = Request(req_id=3, prompt=[1, 2], max_new_tokens=2, eos_token=9)
    req.append_token(4), req.append_token(9)
    out = RequestOutput.from_request(req, detokenizer=lambda ids: str(list(ids)))
    assert out.finish_reason == "stop"
    assert out.text == "[4, 9]"
    assert out.token_ids == [4, 9] and out.prompt_token_ids == [1, 2]
