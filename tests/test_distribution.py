"""Distribution correctness: sharded pjit == single-device, on 8 host devices.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single default device (per the
dry-run's isolation rule).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, reduced
    from repro.configs.base import TrainConfig
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim.optimizers import adamw_init
    from repro.runtime import sharding as shard_lib

    assert jax.device_count() == 8, jax.devices()

    cfg = reduced(get_config("llama3.2-1b")).with_(n_layers=2, remat=False)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=4)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab_size)}

    # single-device reference
    step_ref = jax.jit(make_train_step(cfg, tcfg))
    p1, o1, m1 = step_ref(params, opt, batch)

    # 2 x 4 (data x model) mesh, full sharding rules
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p_specs = shard_lib.param_specs(jax.eval_shape(lambda: params), mesh, cfg,
                                    fsdp=True)
    o_specs = shard_lib.opt_state_specs(jax.eval_shape(lambda: opt), p_specs,
                                        mesh, zero1=True)
    b_specs = shard_lib.batch_specs_tree(jax.eval_shape(lambda: batch), mesh)
    with mesh:
        step_sh = jax.jit(
            make_train_step(cfg, tcfg, grad_specs=p_specs),
            in_shardings=(shard_lib.named(p_specs, mesh),
                          shard_lib.named(o_specs, mesh),
                          shard_lib.named(b_specs, mesh)),
        )
        p2, o2, m2 = step_sh(params, opt, batch)

    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)
    print("DISTRIBUTION_OK")
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "DISTRIBUTION_OK" in r.stdout


_ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
    from repro.configs import get_config, reduced
    from repro.configs.base import TrainConfig
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim.optimizers import adamw_init
    from repro.runtime import sharding as shard_lib

    cfg = reduced(get_config("llama3.2-1b")).with_(n_layers=2, remat=False)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab_size)}

    def step_on_mesh(mesh_shape, p_in, o_in):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        p_specs = shard_lib.param_specs(jax.eval_shape(lambda: params), mesh,
                                        cfg, fsdp=True)
        with mesh:
            p_in = jax.device_put(p_in, shard_lib.named(p_specs, mesh))
            fn = jax.jit(make_train_step(cfg, tcfg, grad_specs=p_specs),
                         in_shardings=(shard_lib.named(p_specs, mesh),
                                       None, None))
            return fn(p_in, o_in, batch)

    # step once on a 2 x 4 mesh, checkpoint
    p1, o1, m1 = step_on_mesh((2, 4), params, opt)
    import tempfile
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, (p1, o1))

    # "node failure": come back on 4 x 2 AND on 8 x 1, restore + step
    ref = None
    for shape in ((4, 2), (8, 1)):
        step, (pr, orr), _ = restore_checkpoint(d, 1, (p1, o1))
        p2, o2, m2 = step_on_mesh(shape, pr, orr)
        loss = float(m2["loss"])
        if ref is None:
            ref = loss
        else:
            # bf16 reduction order differs across mesh shapes
            assert abs(loss - ref) < 1e-2 * max(abs(ref), 1.0), (loss, ref)
    print("ELASTIC_OK", ref)
""")


@pytest.mark.slow
def test_elastic_restore_across_mesh_shapes():
    """Checkpoint on a 2x4 mesh; restore + continue on 4x2 and 8x1 — the
    elastic-restart path. Loss after the resumed step must agree."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ELASTIC_OK" in r.stdout
