"""The paper's central correctness claim, as an exact integer property:

bit-sliced GEMM (fused SPOGA or materialized DEAS, either slicing encoding)
== full-width INT8 GEMM with int32 accumulation, with ZERO tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional-dep gated
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.spoga import (
    deas_matmul,
    direct_matmul,
    quantized_matmul,
    spoga_matmul,
)

STRATEGIES = [spoga_matmul, deas_matmul]


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -128, 128, dtype=jnp.int8)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (3, 5, 7), (16, 64, 32), (128, 249, 16)])
@pytest.mark.parametrize("encoding", ["tc", "sm"])
@pytest.mark.parametrize("fn", STRATEGIES)
def test_bitsliced_equals_fullwidth(m, k, n, encoding, fn):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 1000 + k * 10 + n))
    x, w = _rand_int8(kx, (m, k)), _rand_int8(kw, (k, n))
    expect = direct_matmul(x, w)
    got = fn(x, w, encoding=encoding)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@given(
    st.integers(1, 24), st.integers(1, 48), st.integers(1, 24),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_bitsliced_equality_property(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x, w = _rand_int8(kx, (m, k)), _rand_int8(kw, (k, n))
    expect = np.asarray(direct_matmul(x, w))
    for enc in ("tc", "sm"):
        np.testing.assert_array_equal(np.asarray(spoga_matmul(x, w, encoding=enc)), expect)
        np.testing.assert_array_equal(np.asarray(deas_matmul(x, w, encoding=enc)), expect)


def test_extreme_values_no_overflow():
    """K=249 (paper's max vector size) of -128*-128 accumulates exactly."""
    x = jnp.full((2, 249), -128, jnp.int8)
    w = jnp.full((249, 3), -128, jnp.int8)
    expect = 249 * 128 * 128
    for fn in STRATEGIES:
        out = np.asarray(fn(x, w))
        assert (out == expect).all()


def test_batched_inputs():
    """spoga_matmul broadcasts over leading batch dims like dot_general."""
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = _rand_int8(kx, (4, 8, 16))
    w = _rand_int8(kw, (16, 12))
    got = spoga_matmul(x, w)
    expect = direct_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_quantized_matmul_dequant_accuracy():
    """W8A8 quantized matmul approximates the fp32 GEMM within quant error."""
    from repro.quant import quantize

    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (32, 64), jnp.float32)
    w = jax.random.normal(kw, (64, 48), jnp.float32)
    qx = quantize(x, axis=-1)
    qw = quantize(w, axis=0)
    exact = x @ w
    for mode in ("int8_spoga", "int8_deas", "int8_direct"):
        approx = quantized_matmul(qx.data, qw.data, qx.scale, qw.scale, mode=mode)
        rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
        assert rel < 0.02, f"{mode}: rel err {rel}"
    # all three modes agree bit-exactly with each other
    outs = [
        np.asarray(quantized_matmul(qx.data, qw.data, qx.scale, qw.scale, mode=m))
        for m in ("int8_spoga", "int8_deas", "int8_direct")
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_jit_and_grad_through_fake_quant():
    from repro.quant import fake_quant

    def loss(x):
        return jnp.sum(fake_quant(x) ** 2)

    g = jax.jit(jax.grad(loss))(jnp.linspace(-1, 1, 64))
    assert g.shape == (64,)
    assert bool(jnp.all(jnp.isfinite(g)))
