"""The observability layer (``repro.obs``): span tracer + Chrome trace
export, log-bucketed histograms, scheduler event log, the EngineMetrics
facade, and the two engine-level invariants the layer promises — zero
overhead when disabled, bitwise output-invisibility when enabled.
"""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    LLM,
    KVConfig,
    ObsConfig,
    RequestOutput,
    RuntimeConfig,
    SchedulerConfig,
    SpecConfig,
)
from repro.obs import (
    DISABLED,
    EventLog,
    Histogram,
    MetricsRegistry,
    NULL_EVENTS,
    NULL_TRACER,
    StepProfiler,
    Tracer,
)
from repro.paging.manager import PageManager
from repro.serving.metrics import EngineMetrics


# ---------------------------------------------------------------------------
# tracer: span nesting, monotonicity, Chrome export
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_monotonic_timestamps():
    tr = Tracer()
    with tr.span("outer", step=1):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b"):
            pass
    # children close (and emit) before the parent
    names = [e["name"] for e in tr.events]
    assert names == ["inner_a", "inner_b", "outer"]
    a, b, outer = tr.events
    assert a["args"]["depth"] == b["args"]["depth"] == 1
    assert outer["args"]["depth"] == 0
    assert outer["args"]["step"] == 1
    # timestamp containment is what Perfetto nests by: the parent span
    # starts before and ends after every child
    assert outer["ts"] <= a["ts"] <= a["ts"] + a["dur"]
    assert a["ts"] + a["dur"] <= b["ts"] + 1e-9 or a["ts"] <= b["ts"]
    assert b["ts"] + b["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    assert all(e["dur"] >= 0 for e in tr.events)


def test_span_set_attaches_args_after_entry():
    tr = Tracer()
    with tr.span("defrag") as sp:
        sp.set(pages_moved=3)
    assert tr.events[-1]["args"]["pages_moved"] == 3


def test_tracer_chrome_document_shape(tmp_path):
    tr = Tracer()
    tr.instant("marker", reason="test")
    with tr.span("work"):
        pass
    doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    # two metadata records lead (process/thread naming), then the events
    assert [e["ph"] for e in evs[:2]] == ["M", "M"]
    assert {e["ph"] for e in evs[2:]} == {"i", "X"}
    assert all(e["pid"] == 1 and e["tid"] == 1 for e in evs)
    # the document is valid JSON and round-trips through save()
    out = tmp_path / "trace.json"
    assert tr.save(str(out)) == str(out)
    assert json.loads(out.read_text())["traceEvents"] == json.loads(
        json.dumps(evs))


def test_span_fence_is_free_unless_enabled():
    x = jnp.ones((4,))
    tr = Tracer(fence_spans=False)
    with tr.span("decode") as sp:
        sp.fence(x)
        assert sp._fences == []  # not even retained -> no sync at exit
    tr_f = Tracer(fence_spans=True)
    with tr_f.span("decode") as sp:
        sp.fence(x)
        assert sp._fences == [x]
    assert tr_f.events[-1]["dur"] >= 0


def test_null_tracer_is_inert():
    sp1 = NULL_TRACER.span("a", x=1)
    sp2 = NULL_TRACER.span("b")
    assert sp1 is sp2  # one shared no-op span, nothing allocated
    with sp1 as sp:
        sp.fence(jnp.ones(()))
        sp.set(y=2)
    NULL_TRACER.instant("never")
    assert NULL_TRACER.events == ()
    assert NULL_TRACER.to_chrome()["traceEvents"] == []
    assert NULL_TRACER.save("/nonexistent/should-not-be-written") is None


# ---------------------------------------------------------------------------
# histograms: bucket edges, exact + bucket-interpolated percentiles
# ---------------------------------------------------------------------------

def test_histogram_bucket_edges():
    h = Histogram("lat", base=1e-6, growth=2.0, n_buckets=8)
    # bucket 0 holds everything <= base, including 0 and negatives
    assert h.bucket_index(0.0) == 0
    assert h.bucket_index(1e-6) == 0
    # an exact edge is an inclusive UPPER bound of its bucket
    for i in range(1, 7):
        assert h.bucket_index(h.edge(i)) == i
        assert h.bucket_index(h.edge(i) * 1.0001) == i + 1
    # the last bucket is open-ended
    assert h.bucket_index(1e9) == h.n_buckets - 1
    for v in (0.0, 1e-6, 3e-6, 0.5, 1e9):
        h.observe(v)
    assert sum(h.counts) == h.total == 5
    assert h.min == 0.0 and h.max == 1e9


def test_histogram_exact_percentiles_match_numpy():
    h = Histogram("lat")
    rng = np.random.default_rng(0)
    xs = rng.exponential(0.05, size=200)
    for x in xs:
        h.observe(x)
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q))
    assert h.mean == pytest.approx(float(np.mean(xs)))
    # bucket-interpolated estimate lands inside the right bucket
    p95 = h.percentile(95)
    est = h.bucket_percentile(95)
    i = h.bucket_index(p95)
    lo = 0.0 if i == 0 else h.edge(i - 1)
    assert lo <= est <= h.edge(i)


def test_histogram_empty_and_single_sample():
    h = Histogram("lat")
    assert h.percentile(99) == 0.0 and h.bucket_percentile(50) == 0.0
    assert h.mean == 0.0
    h.observe(0.25)
    assert h.percentile(1) == h.percentile(99) == 0.25


def test_registry_creates_on_first_touch_and_snapshots():
    reg = MetricsRegistry()
    reg.inc("steps")
    reg.inc("steps", 2)
    reg.set("pages", 7)
    reg.set_max("peak", 3)
    reg.set_max("peak", 2)  # running max keeps 3
    reg.observe("ttft", 0.5)
    snap = reg.snapshot()
    assert snap["counters"]["steps"] == 3
    assert snap["gauges"] == {"pages": 7, "peak": 3}
    assert snap["histograms"]["ttft"]["count"] == 1
    assert snap["histograms"]["ttft"]["p99"] == 0.5


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------

def test_event_log_timeline_and_jsonl(tmp_path):
    log = EventLog()
    log.emit("queued", req_id=1)
    log.emit("queued", req_id=2)
    log.emit("admitted", req_id=1, mode="chunked", queue_wait_s=0.01)
    log.emit("rejected", reason="page_capacity", need_pages=4, available=1)
    log.emit("finished", req_id=1, reason="length")
    assert len(log) == 5
    tl = log.timeline(1)
    assert [e["kind"] for e in tl] == ["queued", "admitted", "finished"]
    assert tl[1]["mode"] == "chunked"
    assert log.kinds() == {"queued": 2, "admitted": 1, "rejected": 1,
                           "finished": 1}
    out = tmp_path / "events.jsonl"
    assert log.to_jsonl(str(out)) == str(out)
    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(lines) == 5
    assert all("kind" in ev and "t" in ev for ev in lines)
    # events without a req_id stay out of every timeline
    assert [e["kind"] for e in log.timeline(2)] == ["queued"]


def test_null_event_log_is_inert():
    assert NULL_EVENTS.emit("queued", req_id=1) is None
    assert len(NULL_EVENTS) == 0
    assert NULL_EVENTS.timeline(1) == []
    assert NULL_EVENTS.kinds() == {}
    assert NULL_EVENTS.to_jsonl("/nonexistent/nope") is None


# ---------------------------------------------------------------------------
# ObsConfig resolution + RuntimeConfig round-trip
# ---------------------------------------------------------------------------

def test_obs_config_auto_enable_and_build():
    assert not ObsConfig().resolved_enabled
    assert ObsConfig(trace="t.json").resolved_enabled
    assert ObsConfig(events="e.jsonl").resolved_enabled
    assert ObsConfig(fence_spans=True).resolved_enabled
    assert ObsConfig(debug_invariants=True).resolved_enabled
    assert ObsConfig(enabled=True).resolved_enabled
    # explicit False wins over sink paths
    assert not ObsConfig(enabled=False, trace="t.json").resolved_enabled
    off = ObsConfig().build()
    assert not off.enabled
    assert off.tracer is NULL_TRACER and off.events is NULL_EVENTS
    assert off.save() == []
    on = ObsConfig(enabled=True).build()
    assert on.enabled and isinstance(on.events, EventLog)
    with pytest.raises(ValueError):
        ObsConfig(profile_steps=0)


def test_runtime_config_obs_roundtrip():
    rc = RuntimeConfig(obs=ObsConfig(trace="t.json", events="e.jsonl",
                                     fence_spans=True, profile_steps=5,
                                     debug_invariants=True))
    blob = json.dumps(rc.to_dict())
    assert RuntimeConfig.from_dict(json.loads(blob)) == rc
    # obs defaults survive configs serialized before the field existed
    assert RuntimeConfig.from_dict({"max_new_tokens": 4}).obs == ObsConfig()


# ---------------------------------------------------------------------------
# jax.profiler hook
# ---------------------------------------------------------------------------

def test_step_profiler_wraps_n_steps(tmp_path):
    prof = StepProfiler(str(tmp_path), n_steps=1)
    prof.step_begin()
    jnp.ones((4,)).sum().block_until_ready()
    prof.step_end()  # n_steps reached -> trace stopped here
    prof.close()
    prof.close()  # idempotent
    assert any(tmp_path.rglob("*")), "profiler wrote nothing"


# ---------------------------------------------------------------------------
# EngineMetrics facade: empty-run wall clock, deprecation shim
# ---------------------------------------------------------------------------

def test_engine_metrics_empty_run_reports_cleanly():
    m = EngineMetrics()
    assert m.wall_s == 0.0  # never begun -> no phantom wall clock
    r = m.report()
    assert r["requests"] == 0 and r["tokens_per_s"] == 0.0
    assert r["ttft_p99_s"] == 0.0 and r["accept_len_p50"] == 0.0
    m.begin()
    start = m.start_time
    m.begin()  # idempotent: the stamp does not move
    assert m.start_time == start
    m.touch()
    assert 0 < m.wall_s < 10.0
    assert m.end_time >= start


def test_engine_metrics_deprecation_shim():
    m = EngineMetrics()
    with pytest.warns(DeprecationWarning):
        m.prefills = 5
    assert m.prefills == 5  # the poke still lands (compat), just noisily
    m.inc("prefills")
    assert m.prefills == 6
    # reads and the blessed emission API never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _ = m.prefills
        m.inc("decode_steps")
        m.set_gauge("pages_total", 9)
        m.max_gauge("peak_running", 2)
        m.observe("accept_len", 3)
    assert m.report()["accept_len_p50"] == 3.0
    with pytest.raises(AttributeError):
        _ = m.not_a_metric


# ---------------------------------------------------------------------------
# page-pool invariants: collecting + raising surfaces
# ---------------------------------------------------------------------------

def test_page_manager_invariant_violations_collects_all():
    pm = PageManager(n_pages=8, page_size=4, n_lanes=2, max_pages_per_lane=4)
    assert pm.invariant_violations() == []
    pm.check_invariants()  # healthy pool passes the raising form too
    pm.refcount[1] = 1  # page 1 is still on the free list -> two violations
    bad = pm.invariant_violations()
    assert any("refcount mismatch" in msg for msg in bad)
    assert any("both free and referenced" in msg for msg in bad)
    with pytest.raises(AssertionError):
        pm.check_invariants()


# ---------------------------------------------------------------------------
# engine-level: disabled no-op, timeline completeness, bitwise parity
# ---------------------------------------------------------------------------

def _serve(obs_cfg, tmp_path=None):
    """One paged + chunked + prefix + spec serve (every event source hot)."""
    runtime = RuntimeConfig(
        reduced=True,
        kv=KVConfig(mode="paged", page_size=8, prefix_cache=True),
        scheduler=SchedulerConfig(n_slots=2, prefill_chunk=8),
        spec=SpecConfig(enabled=True, k=2, drafter="ngram"),
        obs=obs_cfg,
    )
    llm = LLM(arch="llama3.2-1b", runtime=runtime)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, llm.config.vocab_size, 16).tolist()
    prompts = [shared + rng.integers(0, llm.config.vocab_size, n).tolist()
               for n in (5, 9, 3)]
    outs = llm.generate(prompts, max_new_tokens=6)
    return llm, outs


def test_disabled_obs_is_noop_and_enabled_is_output_invisible():
    llm_off, outs_off = _serve(ObsConfig())
    # disabled: null sinks saw nothing, outputs carry no timeline
    assert not llm_off.obs.enabled
    assert llm_off.obs.tracer is NULL_TRACER
    assert len(llm_off.obs.events) == 0
    assert all(o.timeline is None and o.queue_wait_s is None
               for o in outs_off)

    # enabled, with the most invasive settings (fenced spans + per-step
    # invariant checking): greedy token streams must stay bitwise equal
    llm_on, outs_on = _serve(ObsConfig(fence_spans=True,
                                       debug_invariants=True))
    assert [o.token_ids for o in outs_on] == [o.token_ids for o in outs_off]

    # spans were recorded for the dispatch kinds this workload exercises
    names = {e["name"] for e in llm_on.obs.tracer.events}
    assert {"step", "chunk"} <= names
    assert names & {"decode", "verify"}
    # every span is a well-formed complete event with monotone bounds
    for e in llm_on.obs.tracer.events:
        assert e["ph"] == "X" and e["dur"] >= 0 and "depth" in e["args"]

    # per-request timelines: queued -> admitted -> ... -> first_token ->
    # finished, in order, with reasons/wait attached
    ids = {o.request_id for o in outs_on}
    for out in outs_on:
        kinds = [e["kind"] for e in out.timeline]
        assert kinds[0] == "queued" and kinds[-1] == "finished"
        assert kinds.index("queued") < kinds.index("admitted")
        assert kinds.index("admitted") < kinds.index("first_token")
        admitted = next(e for e in out.timeline if e["kind"] == "admitted")
        assert admitted["mode"] in ("chunked", "prefix")
        assert admitted["queue_wait_s"] >= 0
        assert out.queue_wait_s == admitted["queue_wait_s"]
        finished = next(e for e in out.timeline if e["kind"] == "finished")
        assert finished["reason"] in ("eos", "length")
        assert all(e["req_id"] in ids for e in out.timeline)
    # the shared prefix makes later requests prefix-admissions, and the
    # 21-token prompts overflow the 8-token chunk -> chunk events exist
    modes = {next(e for e in o.timeline if e["kind"] == "admitted")["mode"]
             for o in outs_on}
    assert "prefix" in modes
    assert any(e["kind"] == "chunk" for o in outs_on for e in o.timeline)

    # the speculative path ran and its metrics carry percentile keys
    rep = llm_on.metrics.report()
    assert rep["verify_dispatches"] >= 1
    assert rep["ttft_p99_s"] >= rep["ttft_p50_s"] >= 0
    assert "accept_len_p99" in rep and "queue_wait_p99_s" in rep


def test_obs_save_writes_configured_sinks(tmp_path):
    trace = tmp_path / "trace.json"
    events = tmp_path / "events.jsonl"
    llm, outs = _serve(ObsConfig(trace=str(trace), events=str(events)))
    assert len(outs) == 3
    written = llm.obs.save()
    assert set(written) == {str(trace), str(events)}
    doc = json.loads(trace.read_text())
    assert doc["traceEvents"] and doc["traceEvents"][0]["ph"] == "M"
    lines = [json.loads(l) for l in events.read_text().splitlines()]
    kinds = {ev["kind"] for ev in lines}
    assert {"queued", "admitted", "first_token", "finished"} <= kinds


def test_request_output_queue_wait_reads_timeline():
    out = RequestOutput(request_id=0, prompt_token_ids=[1], token_ids=[2],
                        text=None, finish_reason="length", ttft_s=0.1,
                        latency_s=0.2,
                        timeline=[{"kind": "queued", "req_id": 0},
                                  {"kind": "admitted", "req_id": 0,
                                   "queue_wait_s": 0.05}])
    assert out.queue_wait_s == 0.05
    assert RequestOutput(request_id=1, prompt_token_ids=[], token_ids=[],
                         text=None, finish_reason="length", ttft_s=None,
                         latency_s=None).queue_wait_s is None
