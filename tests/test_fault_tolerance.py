"""Fault tolerance: restartable loop, bit-exact resume, stragglers, elastic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline
from repro.runtime.fault_tolerance import (
    ElasticPlan,
    StragglerMonitor,
    WorkerFailure,
    run_with_restart,
)


class TestStragglerMonitor:
    def test_flags_slow_host(self):
        mon = StragglerMonitor(n_hosts=4, threshold=2.0)
        for _ in range(10):
            for h in range(3):
                mon.record(h, 1.0)
            mon.record(3, 5.0)
        assert mon.stragglers() == [3]

    def test_no_false_positives(self):
        mon = StragglerMonitor(n_hosts=4)
        for _ in range(10):
            for h in range(4):
                mon.record(h, 1.0 + 0.05 * h)
        assert mon.stragglers() == []

    def test_transient_spike_decays(self):
        mon = StragglerMonitor(n_hosts=2, threshold=2.0, alpha=0.5)
        mon.record(0, 1.0)
        mon.record(1, 1.0)
        mon.record(1, 10.0)      # one spike
        for _ in range(12):
            mon.record(0, 1.0)
            mon.record(1, 1.0)   # back to normal
        assert mon.stragglers() == []


class TestElasticPlan:
    def test_full_world(self):
        assert ElasticPlan.plan(256, 16) == ElasticPlan(dp=16, model=16)

    def test_lost_nodes_keeps_tp(self):
        assert ElasticPlan.plan(240, 16) == ElasticPlan(dp=15, model=16)

    def test_degrades_tp_when_tiny(self):
        p = ElasticPlan.plan(6, 16)
        assert p.dp * p.model == 6


class TestRestart:
    def _setup(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=3)

        def make_state():
            return 0, jnp.zeros((4,), jnp.float32)

        return mgr, make_state

    def test_restart_recovers_and_completes(self, tmp_path):
        mgr, make_state = self._setup(tmp_path)
        fail_at = {7}

        def step_fn(step, state):
            if step in fail_at:
                fail_at.clear()        # fail once
                raise WorkerFailure(f"injected at {step}")
            return state + 1.0

        (step, state), restarts = run_with_restart(
            make_state, step_fn, mgr, n_steps=12, checkpoint_every=3)
        assert restarts == 1
        assert step == 12
        # every step applied exactly once despite the restart
        np.testing.assert_allclose(np.asarray(state), np.full(4, 12.0))

    def test_gives_up_after_max_failures(self, tmp_path):
        mgr, make_state = self._setup(tmp_path)

        def always_fail(step, state):
            raise WorkerFailure("permanent")

        with pytest.raises(WorkerFailure):
            run_with_restart(make_state, always_fail, mgr, n_steps=5,
                             checkpoint_every=2, max_failures=2)


def test_pipeline_restart_bit_exact():
    """The stateless pipeline regenerates the identical stream after a
    simulated restart — the property that makes resume bit-exact."""
    p1 = SyntheticTokenPipeline(vocab_size=512, seq_len=32, global_batch=4, seed=3)
    ref = [np.asarray(p1.global_batch_at(i)) for i in range(6)]
    p2 = SyntheticTokenPipeline(vocab_size=512, seq_len=32, global_batch=4, seed=3)
    for i in (3, 4, 5):  # resume mid-stream
        np.testing.assert_array_equal(np.asarray(p2.global_batch_at(i)), ref[i])


def test_training_resume_bit_exact(tmp_path):
    """Train 6 steps straight vs train 3 + checkpoint + restore + 3: the
    final parameters must be bit-identical (deterministic pipeline + jit)."""
    from repro.configs import get_config, reduced
    from repro.configs.base import TrainConfig
    from repro.launch.train import train_loop

    cfg = reduced(get_config("llama3.2-1b")).with_(n_layers=2, remat=False)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=6)

    params_a, _ = train_loop(cfg, tcfg, steps=6, batch=2, seq=32,
                             ckpt_dir=None, log_every=100)
    ckpt = str(tmp_path / "ckpt")
    train_loop(cfg, tcfg, steps=3, batch=2, seq=32, ckpt_dir=ckpt,
               checkpoint_every=3, log_every=100)
    params_b, _ = train_loop(cfg, tcfg, steps=6, batch=2, seq=32,
                             ckpt_dir=ckpt, checkpoint_every=100, log_every=100)

    for a, b in zip(jax.tree_util.tree_leaves(params_a),
                    jax.tree_util.tree_leaves(params_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
