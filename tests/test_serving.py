"""Serving consistency: prefill + stepwise decode == full-context forward.

The strongest functional check of the KV-cache / recurrent-state machinery:
for every cache-bearing architecture family, decoding token t against the
cache must produce the same logits as a full forward pass over [0..t].
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import decode_step, forward, init_params, prefill

# One representative per cache mechanism:
#   GQA dense, MLA latents, MoE, mLSTM/sLSTM state, RG-LRU + local ring,
#   enc-dec cross-attention.
ARCHS = [
    "llama3.2-1b",
    "minicpm3-4b",
    "granite-moe-3b-a800m",
    "xlstm-125m",
    "recurrentgemma-9b",
    "seamless-m4t-large-v2",
]

S_PROMPT, S_GEN, BATCH = 12, 4, 2


def _inputs(cfg, key, s):
    kt, ke = jax.random.split(key)
    if cfg.is_encoder_decoder:
        return {
            "src_embeds": jax.random.normal(ke, (BATCH, 8, cfg.d_model), jnp.float32) * 0.02,
            "tgt_tokens": jax.random.randint(kt, (BATCH, s), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(kt, (BATCH, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch)).with_(remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, jax.random.fold_in(key, 1))

    total = S_PROMPT + S_GEN
    full_batch = _inputs(cfg, jax.random.fold_in(key, 2), total)
    tok_key = "tgt_tokens" if cfg.is_encoder_decoder else "tokens"
    all_tokens = full_batch[tok_key]

    # reference: full-context forward logits at each position
    ref_logits = forward(params, cfg, full_batch)

    # prefill on the prompt, then decode the remaining tokens one by one
    pre_batch = dict(full_batch)
    pre_batch[tok_key] = all_tokens[:, :S_PROMPT]
    logits, cache = prefill(params, cfg, pre_batch, cache_len=total)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(ref_logits[:, S_PROMPT - 1, :], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    for t in range(S_PROMPT, total):
        logits, cache = decode_step(params, cfg, all_tokens[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, t, :], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode position {t}",
        )


def test_serve_batch_driver_runs():
    from repro.launch.serve import serve_batch

    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)}
    out, timings = serve_batch(cfg, params, batch, cache_len=16, gen_tokens=5)
    assert out.shape == (2, 5)
    assert timings["prefill_s"] > 0 and timings["decode_s"] > 0
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()


def test_int8_kv_cache_close_to_bf16():
    """SPOGA-style byte-size KV cache: decode logits match the bf16-cache
    path within quantization error (beyond-paper feature)."""
    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    outs = {}
    for kv in ("bf16", "int8"):
        c = cfg.with_(kv_cache_dtype=kv)
        logits, cache = prefill(params, c, batch, cache_len=16)
        for t in range(3):
            logits, cache = decode_step(
                params, c, jnp.full((2,), 7, jnp.int32), cache)
        outs[kv] = np.asarray(logits, np.float32)
    ref = outs["bf16"]
    scale = np.abs(ref).max()
    np.testing.assert_allclose(outs["int8"], ref, atol=0.08 * scale)
    # argmax (greedy token) should agree for nearly all positions
    assert (outs["int8"].argmax(-1) == ref.argmax(-1)).mean() >= 0.95
