"""Serving consistency: prefill + stepwise decode == full-context forward,
and the continuous-batching engine == solo decoding of each request.

The strongest functional check of the KV-cache / recurrent-state machinery:
for every cache-bearing architecture family, decoding token t against the
cache must produce the same logits as a full forward pass over [0..t].
The engine tests extend that to slot scattering, padded prefill buckets,
staggered admission and slot reuse: scheduling must be output-invisible.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import decode_step, forward, init_params, prefill
from repro.serving import (
    EngineConfig,
    FIFOScheduler,
    Request,
    SamplingParams,
    ServingEngine,
    SlotCache,
    sample_tokens,
)

# One representative per cache mechanism:
#   GQA dense, MLA latents, MoE, mLSTM/sLSTM state, RG-LRU + local ring,
#   enc-dec cross-attention.
ARCHS = [
    "llama3.2-1b",
    "minicpm3-4b",
    "granite-moe-3b-a800m",
    "xlstm-125m",
    "recurrentgemma-9b",
    "seamless-m4t-large-v2",
]

S_PROMPT, S_GEN, BATCH = 12, 4, 2


def _inputs(cfg, key, s):
    kt, ke = jax.random.split(key)
    if cfg.is_encoder_decoder:
        return {
            "src_embeds": jax.random.normal(ke, (BATCH, 8, cfg.d_model), jnp.float32) * 0.02,
            "tgt_tokens": jax.random.randint(kt, (BATCH, s), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(kt, (BATCH, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch)).with_(remat=False)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, jax.random.fold_in(key, 1))

    total = S_PROMPT + S_GEN
    full_batch = _inputs(cfg, jax.random.fold_in(key, 2), total)
    tok_key = "tgt_tokens" if cfg.is_encoder_decoder else "tokens"
    all_tokens = full_batch[tok_key]

    # reference: full-context forward logits at each position
    ref_logits = forward(params, cfg, full_batch)

    # prefill on the prompt, then decode the remaining tokens one by one
    pre_batch = dict(full_batch)
    pre_batch[tok_key] = all_tokens[:, :S_PROMPT]
    logits, cache = prefill(params, cfg, pre_batch, cache_len=total)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(ref_logits[:, S_PROMPT - 1, :], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    for t in range(S_PROMPT, total):
        logits, cache = decode_step(params, cfg, all_tokens[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, t, :], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode position {t}",
        )


def test_serve_batch_driver_runs():
    from repro.launch.serve import serve_batch

    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)}
    out, timings = serve_batch(cfg, params, batch, cache_len=16, gen_tokens=5)
    assert out.shape == (2, 5)
    assert timings["prefill_s"] > 0 and timings["decode_s"] > 0
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

# one attention-family arch exercising padded prefill buckets (MLA has the
# most intricate cache) + one recurrent arch on the exact-length path; both
# produce varied greedy continuations at smoke scale (llama's random init
# collapses to a repeated token, which would mask pos-bookkeeping bugs).
ENGINE_CASES = [
    ("llama3.2-1b", (8, 16)),
    ("minicpm3-4b", (8, 16)),
    ("xlstm-125m", None),
]


def _engine_setup(arch, buckets, n_slots=2, cache_len=32, ecfg_kw=None, **cfg_kw):
    cfg = reduced(get_config(arch)).with_(remat=False, **cfg_kw)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(n_slots=n_slots, cache_len=cache_len,
                        prefill_buckets=buckets, **(ecfg_kw or {}))
    return cfg, params, ServingEngine(cfg, params, ecfg)


@pytest.mark.parametrize("arch,buckets", ENGINE_CASES)
def test_engine_matches_solo_staggered(arch, buckets):
    """Acceptance: unequal-length requests arriving staggered, with more
    requests than slots (queueing + eviction + slot reuse), each produce
    EXACTLY the greedy tokens of a solo serve_batch run of that request."""
    from repro.launch.serve import serve_batch

    cfg, params, engine = _engine_setup(arch, buckets)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (5, 9, 3, 7)]
    gens = [6, 4, 8, 5]
    arrivals = [(0, prompts[0], gens[0]), (0, prompts[1], gens[1]),
                (2, prompts[2], gens[2]), (4, prompts[3], gens[3])]
    metrics = engine.run(arrivals)

    assert len(metrics.finished) == 4
    by_id = {r.req_id: r for r in metrics.finished}
    for i, (p, g) in enumerate(zip(prompts, gens)):
        solo, _ = serve_batch(cfg, params,
                              {"tokens": jnp.asarray([p], jnp.int32)},
                              cache_len=engine.engine_cfg.cache_len,
                              gen_tokens=g)
        assert by_id[i].output_tokens == np.asarray(solo)[0].tolist(), (
            f"{arch}: request {i} diverged from its solo decode")
    rep = metrics.report()
    assert rep["generated_tokens"] == sum(gens)
    assert rep["prefills"] == 4
    assert rep["ttft_mean_s"] > 0 and rep["latency_mean_s"] > 0


def test_engine_int8_kv_parity():
    """Satellite: greedy decode through the engine with the byte-size int8
    KV cache tracks the bf16 cache within quantization tolerance.  Token
    streams feed back into the model, so one early flip cascades — require
    exact first tokens (pure prefill logits) and high overall agreement."""
    outs = {}
    for kv in ("bf16", "int8"):
        cfg, params, engine = _engine_setup("minicpm3-4b", None,
                                            kv_cache_dtype=kv)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (6, 9, 4)]
        metrics = engine.run([(0, prompts[0], 5), (1, prompts[1], 5),
                              (2, prompts[2], 5)])
        outs[kv] = {r.req_id: r.output_tokens for r in metrics.finished}
    agree = 0
    total = 0
    for rid, ref in outs["bf16"].items():
        assert outs["int8"][rid][0] == ref[0], "first token must match"
        agree += sum(a == b for a, b in zip(outs["int8"][rid], ref))
        total += len(ref)
    assert agree / total >= 0.8, f"int8 KV agreement {agree}/{total}"


# ---------------------------------------------------------------------------
# Paged cache mode (repro/paging/)
# ---------------------------------------------------------------------------

# every cache mechanism the paged engine serves: GQA pools, MLA latent
# pools, MoE (attn pools + routed FFN), recurrent per-lane state (the
# degenerate paged case: no pools, block tables unused), and the hybrid
# rglru + local-attn ring (rings stay per-lane inside the paged tree)
PAGED_ARCHS = ["llama3.2-1b", "minicpm3-4b", "granite-moe-3b-a800m",
               "xlstm-125m", "recurrentgemma-9b"]
RECURRENT_ARCHS = {"xlstm-125m", "recurrentgemma-9b"}


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_engine_paged_matches_solo(arch):
    """Acceptance: the paged engine is output-invisible — staggered
    mixed-length requests (several spanning multiple pages), more requests
    than lanes, exact greedy match vs solo serve_batch.  page_size=8 with
    cache_len=32 makes the gathered view the slot shape, so the match is
    bitwise, not approximate."""
    from repro.launch.serve import serve_batch

    buckets = (8, 16) if arch not in RECURRENT_ARCHS else None
    cfg, params, engine = _engine_setup(
        arch, buckets, ecfg_kw=dict(cache_mode="paged", page_size=8))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (5, 13, 3, 17)]
    gens = [6, 4, 8, 5]
    arrivals = [(0, prompts[0], gens[0]), (0, prompts[1], gens[1]),
                (2, prompts[2], gens[2]), (4, prompts[3], gens[3])]
    metrics = engine.run(arrivals)

    assert len(metrics.finished) == 4
    by_id = {r.req_id: r for r in metrics.finished}
    for i, (p, g) in enumerate(zip(prompts, gens)):
        solo, _ = serve_batch(cfg, params,
                              {"tokens": jnp.asarray([p], jnp.int32)},
                              cache_len=engine.engine_cfg.cache_len,
                              gen_tokens=g)
        assert by_id[i].output_tokens == np.asarray(solo)[0].tolist(), (
            f"{arch}: paged request {i} diverged from its solo decode")
    # eviction returned every page to the pool the same run
    assert engine.store.manager.pages_in_use == 0 if engine._has_paged_kinds else True


@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b"])
def test_engine_chunked_prefill_matches_solo(arch):
    """Chunked admission (prompts spanning several page-sized chunks,
    interleaved with running decodes) produces exactly the solo stream."""
    from repro.launch.serve import serve_batch

    cfg, params, engine = _engine_setup(
        arch, None, ecfg_kw=dict(cache_mode="paged", page_size=8,
                                 prefill_chunk=8))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (13, 21, 3, 17)]
    gens = [5, 4, 6, 5]
    arrivals = [(0, prompts[0], gens[0]), (0, prompts[1], gens[1]),
                (2, prompts[2], gens[2]), (4, prompts[3], gens[3])]
    metrics = engine.run(arrivals)

    assert metrics.chunk_steps >= 6  # 13 -> 2 chunks, 21 -> 3, 17 -> 3
    by_id = {r.req_id: r for r in metrics.finished}
    for i, (p, g) in enumerate(zip(prompts, gens)):
        solo, _ = serve_batch(cfg, params,
                              {"tokens": jnp.asarray([p], jnp.int32)},
                              cache_len=engine.engine_cfg.cache_len,
                              gen_tokens=g)
        assert by_id[i].output_tokens == np.asarray(solo)[0].tolist(), (
            f"{arch}: chunked request {i} diverged from its solo decode")


def test_engine_paged_admissions_serialize_on_capacity():
    """Two requests that each fit but cannot fit TOGETHER must admit one
    after the other (reservation taken before the next capacity gate), not
    crash mid-step on an overcommitted pool."""
    cfg, params, engine = _engine_setup(
        "llama3.2-1b", None,
        ecfg_kw=dict(cache_mode="paged", page_size=8, n_pages=6,
                     max_prefills_per_step=2))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 16).tolist() for _ in range(2)]
    metrics = engine.run([(0, prompts[0], 8), (0, prompts[1], 8)])
    assert len(metrics.finished) == 2
    assert metrics.peak_running == 1      # 3+3 pages never fit 5 at once
    assert engine.store.manager.pages_in_use == 0


def test_engine_paged_int8_matches_slot_int8():
    """int8 byte-size pages quantize exactly like the int8 slot cache, so
    the two modes' greedy streams are identical (not merely close)."""
    outs = {}
    for mode in ("slot", "paged"):
        cfg, params, engine = _engine_setup(
            "llama3.2-1b", None, kv_cache_dtype="int8",
            ecfg_kw=dict(cache_mode=mode, page_size=8))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (6, 11, 4)]
        metrics = engine.run([(0, prompts[0], 5), (1, prompts[1], 5),
                              (2, prompts[2], 5)])
        outs[mode] = {r.req_id: r.output_tokens for r in metrics.finished}
    assert outs["paged"] == outs["slot"]


def test_engine_paged_decode_traced_once():
    """Acceptance: growth, admission, eviction and table refreshes never
    retrace the decode step (fixed shapes end to end)."""
    cfg, params, engine = _engine_setup(
        "llama3.2-1b", None, ecfg_kw=dict(cache_mode="paged", page_size=8,
                                          prefill_chunk=8))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (13, 5, 17, 4)]
    engine.run([(0, prompts[0], 6), (1, prompts[1], 4)])
    n_traces = engine._decode_sample._cache_size()
    assert n_traces >= 1
    engine.run([(0, prompts[2], 8), (0, prompts[3], 3)])
    assert engine._decode_sample._cache_size() == n_traces, (
        "decode step retraced mid-serve")


def test_free_lane_pos_stays_pinned():
    """Satellite: freed lanes' pos is reset inside the jitted step and no
    longer drifts upward on garbage decode tokens."""
    cfg, params, engine = _engine_setup("llama3.2-1b", None, n_slots=2)
    rng = np.random.default_rng(0)
    short = rng.integers(0, cfg.vocab_size, 4).tolist()
    long = rng.integers(0, cfg.vocab_size, 4).tolist()
    engine.run([(0, short, 2), (0, long, 12)])
    # lane 0 (short request) evicted many steps before lane 1 finished;
    # without the active mask its pos would have kept advancing
    assert engine.store.pos.tolist()[0] == 0


def test_engine_streaming_hooks():
    """Satellite: on_token callback fires for every token (in order), and
    the generator API yields the same stream the request records."""
    cfg, params, engine = _engine_setup("minicpm3-4b", None, n_slots=2)
    rng = np.random.default_rng(3)
    seen = []
    req = engine.add_request(rng.integers(0, cfg.vocab_size, 6).tolist(), 5,
                             on_token=seen.append)
    while engine.has_work:
        engine.step()
    assert seen == req.output_tokens and len(seen) == 5

    cfg, params, engine = _engine_setup("minicpm3-4b", None, n_slots=2)
    toks = list(engine.stream(rng.integers(0, cfg.vocab_size, 6).tolist(), 4))
    assert len(toks) == 4
    assert toks == engine.metrics.finished[0].output_tokens


def test_engine_rejects_bad_paged_configs():
    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="cache_mode"):
        ServingEngine(cfg, params, EngineConfig(cache_mode="virtual"))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, EngineConfig(cache_mode="slot", prefill_chunk=8))
    with pytest.raises(ValueError, match="multiple of"):
        ServingEngine(cfg, params, EngineConfig(cache_mode="paged", page_size=8,
                                                prefill_chunk=12))
    # a request whose worst-case reservation can never fit the pool must
    # fail fast, not stall the admission gate forever
    tiny = ServingEngine(cfg, params, EngineConfig(
        n_slots=2, cache_len=32, cache_mode="paged", page_size=8, n_pages=3))
    with pytest.raises(ValueError, match="pages"):
        tiny.add_request(list(range(1, 17)), max_new_tokens=8)  # needs 3 pages, has 2
    # MoE capacity depends on how many tokens share a dispatch -> unchunkable
    moe_cfg = reduced(get_config("granite-moe-3b-a800m")).with_(remat=False)
    moe_params = init_params(moe_cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chunk"):
        ServingEngine(moe_cfg, moe_params,
                      EngineConfig(cache_mode="paged", page_size=8,
                                   prefill_chunk=8))


def test_engine_rejects_bad_configs():
    cfg = reduced(get_config("xlstm-125m")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent|state integrates"):
        ServingEngine(cfg, params,
                      EngineConfig(n_slots=2, cache_len=32, prefill_buckets=(8,)))
    _, _, engine = _engine_setup("llama3.2-1b", None, cache_len=16)
    with pytest.raises(ValueError, match="cache_len"):
        engine.add_request(list(range(1, 14)), max_new_tokens=8)


# ---------------------------------------------------------------------------
# Engine components (host-side units)
# ---------------------------------------------------------------------------

def test_fifo_scheduler_slots_and_queueing():
    sched = FIFOScheduler(n_slots=2, max_prefills_per_step=1)
    reqs = [Request(req_id=i, prompt=[1], max_new_tokens=1) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    # one admission per step (interleave policy), lowest slot first
    assert [(r.req_id, s) for r, s in sched.schedule()] == [(0, 0)]
    assert [(r.req_id, s) for r, s in sched.schedule()] == [(1, 1)]
    assert sched.schedule() == []  # pool full, 2 still waiting
    assert sched.free_slots == 0 and len(sched.waiting) == 2
    done = sched.release(0)
    assert done.req_id == 0 and done.slot is None
    # freed slot is immediately reusable, FIFO order preserved
    assert [(r.req_id, s) for r, s in sched.schedule()] == [(2, 0)]
    sched.release(1)
    assert [(r.req_id, s) for r, s in sched.schedule()] == [(3, 1)]
    sched.release(0), sched.release(1)
    assert not sched.has_work and sched.free_slots == 2


def test_sample_tokens_policies():
    logits = jnp.asarray([[0.0, 1.0, 3.0, 2.0]] * 3, jnp.float32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3))
    # greedy lanes: argmax regardless of key/temperature
    toks = sample_tokens(logits, jnp.ones((3,)), jnp.zeros((3,), jnp.int32),
                         jnp.ones((3,), bool), keys)
    assert toks.tolist() == [2, 2, 2]
    # top_k=1 equals greedy even when stochastic
    toks = sample_tokens(logits, jnp.full((3,), 5.0),
                         jnp.ones((3,), jnp.int32), jnp.zeros((3,), bool), keys)
    assert toks.tolist() == [2, 2, 2]
    # top_k=2 at high temperature only ever emits the top-2 set {2, 3}
    seen = set()
    for s in range(20):
        ks = jax.vmap(jax.random.PRNGKey)(jnp.arange(3) + 100 * s)
        toks = sample_tokens(logits, jnp.full((3,), 10.0),
                             jnp.full((3,), 2, jnp.int32),
                             jnp.zeros((3,), bool), ks)
        seen |= set(toks.tolist())
    assert seen == {2, 3}


def test_slot_cache_insert_free_roundtrip():
    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pool = SlotCache(cfg, n_slots=3, cache_len=16)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                                          cfg.vocab_size)}
    _, single = prefill(params, cfg, batch, cache_len=16)
    pool.insert(single, 1)
    assert pool.pos.tolist() == [0, 6, 0]
    # the lane's stacked-block K rows equal the batch=1 prefill cache ...
    k_pool = np.asarray(pool.cache["blocks"][0]["k"][:, 1])
    k_one = np.asarray(single["blocks"][0]["k"][:, 0])
    np.testing.assert_array_equal(k_pool, k_one)
    # ... and the other lanes stay zero
    assert not np.asarray(pool.cache["blocks"][0]["k"][:, 0]).any()
    pool.free(1)
    assert pool.pos.tolist() == [0, 0, 0]


def test_int8_kv_cache_close_to_bf16():
    """SPOGA-style byte-size KV cache: decode logits match the bf16-cache
    path within quantization error (beyond-paper feature)."""
    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    outs = {}
    for kv in ("bf16", "int8"):
        c = cfg.with_(kv_cache_dtype=kv)
        logits, cache = prefill(params, c, batch, cache_len=16)
        for t in range(3):
            logits, cache = decode_step(
                params, c, jnp.full((2,), 7, jnp.int32), cache)
        outs[kv] = np.asarray(logits, np.float32)
    ref = outs["bf16"]
    scale = np.abs(ref).max()
    np.testing.assert_allclose(outs["int8"], ref, atol=0.08 * scale)
    # argmax (greedy token) should agree for nearly all positions
    assert (outs["int8"].argmax(-1) == ref.argmax(-1)).mean() >= 0.95


# -- chunked prefill for recurrent stacks (repro/paging/prefill.py) ---------

def _zero_cell_state(kind, cfg, p, b=1):
    d, hh = cfg.d_model, cfg.n_heads
    dh = d // hh
    f32 = jnp.float32
    if kind == "rglru":
        lru = p["conv_w"].shape[-1]
        return {"h": jnp.zeros((b, lru), f32),
                "conv": jnp.zeros((b, cfg.conv_width - 1, lru), f32)}
    if kind == "mlstm":
        return {"C": jnp.zeros((b, hh, dh, dh), f32),
                "n": jnp.zeros((b, hh, dh), f32)}
    return {"c": jnp.zeros((b, hh, dh), f32),
            "n": jnp.zeros((b, hh, dh), f32),
            "h": jnp.zeros((b, hh, dh), f32)}


@pytest.mark.parametrize("kind,arch", [
    ("rglru", "recurrentgemma-9b"),
    ("mlstm", "xlstm-125m"),
    ("slstm", "xlstm-125m"),
])
def test_recurrent_chunk_cells_match_block(kind, arch):
    """Unit contract for the state-carrying chunk cells: running a sequence
    through ``*_chunk`` in pieces (with a ragged, padded final chunk)
    matches the one-shot ``*_block`` on the valid prefix.  sLSTM is
    bitwise (identical sequential op order under the carry freeze);
    RG-LRU / mLSTM regroup their scans at chunk boundaries -> allclose."""
    from repro.models import recurrent as rec

    cfg = reduced(get_config(arch)).with_(remat=False)
    init = {"rglru": rec.init_rglru, "mlstm": rec.init_mlstm,
            "slstm": rec.init_slstm}[kind]
    block = {"rglru": rec.rglru_block, "mlstm": rec.mlstm_block,
             "slstm": rec.slstm_block}[kind]
    chunk = {"rglru": rec.rglru_chunk, "mlstm": rec.mlstm_chunk,
             "slstm": rec.slstm_chunk}[kind]
    p = init(jax.random.PRNGKey(0), cfg)
    s_valid, c_len = 21, 8  # 3 chunks, last one ragged (5 valid + 3 pad)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, s_valid, cfg.d_model),
                          jnp.bfloat16)
    ref, _ = block(x, p, cfg, None)

    state = _zero_cell_state(kind, cfg, p)
    outs = []
    for start in range(0, s_valid, c_len):
        n_valid = min(c_len, s_valid - start)
        xc = jnp.zeros((1, c_len, cfg.d_model), x.dtype)
        xc = xc.at[:, :n_valid].set(x[:, start:start + n_valid])
        o, state = chunk(xc, p, cfg, state, jnp.int32(n_valid))
        outs.append(np.asarray(o[:, :n_valid], np.float32))
    got = np.concatenate(outs, axis=1)
    ref = np.asarray(ref, np.float32)
    scale = max(np.abs(ref).max(), 1e-6)
    np.testing.assert_allclose(got, ref, atol=2e-2 * scale, rtol=0)


def test_engine_chunked_xlstm_matches_unchunked():
    """Satellite acceptance: an xLSTM stack admits long prompts in chunks
    (state carried across chunk boundaries, ragged lengths, lane reuse
    zeroing a freed lane's stale cell state) and produces the same greedy
    tokens as one-shot exact-length admission."""
    cfg = reduced(get_config("xlstm-125m")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # lengths straddle chunk multiples; > n_slots requests force lane reuse
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (13, 21, 8, 17)]
    outs = {}
    for chunk in (None, 8):
        engine = ServingEngine(params=params, cfg=cfg, engine_cfg=EngineConfig(
            n_slots=2, cache_len=48, cache_mode="paged", page_size=8,
            prefill_chunk=chunk))
        metrics = engine.run([(i, p, 6) for i, p in enumerate(prompts)])
        outs[chunk] = [r.output_tokens
                       for r in sorted(metrics.finished,
                                       key=lambda r: r.req_id)]
        if chunk:
            assert metrics.chunk_steps >= 6  # 13->2, 21->3, 8->1, 17->3
    assert outs[None] == outs[8], "chunked xLSTM diverged from one-shot"


def test_chunked_prefill_gate_tiers():
    """``chunkable_with_state`` admits pure-recurrent stacks to chunked
    prefill while the bitwise ``chunkable`` contract still excludes them
    (prefix cache / spec); local_attn ring buffers stay unchunkable."""
    from repro.paging import chunkable, chunkable_with_state

    xl = reduced(get_config("xlstm-125m")).with_(remat=False)
    assert not chunkable(xl) and chunkable_with_state(xl)
    rg = reduced(get_config("recurrentgemma-9b")).with_(remat=False)
    assert not chunkable_with_state(rg)  # local_attn in the pattern
    with pytest.raises(ValueError, match="chunk"):
        ServingEngine(rg, init_params(rg, jax.random.PRNGKey(0)),
                      EngineConfig(cache_mode="paged", page_size=8,
                                   prefill_chunk=8))


# -- DeadlineAdmission (ingress shedding) -----------------------------------

def test_deadline_admission_sheds_late():
    """Requests already past their deadline in the queue are shed at
    ingress: reason="deadline", a deadline_shed count, and the ordinary
    finish accounting (miss + zero goodput) — without ever holding a lane."""
    from repro.serving.policies import DeadlineAdmission, EnginePolicies

    cfg = reduced(get_config("llama3.2-1b")).with_(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, EngineConfig(n_slots=1, cache_len=32),
        policies=EnginePolicies(admission=DeadlineAdmission()))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).tolist()
    # 1 lane, 3 requests, an impossible deadline: everything queued goes
    # stale immediately and must be shed rather than decoded late
    sp = SamplingParams(deadline_s=1e-6)
    metrics = engine.run([(0, prompt, 6, sp) for _ in range(3)])
    rep = metrics.report()
    assert rep["deadline_shed"] >= 2
    assert rep["requests"] == 3
    shed = [r for r in metrics.finished if r.finish_reason == "deadline"]
    assert len(shed) >= 2 and all(not r.output_tokens for r in shed)
    # shed requests are misses with zero goodput contribution
    assert rep["deadline_misses"] >= len(shed)
    # a generous deadline sheds nothing and finishes normally
    engine2 = ServingEngine(
        cfg, params, EngineConfig(n_slots=1, cache_len=32),
        policies=EnginePolicies(admission=DeadlineAdmission()))
    m2 = engine2.run([(0, prompt, 6, SamplingParams(deadline_s=300.0))
                      for _ in range(2)])
    assert m2.report()["deadline_shed"] == 0
    assert all(len(r.output_tokens) == 6 for r in m2.finished)


def test_deadline_admission_slack_and_validation():
    from repro.serving.policies import DeadlineAdmission

    with pytest.raises(ValueError):
        DeadlineAdmission(slack_s=-1.0)
    pol = DeadlineAdmission(slack_s=0.5)
    now = 100.0
    mk = lambda submit, dl: Request(req_id=0, prompt=[1], max_new_tokens=1,
                                    submit_time=submit, deadline_s=dl)
    # 0.4s left > would finish inside slack? shed when remaining < slack
    assert pol.shed([mk(99.0, 1.2)], now) == [0]   # 0.2s left < 0.5 slack
    assert pol.shed([mk(99.0, 2.0)], now) == []    # 1.0s left
    assert pol.shed([mk(99.0, None)], now) == []   # no deadline: never shed
