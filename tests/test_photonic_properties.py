"""Property tests on the photonic models' physical invariants."""

import pytest

pytest.importorskip("hypothesis")  # property suite is optional-dep gated
from hypothesis import given, settings, strategies as st

from repro.core.accelerator_sim import AccelConfig, simulate
from repro.core.photonic_model import max_vector_length
from repro.core.workloads import GemmShape


class TestLinkBudgetMonotonicity:
    @given(st.sampled_from(["MWA", "MAW", "AMW"]),
           st.floats(0.0, 12.0), st.floats(0.0, 11.0))
    @settings(max_examples=60, deadline=None)
    def test_more_power_never_shrinks_n(self, org, p1, dp):
        n1, _ = max_vector_length(org, p1, 5.0)
        n2, _ = max_vector_length(org, p1 + dp, 5.0)
        assert n2 >= n1

    @given(st.sampled_from(["MWA", "MAW", "AMW"]),
           st.floats(1.0, 9.0), st.floats(0.0, 5.0))
    @settings(max_examples=60, deadline=None)
    def test_faster_rate_never_grows_n(self, org, dr, ddr):
        n1, _ = max_vector_length(org, 10.0, dr)
        n2, _ = max_vector_length(org, 10.0, dr + ddr)
        assert n2 <= n1

    def test_square_orgs_return_square(self):
        for org in ("MAW", "AMW"):
            n, m = max_vector_length(org, 10.0, 1.0)
            assert n == m

    def test_mwa_m_fixed_16(self):
        for p in (1.0, 5.0, 10.0):
            _, m = max_vector_length("MWA", p, 1.0)
            assert m == 16


class TestSimulatorInvariants:
    def test_energy_time_consistency(self):
        cfg = AccelConfig("SPOGA_5", "MWA", 5.0)
        r = simulate(cfg, "googlenet")
        assert r.time_s > 0 and r.energy_j > 0
        assert abs(r.power_w - r.energy_j / r.time_s) / r.power_w < 1e-9

    def test_bigger_workload_never_faster(self):
        cfg = AccelConfig("SPOGA_10", "MWA", 10.0)
        small = simulate(cfg, "shufflenet_v2")   # 0.11 GMAC
        big = simulate(cfg, "resnet50")          # 4.1 GMAC
        assert big.time_s > small.time_s

    def test_more_groups_not_slower(self):
        a = simulate(AccelConfig("s", "MWA", 10.0, n_groups=4), "resnet50")
        b = simulate(AccelConfig("s", "MWA", 10.0, n_groups=16), "resnet50")
        assert b.time_s <= a.time_s
        assert b.power_w >= a.power_w           # more hardware, more watts

    def test_spoga_conversions_scale_with_dots_only(self):
        """ADC count is exactly one per dot product, independent of K."""
        cfg = AccelConfig("s", "MWA", 1.0)
        from repro.core import accelerator_sim as sim

        trace_small_k = [GemmShape("g", m=64, k=100, n=50)]
        trace_large_k = [GemmShape("g", m=64, k=2000, n=50)]
        _, ev1 = sim._run_trace(cfg, trace_small_k)
        _, ev2 = sim._run_trace(cfg, trace_large_k)
        assert ev1["adc"] == ev2["adc"] == 64 * 50
