"""Quantization substrate: QTensor, calibration, fake-quant STE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional-dep gated
from hypothesis import given, settings, strategies as st

from repro.quant.calibrate import absmax_calibrate, percentile_calibrate
from repro.quant.fake_quant import fake_quant
from repro.quant.qtensor import QTensor, dequantize, quantize


class TestQTensor:
    def test_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)
        q = quantize(x, axis=-1)
        err = np.abs(np.asarray(dequantize(q) - x))
        step = np.asarray(q.scale)  # per-row scale == one quant step
        assert (err <= step * 0.5 + 1e-7).all()

    def test_pytree_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 8), jnp.float32)
        q = quantize(x, axis=None)
        leaves, treedef = jax.tree_util.tree_flatten(q)
        q2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_array_equal(np.asarray(q.data), np.asarray(q2.data))

    def test_int8_range(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (128,), jnp.float32) * 100
        q = quantize(x)
        d = np.asarray(q.data)
        assert d.dtype == np.int8
        assert d.max() <= 127 and d.min() >= -127

    @given(st.integers(1, 40), st.floats(0.01, 50.0))
    @settings(max_examples=20, deadline=None)
    def test_scale_invariance(self, n, scale):
        """quantize(c*x) has codes equal to quantize(x) up to one rounding
        step at exact half-code boundaries (symmetric absmax)."""
        x = np.linspace(-1, 1, n, dtype=np.float32)
        qa = np.asarray(quantize(jnp.asarray(x)).data, np.int32)
        qb = np.asarray(quantize(jnp.asarray(x * scale)).data, np.int32)
        assert np.abs(qa - qb).max() <= 1


class TestCalibrate:
    def test_absmax(self):
        samples = [jnp.asarray([1.0, -3.0]), jnp.asarray([2.0, 0.5])]
        np.testing.assert_allclose(float(absmax_calibrate(samples)), 3.0 / 127.0)

    def test_percentile_clips_outliers(self):
        x = jnp.concatenate([jnp.ones(999), jnp.asarray([1000.0])])
        p = float(percentile_calibrate([x], pct=99.0))
        np.testing.assert_allclose(p, 1.0 / 127.0, rtol=1e-3)


class TestFakeQuantSTE:
    def test_forward_quantizes(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (32,), jnp.float32)
        y = fake_quant(x)
        # values land on the int8 grid of the row scale
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        codes = np.asarray(y) / scale
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)

    def test_gradient_is_identity(self):
        """Straight-through estimator: d(fake_quant)/dx == 1."""
        x = jax.random.normal(jax.random.PRNGKey(4), (16,), jnp.float32)
        g = jax.grad(lambda v: fake_quant(v).sum())(x)
        np.testing.assert_allclose(np.asarray(g), 1.0, rtol=1e-6)
