"""Shared-prefix KV cache (repro/prefix/): radix-tree contracts, page
refcount / copy-on-write invariants, and engine-level exactness.

The headline guarantee extends the repo's exactness discipline: greedy
engine output with the prefix cache ON is bitwise identical to prefix
cache OFF (and to a solo ``serve_batch`` decode) across GQA, MLA and int8
paged KV — including multi-page shared prefixes, CoW forks on full-prompt
hits, LRU eviction under pool pressure, and defrag moving shared pages.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import serve_batch
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.paging import PageManager
from repro.prefix import PrefixCache, PrefixTree
from repro.serving import (
    EngineConfig,
    EnginePolicies,
    BucketBatchedAdmission,
    PriorityAdmission,
    Request,
    Scheduler,
    ServingEngine,
    ThresholdDefrag,
)

PS = 4  # tree-test page size


# ---------------------------------------------------------------------------
# PrefixTree (host radix tree)
# ---------------------------------------------------------------------------

def test_tree_match_is_page_aligned():
    t = PrefixTree(PS)
    toks = list(range(100, 112))                   # 3 pages
    assert t.insert(toks, [1, 2, 3]) == [1, 2, 3]
    # full match, page-aligned
    pages, path = t.match(toks)
    assert pages == [1, 2, 3] and len(path) == 1
    # a prompt sharing 2 pages + a partial third page matches only 2 pages
    pages, _ = t.match(toks[:8] + [999, 999, 999, 999])
    assert pages == [1, 2]
    # no sharing below page granularity: 3 shared tokens match nothing
    pages, _ = t.match(toks[:3] + [999] * 9)
    assert pages == []
    # short prompts (< one page) can never match
    assert t.match(toks[:3])[0] == []


def test_tree_split_and_divergent_insert():
    t = PrefixTree(PS)
    a = [1, 2, 3, 4, 5, 6, 7, 8]                   # pages A1 A2
    b = [1, 2, 3, 4, 9, 9, 9, 9]                   # shares page 1 only
    assert t.insert(a, [10, 11]) == [10, 11]
    new = t.insert(b, [20, 21])
    assert new == [21]                             # page 1 already cached
    pa, _ = t.match(a)
    pb, _ = t.match(b)
    assert pa == [10, 11] and pb == [10, 21]
    assert t.total_pages == 3 and t.n_nodes == 3   # trunk + two tails


def test_tree_duplicate_insert_keeps_original_pages():
    t = PrefixTree(PS)
    toks = [5, 6, 7, 8]
    assert t.insert(toks, [3]) == [3]
    # a second lane computed the same prefix into its own page: tree keeps
    # the original, the duplicate stays lane-owned
    assert t.insert(toks, [7]) == []
    assert t.match(toks)[0] == [3]


def test_tree_lru_evicts_leaves_first_with_protection():
    t = PrefixTree(PS)
    trunk = [1, 2, 3, 4]
    t.insert(trunk + [5, 5, 5, 5], [1, 2])         # trunk + leaf A
    t.insert(trunk + [6, 6, 6, 6], [1, 3])         # leaf B
    t.match(trunk + [5, 5, 5, 5])                  # A is now most recent
    released = t.evict(1, evictable=lambda n: True)
    assert released == [3]                         # LRU leaf B, not trunk
    # trunk is protected while A still hangs off it? it's not a leaf:
    released = t.evict(10, evictable=lambda n: True,
                       protect=list(t.match(trunk + [5, 5, 5, 5])[1]))
    assert released == []                          # everything left is pinned
    released = t.evict(10, evictable=lambda n: True)
    assert sorted(released) == [1, 2]              # leaf A then exposed trunk
    assert t.n_nodes == 0


def test_tree_remap_rewrites_pages():
    t = PrefixTree(PS)
    t.insert([1, 2, 3, 4, 5, 6, 7, 8], [9, 4])
    t.remap({9: 1, 4: 2})
    assert t.match([1, 2, 3, 4, 5, 6, 7, 8])[0] == [1, 2]


# ---------------------------------------------------------------------------
# PageManager refcounts / CoW (host bookkeeping)
# ---------------------------------------------------------------------------

def test_manager_adopt_and_shared_free():
    mgr = PageManager(n_pages=10, page_size=4, n_lanes=3, max_pages_per_lane=4)
    mgr.admit(0, reserve_tokens=16)
    pages = mgr.alloc(0, 2)
    mgr.tree_ref(pages)                            # published
    # lane 1 aliases the published pages; pool draw is only the remainder
    mgr.admit(1, reserve_tokens=16, adopt_pages=pages)
    assert mgr.lane_pages[1] == pages
    assert mgr.block_tables[1, :2].tolist() == pages
    assert (mgr.refcount[pages] == 3).all()        # 2 lanes + tree
    mgr.check_invariants()
    # freeing one lane keeps the pages alive for the other + the tree
    assert mgr.free_lane(0) == 0
    assert (mgr.refcount[pages] == 2).all()
    assert mgr.free_lane(1) == 0                   # tree still holds them
    assert mgr.tree_unref(pages) == 2              # now they return
    mgr.check_invariants()


def test_manager_cow_fork_swaps_private_page():
    mgr = PageManager(n_pages=10, page_size=4, n_lanes=2, max_pages_per_lane=4)
    mgr.admit(0, reserve_tokens=8)
    pages = mgr.alloc(0, 2)
    mgr.tree_ref(pages)
    mgr.admit(1, reserve_tokens=12, adopt_pages=pages, forks=1)
    src, dst = mgr.cow_fork(1, 1)
    assert src == pages[1] and dst not in pages
    assert mgr.lane_pages[1] == [pages[0], dst]
    assert mgr.block_tables[1, 1] == dst
    assert mgr.refcount[src] == 2 and mgr.refcount[dst] == 1
    mgr.check_invariants()
    # ensure_writable is a no-op on private pages, forks shared ones
    assert mgr.ensure_writable(1, 7) is None       # dst is private
    move = mgr.ensure_writable(1, 2)               # pages[0] is shared
    assert move is not None and move[0] == pages[0]
    mgr.check_invariants()
    with pytest.raises(RuntimeError, match="not shared"):
        mgr.cow_fork(1, 0)                         # already private now


def test_manager_admit_gate_counts_adoption_and_forks():
    mgr = PageManager(n_pages=6, page_size=4, n_lanes=2, max_pages_per_lane=5)
    mgr.admit(0, reserve_tokens=12)
    pages = mgr.alloc(0, 3)
    mgr.tree_ref(pages)
    mgr.free_lane(0)                               # tree-only now; 2 free
    # 5-page reservation adopting 3 shared pages draws only 2 from the pool
    mgr.admit(1, reserve_tokens=20, adopt_pages=pages)
    assert mgr.available == 0
    mgr.free_lane(1)
    # the same adoption with a fork draws 3 — one too many for 2 free pages
    with pytest.raises(RuntimeError, match="overcommit"):
        mgr.admit(1, reserve_tokens=20, adopt_pages=pages, forks=2)
    mgr.check_invariants()


def test_manager_defrag_preserves_shared_aliasing():
    mgr = PageManager(n_pages=12, page_size=4, n_lanes=3, max_pages_per_lane=4)
    mgr.admit(0, reserve_tokens=8)
    low = mgr.alloc(0, 2)                          # pages 1,2
    mgr.admit(1, reserve_tokens=8)
    mid = mgr.alloc(1, 2)                          # pages 3,4
    mgr.tree_ref(mid)
    mgr.admit(2, reserve_tokens=8, adopt_pages=mid)  # lanes 1+2 alias mid
    mgr.free_lane(0)                               # holes at 1,2
    seen = {}
    mgr.remap_listeners.append(seen.update)
    moves = mgr.defrag()
    assert moves                                   # mid compacts into 1,2
    assert mgr.lane_pages[1] == mgr.lane_pages[2]  # aliasing preserved
    assert (mgr.block_tables[1, :2] == mgr.block_tables[2, :2]).all()
    assert seen == dict(moves)                     # tree listener notified
    assert (mgr.refcount[mgr.lane_pages[1]] == 3).all()
    mgr.check_invariants()


def test_manager_invariants_under_random_ops():
    """Property-style: a random alloc/adopt/publish/fork/free/defrag storm
    never breaks refcount bookkeeping — counts match holders, nothing is
    simultaneously free and referenced, tables mirror page lists."""
    rng = np.random.default_rng(0)
    mgr = PageManager(n_pages=24, page_size=4, n_lanes=4, max_pages_per_lane=5)
    published: list[int] = []
    for _ in range(300):
        lane = int(rng.integers(0, 4))
        op = rng.integers(0, 6)
        try:
            if op == 0 and not mgr.lane_pages[lane] and not mgr.reserved[lane]:
                mgr.admit(lane, int(rng.integers(1, 20)))
            elif op == 1:
                mgr.ensure(lane, int(rng.integers(1, 20)))
            elif op == 2 and mgr.lane_pages[lane]:
                n = mgr.free_lane(lane)
                assert n >= 0
            elif op == 3 and mgr.lane_pages[lane]:
                # publish the lane's first unpublished page
                for p in mgr.lane_pages[lane]:
                    if not mgr.tree_held[p]:
                        mgr.tree_ref([p])
                        published.append(p)
                        break
            elif op == 4:
                for i, p in enumerate(mgr.lane_pages[lane]):
                    if mgr.refcount[p] > 1 and mgr.free_pages > 0:
                        mgr.cow_fork(lane, i)
                        break
            elif op == 5:
                mgr.defrag()
                published[:] = [p for p in published if mgr.tree_held[p]]
        except RuntimeError:
            pass  # legitimate capacity/width rejections
        mgr.check_invariants()
        assert (mgr.refcount >= 0).all()
    while published:
        mgr.tree_unref([published.pop()])
        mgr.check_invariants()


# ---------------------------------------------------------------------------
# Engine-level exactness (the acceptance bar)
# ---------------------------------------------------------------------------

def _setup(arch, **cfg_kw):
    cfg = reduced(get_config(arch)).with_(remat=False, **cfg_kw)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, policies=None, **ecfg_kw):
    kw = dict(n_slots=2, cache_len=48, cache_mode="paged", page_size=8,
              prefill_chunk=8)
    kw.update(ecfg_kw)
    return ServingEngine(cfg, params, EngineConfig(**kw), policies=policies)


def _solo(cfg, params, prompt, gen, cache_len=48):
    out, _ = serve_batch(cfg, params,
                         {"tokens": jnp.asarray([prompt], jnp.int32)},
                         cache_len=cache_len, gen_tokens=gen)
    return np.asarray(out)[0].tolist()


@pytest.mark.parametrize("arch,kv", [
    ("llama3.2-1b", "bf16"),      # GQA pages
    ("minicpm3-4b", "bf16"),      # MLA latent pages
    ("llama3.2-1b", "int8"),      # byte-size pages + scales
])
def test_engine_prefix_cache_is_bitwise_invisible(arch, kv):
    """Acceptance: staggered requests sharing a multi-page prefix produce
    EXACTLY the same greedy tokens with the prefix cache ON as OFF — and
    both equal each request's solo decode.  ON must actually hit (the
    equality must not be vacuous)."""
    cfg, params = _setup(arch, kv_cache_dtype=kv)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()   # 2 pages
    prompts = [shared + rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 3)]
    gens = [6, 5, 4]
    arrivals = [(2 * i, p, g) for i, (p, g) in enumerate(zip(prompts, gens))]
    outs = {}
    for on in (False, True):
        engine = _engine(cfg, params, prefix_cache=on)
        m = engine.run(arrivals)
        outs[on] = {r.req_id: r.output_tokens for r in m.finished}
        if on:
            assert m.prefix_hits >= 2, "prefix cache never engaged"
            assert m.prefix_hit_tokens >= 2 * 16
            engine.store.manager.check_invariants()
    assert outs[True] == outs[False]
    for i, (p, g) in enumerate(zip(prompts, gens)):
        assert outs[True][i] == _solo(cfg, params, p, g), (
            f"{arch}/{kv}: request {i} diverged from its solo decode")


def test_engine_prefix_cow_fork_under_concurrent_decode():
    """A full-prompt hit CoW-forks the boundary page while the publishing
    request is STILL decoding through lanes that alias it — both streams
    must equal the solo decode."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 16).tolist()   # exactly 2 pages
    engine = _engine(cfg, params, prefix_cache=True)
    m = engine.run([(0, prompt, 10), (2, prompt, 10)])      # 2nd mid-decode
    assert m.prefix_cow_forks >= 1
    assert m.prefix_hits == 1
    ref = _solo(cfg, params, prompt, 10)
    for r in m.finished:
        assert r.output_tokens == ref
    engine.store.manager.check_invariants()


def test_engine_prefix_no_sharing_below_page_granularity():
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab_size, 5).tolist()    # < one page
    prompts = [shared + rng.integers(0, cfg.vocab_size, 6).tolist()
               for _ in range(2)]
    engine = _engine(cfg, params, prefix_cache=True)
    m = engine.run([(0, prompts[0], 4), (2, prompts[1], 4)])
    assert m.prefix_hits == 0 and m.prefix_misses == 2
    # 12-token prompts still publish their one full page each; the second
    # prompt's first page differs (suffix bleeds into it), so no match
    for i, p in enumerate(prompts):
        got = {r.req_id: r.output_tokens for r in m.finished}[i]
        assert got == _solo(cfg, params, p, 4)


def test_engine_prefix_eviction_then_rematch():
    """Pool pressure LRU-evicts tree-only pages to admit new work; an
    evicted prefix misses, republishes, and matches again — all exact."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(3)
    pA = rng.integers(0, cfg.vocab_size, 16).tolist()
    pB = rng.integers(0, cfg.vocab_size, 16).tolist()
    engine = ServingEngine(cfg, params, EngineConfig(
        n_slots=1, cache_len=32, cache_mode="paged", page_size=8, n_pages=5,
        prefix_cache=True))
    m = engine.run([(0, pA, 8), (5, pB, 8), (10, pA, 8), (15, pA, 8)])
    assert m.prefix_evicted_pages > 0
    assert m.prefix_hits >= 1
    engine.store.manager.check_invariants()
    outs = {r.req_id: r.output_tokens for r in m.finished}
    refs = {0: _solo(cfg, params, pA, 8, 32), 1: _solo(cfg, params, pB, 8, 32)}
    assert outs[0] == outs[2] == outs[3] == refs[0]
    assert outs[1] == refs[1]


def test_engine_prefix_defrag_moves_shared_pages_exactly():
    """Aggressive ThresholdDefrag compacts mid-run while the tree and
    several lanes alias the same physical pages; outputs stay solo-exact
    and the manager invariants hold."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 3, 7)]
    gens = [6, 4, 7, 5]
    engine = _engine(cfg, params, prefix_cache=True,
                     policies=EnginePolicies(
                         defrag=ThresholdDefrag(0.1, min_pages=2)))
    m = engine.run([(0, prompts[0], gens[0]), (2, prompts[1], gens[1]),
                    (6, prompts[2], gens[2]), (9, prompts[3], gens[3])])
    assert m.defrag_count > 0 and m.prefix_hits >= 2
    engine.store.manager.check_invariants()
    outs = {r.req_id: r.output_tokens for r in m.finished}
    for i, (p, g) in enumerate(zip(prompts, gens)):
        assert outs[i] == _solo(cfg, params, p, g), i


def test_engine_rejects_bad_prefix_configs():
    cfg, params = _setup("llama3.2-1b")
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, EngineConfig(cache_mode="slot",
                                                prefix_cache=True))
    moe_cfg, moe_params = _setup("granite-moe-3b-a800m")
    with pytest.raises(ValueError, match="row-independent"):
        ServingEngine(moe_cfg, moe_params, EngineConfig(
            cache_mode="paged", page_size=8, prefix_cache=True))
    rec_cfg, rec_params = _setup("xlstm-125m")
    with pytest.raises(ValueError, match="per-lane"):
        ServingEngine(rec_cfg, rec_params, EngineConfig(
            cache_mode="paged", page_size=8, prefix_cache=True))


# ---------------------------------------------------------------------------
# Satellites: stacked paged admission, priority admission
# ---------------------------------------------------------------------------

def test_engine_paged_stacked_admission_exact():
    """PR 4 follow-up closed: same-bucket prompts admit the PAGED engine
    as ONE batch=k fused prefill + per-lane page scatter — fewer
    dispatches, bitwise-identical streams."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in (9, 12, 14)]
    engine = _engine(cfg, params, n_slots=3, prefill_chunk=None,
                     prefill_buckets=(16,),
                     policies=EnginePolicies(admission=BucketBatchedAdmission()))
    m = engine.run([(0, prompts[0], 5), (0, prompts[1], 5), (0, prompts[2], 5)])
    assert m.stacked_prefills == 3 and m.prefill_dispatches == 1
    outs = {r.req_id: r.output_tokens for r in m.finished}
    for i, p in enumerate(prompts):
        assert outs[i] == _solo(cfg, params, p, 5), i
    engine.store.manager.check_invariants()
    assert engine.store.manager.pages_in_use == 0


def test_engine_paged_stacked_respects_capacity_gate():
    """Members of one stacked dispatch reserve against a single tallied
    pool snapshot: two requests that each fit but not together admit one
    after the other instead of overcommitting."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, 16).tolist() for _ in range(2)]
    engine = ServingEngine(cfg, params, EngineConfig(
        n_slots=2, cache_len=32, cache_mode="paged", page_size=8, n_pages=6,
        prefill_buckets=(16,), max_prefills_per_step=2),
        policies=EnginePolicies(admission=BucketBatchedAdmission()))
    m = engine.run([(0, prompts[0], 8), (0, prompts[1], 8)])
    assert len(m.finished) == 2
    assert m.peak_running == 1            # 3+3 pages never fit 5 at once
    assert engine.store.manager.pages_in_use == 0


def test_priority_admission_orders_and_ages():
    sched = Scheduler(n_slots=1, admission=PriorityAdmission(aging_steps=3))
    lo = Request(req_id=0, prompt=[1], max_new_tokens=1, priority=0)
    hi = Request(req_id=1, prompt=[1], max_new_tokens=1, priority=5)
    sched.submit(lo)
    sched.submit(hi)
    # higher priority jumps the FIFO queue
    assert [r.req_id for r, _ in sched.schedule_group()] == [1]
    sched.release(0)
    # the chosen head is head-of-line for the capacity gate: a veto admits
    # nothing (no skip-ahead starvation of large requests)
    sched.submit(Request(req_id=2, prompt=[1], max_new_tokens=1, priority=9))
    assert sched.schedule_group(admit_ok=lambda r: r.req_id != 2) == []
    assert [r.req_id for r, _ in sched.schedule_group()] == [2]
    sched.release(0)
    assert [r.req_id for r, _ in sched.schedule_group()] == [0]


def test_priority_admission_aging_prevents_starvation():
    pol = PriorityAdmission(aging_steps=2)
    old = Request(req_id=0, prompt=[1], max_new_tokens=1, priority=0)
    waiting = [old]
    # a stream of fresh priority-2 arrivals would starve req 0 forever
    # without aging; after enough polls its effective priority wins
    picked_old = False
    for i in range(12):
        fresh = Request(req_id=100 + i, prompt=[1], max_new_tokens=1,
                        priority=2)
        queue = [old, fresh]
        idx = pol.next_group(queue, 1, lambda r: True, lambda r: 1)
        if queue[idx[0]].req_id == 0:
            picked_old = True
            break
    assert picked_old, "aging never lifted the starved request"


def test_priority_admission_through_engine():
    """End-to-end: with one lane, a high-priority later arrival admits
    before an earlier low-priority one."""
    cfg, params = _setup("llama3.2-1b")
    rng = np.random.default_rng(7)
    engine = ServingEngine(cfg, params, EngineConfig(
        n_slots=1, cache_len=32),
        policies=EnginePolicies(admission=PriorityAdmission()))
    p1 = rng.integers(0, cfg.vocab_size, 6).tolist()
    p2 = rng.integers(0, cfg.vocab_size, 6).tolist()
    r_lo = engine.add_request(p1, 4, priority=0)
    r_hi = engine.add_request(p2, 4, priority=3)
    while engine.has_work:
        engine.step()
    assert r_hi.first_token_time < r_lo.first_token_time
    assert r_lo.output_tokens == _solo(cfg, params, p1, 4, 32)
    assert r_hi.output_tokens == _solo(cfg, params, p2, 4, 32)
