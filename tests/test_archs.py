"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + one prefill/decode step on CPU, asserting shapes and
finiteness (no NaNs).  The FULL configs are exercised only by the dry-run.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import (
    decode_step,
    forward,
    init_params,
    lm_loss,
    prefill,
)

SEQ = 64
BATCH = 2


def _batch_for(cfg, key, seq=SEQ, batch=BATCH):
    kt, ke = jax.random.split(key)
    if cfg.is_encoder_decoder:
        return {
            "src_embeds": jax.random.normal(ke, (batch, seq, cfg.d_model), jnp.float32) * 0.02,
            "tgt_tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
        }
    if cfg.frontend is not None:
        return {
            "embeds": jax.random.normal(ke, (batch, seq, cfg.d_model), jnp.float32) * 0.02,
            "labels": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)}


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_loss(arch, rng):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng)
    batch = _batch_for(cfg, rng)
    logits = forward(params, cfg, batch)
    tgt = batch.get("tgt_tokens", batch.get("tokens", batch.get("labels")))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = lm_loss(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step_grads(arch, rng):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng)
    batch = _batch_for(cfg, rng, seq=32)
    loss, grads = jax.value_and_grad(lambda p: lm_loss(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    finite = jax.tree_util.tree_all(
        jax.tree_util.tree_map(lambda g: bool(jnp.all(jnp.isfinite(g))), grads)
    )
    assert finite, f"non-finite grads for {arch}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode(arch, rng):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng)
    batch = _batch_for(cfg, rng, seq=32)
    logits, cache = prefill(params, cfg, batch, cache_len=64)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = decode_step(params, cfg, tok, cache)
    assert logits2.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1
