"""End-to-end training integration on CPU with reduced configs."""

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.launch.train import train_loop


def test_loss_decreases_dense():
    cfg = reduced(get_config("llama3.2-1b")).with_(n_layers=2, remat=False)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3, total_steps=25)
    _, losses = train_loop(cfg, tcfg, steps=25, batch=4, seq=64, log_every=100)
    first = np.mean(losses[:3])
    last = np.mean(losses[-3:])
    assert np.isfinite(losses).all()
    assert last < first - 0.25, f"no learning: {first:.3f} -> {last:.3f}"


def test_loss_decreases_int8_spoga():
    """The paper's motivating claim: INT8 W8A8 (SPOGA dataflow) trains."""
    cfg = reduced(get_config("llama3.2-1b")).with_(
        n_layers=2, remat=False, quant_mode="int8_spoga")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3, total_steps=25)
    _, losses = train_loop(cfg, tcfg, steps=25, batch=4, seq=64, log_every=100)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.2


def test_quant_modes_agree_exactly():
    """spoga / deas / direct are the SAME integer arithmetic: train curves
    must match bit-for-bit (paper Sec. III: the dataflows are equivalent)."""
    curves = {}
    for mode in ("int8_spoga", "int8_deas", "int8_direct"):
        cfg = reduced(get_config("llama3.2-1b")).with_(
            n_layers=2, remat=False, quant_mode=mode)
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=6)
        _, losses = train_loop(cfg, tcfg, steps=6, batch=2, seq=32, log_every=100)
        curves[mode] = losses
    np.testing.assert_array_equal(curves["int8_spoga"], curves["int8_deas"])
    np.testing.assert_array_equal(curves["int8_spoga"], curves["int8_direct"])


def test_microbatched_grad_accum_matches_full_batch():
    """k microbatches with mean-accumulated grads == one full batch step."""
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim.optimizers import adamw_init

    cfg = reduced(get_config("llama3.2-1b")).with_(n_layers=2, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab_size)}
    p1, _, m1 = jax.jit(make_train_step(cfg, TrainConfig()))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, TrainConfig(microbatches=4)))(
        params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)


def test_grad_compression_trains():
    """int8-compressed gradient all-reduce still converges (shard_map DP)."""
    import functools

    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.models import lm_loss
    from repro.optim.optimizers import adamw_init, adamw_update
    from repro.runtime.collectives import compressed_psum_mean, shard_map

    cfg = reduced(get_config("llama3.2-1b")).with_(n_layers=2, remat=False)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3, total_steps=25)
    params = init_params_ = None
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))

    def dp_step(params, opt, batch):
        def local(params, opt, batch):
            loss, g = jax.value_and_grad(lm_loss)(params, cfg, batch)
            g = compressed_psum_mean(g, "data")
            loss = jax.lax.pmean(loss, "data")
            params, opt, metrics = adamw_update(params, g, opt, tcfg)
            return params, opt, loss

        return shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P("data")),
            out_specs=(P(), P(), P()),
            check_vma=False,  # scan carries inside lm_loss start unvarying
        )(params, opt, batch)

    dp_step = jax.jit(dp_step)
    from repro.data.pipeline import SyntheticTokenPipeline
    pipe = SyntheticTokenPipeline(cfg.vocab_size, 64, 4 * jax.device_count())
    losses = []
    for step in range(25):
        params, opt, loss = dp_step(params, opt, {"tokens": pipe.global_batch_at(step)})
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.2
