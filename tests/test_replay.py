"""Flight recorder + deterministic replay (``repro.obs.recorder`` /
``repro.obs.replay``).

The contract under test: a run recorded with ``ObsConfig(record_path=...)``
replays **bitwise** — every request's greedy token stream and every
scheduler decision in the journal — from nothing but the bundle, and a
deliberately perturbed replay is diffed to the *first* divergent
decision.  Plus the satellites that ride on the same machinery: the
``DeadlinePreemption`` eviction policy (a time-dependent decision that
must record + replay through the decision-clock tape), the
``/events?n=N`` endpoint, event-log ``wall``/``seq`` guarantees across
rotation, and the zero-overhead-disarmed invariant.
"""

import dataclasses
import json
import time
import urllib.request

import numpy as np
import pytest

from repro.api import (
    LLM,
    DeadlinePreemption,
    KVConfig,
    ObsConfig,
    RuntimeConfig,
    SchedulerConfig,
    SpecConfig,
)
from repro.obs.events import EventLog, NullEventLog
from repro.obs.replay import (
    ReplayClock,
    canonical_event,
    diff_journals,
    load_bundle,
    replay_bundle,
)
from repro.serving.sampling import SamplingParams


# ---------------------------------------------------------------------------
# event log: wall clock + contiguous seq across rotation (satellite)
# ---------------------------------------------------------------------------

def test_event_log_emits_wall_and_monotonic_seq(tmp_path):
    log = EventLog()
    before = time.time()
    evs = [log.emit("k", i, x=i) for i in range(5)]
    after = time.time()
    assert [e["seq"] for e in evs] == [0, 1, 2, 3, 4]
    for e in evs:
        assert before <= e["wall"] <= after
        assert "t" in e
    assert NullEventLog().tail(3) == []
    assert log.tail(2) == evs[-2:]
    assert log.tail(0) == []
    assert log.tail(99) == evs


def test_event_seq_stays_contiguous_across_rotation(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    log = EventLog(stream_path=path, max_bytes=400)
    for i in range(40):
        log.emit("tick", i, payload="x" * 20)
    log.close()
    assert log.rotations >= 1
    lines = []
    for p in (path + ".1", path):
        with open(p) as f:
            lines += [json.loads(l) for l in f]
    seqs = [e["seq"] for e in lines]
    # rotation renames the file but never resets or skips the counter:
    # the surviving stream is a contiguous seq suffix (here: everything)
    assert seqs == list(range(seqs[0], seqs[0] + len(seqs)))
    assert all("wall" in e for e in lines)


# ---------------------------------------------------------------------------
# journal differ + replay clock units
# ---------------------------------------------------------------------------

def test_diff_journals_finds_first_divergence_and_ignores_volatiles():
    a = [{"seq": 0, "t": 1.0, "wall": 9.0, "kind": "queued", "req_id": 0},
         {"seq": 1, "t": 2.0, "wall": 9.1, "kind": "admitted", "req_id": 0,
          "pages": [1, 2], "queue_wait_s": 0.5}]
    b = [{"seq": 0, "t": 5.0, "wall": 99.0, "kind": "queued", "req_id": 0},
         {"seq": 1, "t": 6.0, "wall": 99.1, "kind": "admitted", "req_id": 0,
          "pages": [1, 2], "queue_wait_s": 0.9}]
    assert diff_journals(a, b) is None  # timestamps/waits are volatile
    b[1]["pages"] = [1, 3]
    div = diff_journals(a, b)
    assert div is not None and div.index == 1
    msg = div.format()
    assert "diverged at event 1" in msg
    assert "pages=[1, 2]" in msg and "pages=[1, 3]" in msg
    # length mismatch: the shorter journal's end is the divergence
    div = diff_journals(a, a[:1])
    assert div.index == 1 and div.replayed is None
    assert "<journal ended>" in div.format()
    # tuples canonicalize like the JSON round-trip the journal went through
    assert canonical_event({"kind": "defrag", "moves": [(5, 1)]}) == \
        {"kind": "defrag", "moves": [[5, 1]]}


def test_replay_clock_scripts_tape_then_holds():
    clk = ReplayClock([1.0, 2.5, 7.0])
    assert [clk(), clk(), clk()] == [1.0, 2.5, 7.0]
    assert clk() == 7.0 and clk() == 7.0  # exhausted: hold the last instant
    assert clk.exhausted_reads == 2
    assert ReplayClock([])() == 0.0


# ---------------------------------------------------------------------------
# record -> replay: bitwise fidelity (tentpole acceptance)
# ---------------------------------------------------------------------------

def _mixed_runtime(record_path=None, spec=True, eviction="budget",
                   admission="fifo"):
    """The everything-on paged engine: prefix cache + chunked prefill +
    (optionally) speculative decoding."""
    return RuntimeConfig(
        reduced=True,
        kv=KVConfig(mode="paged", page_size=8, prefix_cache=True),
        scheduler=SchedulerConfig(n_slots=2, prefill_chunk=8,
                                  admission=admission, eviction=eviction),
        spec=SpecConfig(enabled=spec, k=2, drafter="ngram"),
        obs=ObsConfig(record_path=record_path),
    )


def _record_mixed_run(path, seed=0, deadlines=(None, 120.0, None, 120.0)):
    """Record one staggered paged+prefix+spec run; returns recorded
    per-request token streams keyed by req_id."""
    llm = LLM(arch="llama3.2-1b", runtime=_mixed_runtime(record_path=path))
    eng = llm.build_engine(25, 6)
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, llm.config.vocab_size, 16).tolist()
    arrivals = []
    for s, n in enumerate((5, 9, 3, 7)):
        prompt = shared + rng.integers(0, llm.config.vocab_size, n).tolist()
        arrivals.append((s * 2, prompt, 6,
                         SamplingParams(deadline_s=deadlines[s])))
    eng.run(arrivals=arrivals)
    tokens = {r.req_id: list(r.output_tokens) for r in eng.metrics.finished}
    llm.close()
    return tokens


def test_record_replay_mixed_paged_prefix_spec_bitwise(tmp_path):
    bundle = str(tmp_path / "bundle")
    recorded = _record_mixed_run(bundle)
    b = load_bundle(bundle)
    assert b.manifest["arch"] == "llama3.2-1b"
    assert b.manifest["engine"]["cache_mode"] == "paged"
    assert b.manifest["fingerprint"]["jax"]
    assert len(b.arrivals) == 4 and len(b.outputs) == 4
    assert {e["kind"] for e in b.journal} >= {"queued", "admitted",
                                              "spec_verify", "finished"}
    # admit decisions carry re-executable operands, not just reasons
    admits = [e for e in b.journal if e["kind"] == "admitted"]
    assert all("pages" in e or e["mode"] in ("chunked", "cold")
               for e in admits)
    assert any(e.get("mode") == "prefix" and e.get("pages") for e in admits)
    assert all(len(b.clock) > 0 for _ in [0])

    res = LLM.replay(bundle)  # the api-level entrypoint
    assert res.ok, res.summary()
    assert res.token_mismatches == [] and res.divergence is None
    assert res.n_recorded_events == res.n_replayed_events > 0
    # outputs in the bundle match what the recording engine produced
    assert {o["req_id"]: o["tokens"] for o in b.outputs} == recorded


def test_perturbed_replay_names_first_divergent_decision(tmp_path):
    bundle = str(tmp_path / "bundle")
    _record_mixed_run(bundle)

    def shrink(rt):
        # a smaller page pool: admissions that fit on record now reject
        return dataclasses.replace(
            rt, kv=dataclasses.replace(rt.kv, n_pages=6))

    res = replay_bundle(bundle, runtime_transform=shrink, max_steps=2000)
    assert not res.ok
    assert res.divergence is not None
    msg = res.divergence.format()
    assert "diverged at event" in msg
    # the differ shows both contexts: the recorded decision and what the
    # perturbed engine did instead
    assert "recorded " in msg and "replayed " in msg
    rec, rep = res.divergence.recorded, res.divergence.replayed
    assert canonical_event(rec) != canonical_event(rep)


def test_fuzz_random_workloads_record_replay_bitwise(tmp_path):
    """Property-style: random stagger / priorities / deadlines /
    prefix-shared prompts / spec on-off -> record -> replay -> bitwise."""
    for case, fuzz_seed in enumerate((7, 23, 101)):
        rng = np.random.default_rng(fuzz_seed)
        spec = bool(case % 2 == 0)
        admission = ["fifo", "priority", "deadline"][case % 3]
        bundle = str(tmp_path / f"fuzz{case}")
        llm = LLM(arch="llama3.2-1b",
                  runtime=_mixed_runtime(record_path=bundle, spec=spec,
                                         admission=admission))
        eng = llm.build_engine(25, 6)
        shared = rng.integers(0, llm.config.vocab_size, 16).tolist()
        n_req = int(rng.integers(3, 6))
        step = 0
        for _ in range(n_req):
            use_prefix = rng.random() < 0.6
            n = int(rng.integers(2, 9))
            prompt = ((shared if use_prefix else []) +
                      rng.integers(0, llm.config.vocab_size, n).tolist())
            gen = int(rng.integers(2, 7))
            # a mix of no deadline, generous, and already-blown (the
            # tiny one exercises shed/lateness through the clock tape)
            deadline = [None, 120.0, 1e-6][int(rng.integers(0, 3))]
            eng.add_request(prompt, gen,
                            sampling=SamplingParams(deadline_s=deadline),
                            priority=int(rng.integers(0, 3)))
            for _ in range(int(rng.integers(0, 3))):  # arrival stagger
                if eng.has_work:
                    eng.step()
                step += 1
        eng.run()
        llm.close()
        res = replay_bundle(bundle)
        assert res.ok, (f"fuzz case {case} (seed {fuzz_seed}, spec={spec}, "
                        f"admission={admission}):\n" + res.summary())


# ---------------------------------------------------------------------------
# disarmed recorder: zero overhead, identical decisions
# ---------------------------------------------------------------------------

def test_recorder_disarmed_zero_overhead_and_output_invisible(tmp_path):
    llm_off = LLM(arch="llama3.2-1b", runtime=_mixed_runtime())
    eng_off = llm_off.build_engine(25, 6)
    # disarmed: no recorder object, and the decision clock IS
    # time.perf_counter (no wrapper on any host path)
    assert llm_off.obs.recorder is None
    assert eng_off._recorder is None
    assert eng_off._clock is time.perf_counter
    assert eng_off.scheduler.clock is time.perf_counter

    llm_on = LLM(arch="llama3.2-1b",
                 runtime=_mixed_runtime(record_path=str(tmp_path / "b")))
    eng_on = llm_on.build_engine(25, 6)
    # recording is host-side only: armed and disarmed engines share the
    # exact same jitted callables (same lru_cache entries -> same jaxprs)
    assert eng_on._decode_sample is eng_off._decode_sample

    rng = np.random.default_rng(3)
    shared = rng.integers(0, llm_off.config.vocab_size, 16).tolist()
    arrivals = [(s * 2, shared + rng.integers(
        0, llm_off.config.vocab_size, n).tolist(), 5)
        for s, n in enumerate((4, 8, 6))]
    eng_off.run(arrivals=list(arrivals))
    eng_on.run(arrivals=list(arrivals))
    off = {r.req_id: r.output_tokens for r in eng_off.metrics.finished}
    on = {r.req_id: r.output_tokens for r in eng_on.metrics.finished}
    assert off == on  # recording never steers the run
    llm_on.close()
    llm_off.close()


# ---------------------------------------------------------------------------
# DeadlinePreemption (satellite): SLO eviction, recorded + replayable
# ---------------------------------------------------------------------------

def test_deadline_preemption_frees_lane_for_ontime_work(tmp_path):
    bundle = str(tmp_path / "preempt")
    rt = RuntimeConfig(
        reduced=True,
        kv=KVConfig(cache_len=64),
        scheduler=SchedulerConfig(n_slots=1, eviction="deadline-preempt"),
        obs=ObsConfig(record_path=bundle),
    )
    llm = LLM(arch="llama3.2-1b", runtime=rt)
    eng = llm.engine
    assert isinstance(llm._policies.eviction, DeadlinePreemption)
    rng = np.random.default_rng(0)
    doomed = eng.add_request(
        rng.integers(0, llm.config.vocab_size, 8).tolist(), 32,
        sampling=SamplingParams(deadline_s=1e-6))  # missed before it starts
    ontime = eng.add_request(
        rng.integers(0, llm.config.vocab_size, 8).tolist(), 4)
    eng.run()
    # the doomed lane was preempted (not run to its 32-token budget) so
    # the on-time request could have the only slot
    assert doomed.finish_reason == "deadline"
    assert len(doomed.output_tokens) < 32
    assert len(ontime.output_tokens) == 4
    assert eng.metrics.deadline_preempt == 1
    evicted = [e for e in llm.obs.events.events if e["kind"] == "evicted"]
    assert len(evicted) == 1
    assert evicted[0]["req_id"] == doomed.req_id
    assert evicted[0]["reason"] == "deadline"
    assert eng.metrics.report()["deadline_preempt"] == 1
    llm.close()

    # the preemption is a *time-dependent* decision: replay must reproduce
    # it (same step, same lane) from the decision-clock tape alone
    res = replay_bundle(bundle)
    assert res.ok, res.summary()
    replays = [e for e in load_bundle(bundle).journal
               if e["kind"] == "evicted"]
    assert len(replays) == 1 and replays[0]["reason"] == "deadline"


def test_deadline_preemption_keeps_lane_when_nothing_waiting():
    # with an empty queue a late request keeps running: a late answer
    # beats an idle lane (the policy is work-conserving)
    rt = RuntimeConfig(
        reduced=True,
        kv=KVConfig(cache_len=64),
        scheduler=SchedulerConfig(n_slots=1, eviction="deadline-preempt"),
    )
    llm = LLM(arch="llama3.2-1b", runtime=rt)
    eng = llm.engine
    rng = np.random.default_rng(1)
    late = eng.add_request(
        rng.integers(0, llm.config.vocab_size, 8).tolist(), 4,
        sampling=SamplingParams(deadline_s=1e-6))
    eng.run()
    assert len(late.output_tokens) == 4  # ran to budget, never preempted
    assert eng.metrics.deadline_preempt == 0
    assert late.finish_reason == "length"
    llm.close()


def test_scheduler_config_rejects_unknown_eviction():
    with pytest.raises(ValueError, match="eviction"):
        SchedulerConfig(eviction="lru")


# ---------------------------------------------------------------------------
# /events endpoint (satellite)
# ---------------------------------------------------------------------------

def test_metrics_server_serves_event_tail(tmp_path):
    rt = RuntimeConfig(
        reduced=True,
        kv=KVConfig(cache_len=64),
        scheduler=SchedulerConfig(n_slots=2),
        obs=ObsConfig(enabled=True, metrics_port=0),
    )
    llm = LLM(arch="llama3.2-1b", runtime=rt)
    rng = np.random.default_rng(0)
    llm.generate([rng.integers(0, llm.config.vocab_size, 8).tolist()],
                 max_new_tokens=3)
    base = llm.metrics_server.url
    doc = json.loads(urllib.request.urlopen(base + "/events?n=2").read())
    assert doc["returned"] == 2
    assert doc["window"] == len(llm.obs.events)
    assert doc["events"] == list(llm.obs.events.events)[-2:]
    assert all({"seq", "t", "wall", "kind"} <= set(e)
               for e in doc["events"])
    # default tail without a query string
    doc = json.loads(urllib.request.urlopen(base + "/events").read())
    assert doc["returned"] == min(100, doc["window"]) > 0
    # malformed n -> 400, not a dead server
    with pytest.raises(urllib.error.HTTPError) as err:
        urllib.request.urlopen(base + "/events?n=bogus")
    assert err.value.code == 400
    json.loads(urllib.request.urlopen(base + "/snapshot").read())  # still up
    llm.close()
