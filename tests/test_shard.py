"""Tensor-parallel sharded serving (``repro/shard/``).

Correctness contract: at tp=1 the mesh adds size-1 axes only, so every
trace-time constraint is trivial and greedy outputs are BITWISE the
unsharded engine's, across every engine mode (slot / paged / prefix /
spec).  At tp>1 the row-parallel psums change float accumulation order,
so logits are allclose-not-bitwise — the tests assert greedy *token
parity* (deterministic per platform) plus page-pool invariants under
eviction/defrag and the decode step staying traced-once.

Multi-device cases force a host mesh; run with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (CI does).  With
fewer devices those tests skip, so the tier-1 suite stays green on a
plain single-device run.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (
    LLM,
    KVConfig,
    MeshConfig,
    RuntimeConfig,
    SchedulerConfig,
    SpecConfig,
)
from repro.configs import get_config
from repro.models import init_params
from repro.models.model import paged_cache_shapes
from repro.runtime.sharding import param_specs, pool_specs
from repro.shard import (
    build_mesh,
    make_host_mesh,
    mesh_axis_size,
    tree_device_bytes,
    validate_mesh_config,
)

needs_devices = lambda n: pytest.mark.skipif(
    jax.device_count() < n,
    reason=f"needs {n} devices (XLA_FLAGS=--xla_force_host_platform_"
           f"device_count={n})")


# -- config plumbing (no devices needed) ------------------------------------

def test_mesh_config_roundtrip():
    rt = RuntimeConfig(mesh=MeshConfig(tp=2, dp=1, enable=True))
    back = RuntimeConfig.from_dict(rt.to_dict())
    assert back.mesh == rt.mesh
    assert back.mesh.enabled
    # default config round-trips to the disabled mesh
    rt0 = RuntimeConfig.from_dict(RuntimeConfig().to_dict())
    assert rt0.mesh == MeshConfig() and not rt0.mesh.enabled


def test_mesh_config_validation():
    with pytest.raises(ValueError):
        MeshConfig(tp=0)
    with pytest.raises(ValueError):
        MeshConfig(axes=("model", "model"))
    # enable semantics: explicit enable=True at tp=1 builds a real mesh,
    # the default activates iff an axis exceeds 1
    assert MeshConfig(enable=True).enabled
    assert MeshConfig(tp=2).enabled
    assert not MeshConfig().enabled
    validate_mesh_config(MeshConfig(tp=2, enable=True))


def test_build_mesh_off_and_on():
    assert build_mesh(None) is None
    assert build_mesh(MeshConfig()) is None
    m = build_mesh(MeshConfig(enable=True))
    assert m is not None and m.shape == {"data": 1, "model": 1}
    assert mesh_axis_size(m, "model") == 1
    assert mesh_axis_size(None, "model") == 1


def test_host_mesh_device_count_error():
    """Satellite: asking for more devices than exist raises the actionable
    error (XLA_FLAGS hint), not jax's bare reshape failure."""
    need = jax.device_count() * 64
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_host_mesh(1, need)


# -- engine parity ----------------------------------------------------------

def _runtime(mesh_cfg, *, mode="slot", prefix=False, spec=False, chunk=None,
             n_slots=2, cache_len=64):
    kv = KVConfig(mode=mode, cache_len=cache_len, page_size=16,
                  prefix_cache=prefix)
    return RuntimeConfig(
        kv=kv,
        scheduler=SchedulerConfig(n_slots=n_slots, prefill_chunk=chunk),
        spec=SpecConfig(enabled=spec, k=3, drafter="ngram"),
        mesh=mesh_cfg, max_new_tokens=8, reduced=True)


def _serve(runtime, prompts, gen=8, arch="llama3.2-1b"):
    llm = LLM(arch=arch, runtime=runtime)
    engine = llm.build_engine(max(len(p) for p in prompts), gen)
    metrics = engine.run([(0, p, gen) for p in prompts])
    outs = [r.output_tokens
            for r in sorted(metrics.finished, key=lambda r: r.req_id)]
    return llm, engine, outs


def _prompts(cfg_vocab=512, shared=0, seed=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg_vocab, shared).tolist()
    return [pre + rng.integers(0, cfg_vocab, n).tolist()
            for n in (13, 5, 17)]


@pytest.mark.parametrize("mode_kw", [
    dict(mode="slot"),
    dict(mode="paged"),
    dict(mode="paged", prefix=True, chunk=16),
    dict(mode="paged", spec=True),
], ids=["slot", "paged", "paged+prefix", "paged+spec"])
def test_tp1_mesh_bitwise_unsharded(mode_kw):
    """Tentpole acceptance: a genuine 1x1 mesh (enable=True at tp=1) runs
    the whole sharded path — committed params, pool shardings, trace-time
    constraints — and greedy outputs are bitwise the unsharded engine's in
    every engine mode."""
    prompts = _prompts(shared=8 if mode_kw.get("prefix") else 0)
    _, _, base = _serve(_runtime(MeshConfig(), **mode_kw), prompts)
    _, _, meshed = _serve(_runtime(MeshConfig(enable=True), **mode_kw),
                          prompts)
    assert base == meshed, "tp=1 mesh changed greedy outputs"


@needs_devices(2)
@pytest.mark.parametrize("tp", [2, 4])
def test_tp_token_parity(tp):
    """tp>1 reorders the row-parallel reductions (allclose, not bitwise);
    greedy token streams must still match the unsharded engine on a
    forced host mesh (deterministic per platform, so not flaky)."""
    if jax.device_count() < tp or jax.device_count() % tp:
        pytest.skip(f"needs a multiple of {tp} devices")
    prompts = _prompts()
    _, _, base = _serve(_runtime(MeshConfig(), mode="paged"), prompts)
    _, _, shard = _serve(_runtime(MeshConfig(tp=tp), mode="paged"), prompts)
    assert base == shard, f"tp={tp} diverged from unsharded tokens"


@needs_devices(2)
def test_tp_decode_traced_once_and_single_dispatch():
    """Acceptance: under a tp=2 mesh the decode step still traces ONCE per
    engine lifetime (block tables are uploaded replicated, pools are
    committed, so admissions/evictions never retrace), i.e. decode remains
    one pjit dispatch per step."""
    prompts = _prompts()
    llm, engine, _ = _serve(_runtime(MeshConfig(tp=2), mode="paged"), prompts)
    fn = engine._decode_sample
    jitted = getattr(fn, "__wrapped__", fn)  # _with_mesh wraps the pjit fn
    n_traces = jitted._cache_size()
    assert n_traces >= 1
    engine.run([(0, p, 4) for p in _prompts(seed=1)])
    assert jitted._cache_size() == n_traces, "decode retraced under mesh"


@needs_devices(2)
def test_tp_pool_invariants_under_eviction_and_defrag():
    """The sharded page pool keeps the host-side PageManager's invariants
    through admission churn, eviction and defrag — the block tables stay
    host-authoritative with the device pools sharded under them."""
    rt = _runtime(MeshConfig(tp=2), mode="paged", chunk=16, n_slots=2)
    rt = dataclasses.replace(
        rt, scheduler=dataclasses.replace(rt.scheduler,
                                          defrag_threshold=0.1))
    prompts = _prompts() + _prompts(seed=3)  # > lanes: queueing + eviction
    llm = LLM(arch="llama3.2-1b", runtime=rt)
    engine = llm.build_engine(max(len(p) for p in prompts), 8)
    engine.run([(i, p, 8) for i, p in enumerate(prompts)])
    engine.store.manager.check_invariants()
    assert engine.metrics.defrag_count >= 0  # defrag path exercised or not,
    # invariants above are the real assertion
    # every pool leaf is committed to the mesh (not single-device)
    pools = engine.store.cache
    leaves = jax.tree_util.tree_leaves(pools)
    assert any(len(l.sharding.device_set) > 1 for l in leaves
               if hasattr(l, "sharding")), "no pool leaf spans the mesh"


# -- big-model footprint (analytic + reduced dryrun) ------------------------

@needs_devices(4)
def test_mistral_large_tp4_footprint_analytic():
    """Acceptance: at mistral-large-123b scale, tp=4 holds per-device
    params + paged KV below half the unsharded footprint — computed
    analytically over eval_shape trees (no 123B allocation)."""
    cfg = get_config("mistral-large-123b").with_(remat=False)
    mesh = make_host_mesh(1, 4)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pshapes = paged_cache_shapes(cfg, 8, 4096, 16, 2048)

    def total_bytes(tree):
        return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(tree))

    per_dev = (
        tree_device_bytes(shapes, param_specs(shapes, mesh, cfg, fsdp=False),
                          mesh)
        + tree_device_bytes(pshapes, pool_specs(pshapes, mesh), mesh))
    unsharded = total_bytes(shapes) + total_bytes(pshapes)
    assert per_dev < unsharded / 2, (
        f"tp=4 per-device {per_dev/2**30:.1f} GiB not < "
        f"{unsharded/2**31:.1f} GiB (half of unsharded)")
    # the dominant leaves really split 4-ways
    assert per_dev < unsharded / 3


@needs_devices(4)
def test_mistral_large_reduced_tp4_decodes():
    """The same arch at reduced size actually initializes, shards and
    decodes on the tp=4 host mesh end to end (paged engine)."""
    rt = _runtime(MeshConfig(tp=4), mode="paged", cache_len=64)
    llm, _, outs = _serve(rt, _prompts(), gen=4, arch="mistral-large-123b")
    assert [len(o) for o in outs] == [4, 4, 4]
    # params were committed across the mesh
    leaves = jax.tree_util.tree_leaves(llm.params)
    assert any(len(l.sharding.device_set) == 4 for l in leaves)
