"""MoE dispatch correctness: local-capacity sort-based dispatch vs the
dense every-expert reference, drop semantics, and the grouped int8 GEMM."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import moe as moe_mod


def _cfg(capacity_factor=8.0, quant_mode="bf16", num_experts=8, top_k=2):
    cfg = reduced(get_config("granite-moe-3b-a800m")).with_(quant_mode=quant_mode)
    moe = dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                              num_experts=num_experts, top_k=top_k)
    return cfg.with_(moe=moe)


@pytest.fixture()
def params_and_x():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(jax.random.fold_in(key, 1), cfg)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    return cfg, p, x


def test_matches_dense_reference_at_high_capacity(params_and_x):
    """With capacity >= S*k no token drops: the sparse dispatch must equal
    the dense every-expert reference exactly (same expert math)."""
    cfg, p, x = params_and_x
    sparse, _ = moe_mod.moe_ffn(x, p, cfg)
    dense = moe_mod.moe_ffn_reference(x, p, cfg)
    np.testing.assert_allclose(np.asarray(sparse, np.float32),
                               np.asarray(dense, np.float32),
                               rtol=5e-2, atol=5e-3)  # bf16 compute


def test_low_capacity_drops_gracefully(params_and_x):
    cfg, p, x = params_and_x
    tight = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    out, aux = moe_mod.moe_ffn(x, p, tight)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert np.isfinite(float(aux))
    # dropped tokens fall back to (shared expert + residual-zero), so the
    # output magnitude shrinks but never explodes
    full, _ = moe_mod.moe_ffn(x, p, cfg)
    assert (np.abs(np.asarray(out, np.float32)).mean()
            <= np.abs(np.asarray(full, np.float32)).mean() * 1.5 + 1e-3)


def test_capacity_is_per_row(params_and_x):
    """Routing is batch-local: permuting batch rows permutes outputs."""
    cfg, p, x = params_and_x
    out, _ = moe_mod.moe_ffn(x, p, cfg)
    out_swapped, _ = moe_mod.moe_ffn(x[::-1], p, cfg)
    np.testing.assert_allclose(np.asarray(out_swapped, np.float32),
                               np.asarray(out, np.float32)[::-1],
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["int8_spoga", "int8_deas", "int8_direct"])
def test_grouped_int8_modes_agree(params_and_x, mode):
    """Expert GEMMs under the three int8 dataflows are identical."""
    cfg, p, x = params_and_x
    ref, _ = moe_mod.moe_ffn(x, p, cfg.with_(quant_mode="int8_spoga"))
    got, _ = moe_mod.moe_ffn(x, p, cfg.with_(quant_mode=mode))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux == 1 (Switch normalization)."""
    cfg = _cfg()
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    t = 4096
    probs = jnp.full((t, e), 1.0 / e)
    topi = jnp.tile(jnp.arange(k)[None, :], (t, 1))
    # replicate the formula on synthetic stats
    dispatch_frac = jnp.mean(jax.nn.one_hot(topi, e).sum(1), axis=0)
    aux = e * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0)) / k
    assert abs(float(aux) - 1.0) < 1e-5
