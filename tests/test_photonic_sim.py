"""Paper-claim validation: Table I (exact) and Fig. 5 headline ratios."""

import math

import pytest

from repro.core.accelerator_sim import (
    ACCELS, PAPER_RATIOS, fig5_comparison, headline_ratios, simulate,
)
from repro.core.photonic_model import PAPER_TABLE_I, scalability_table
from repro.core.workloads import CNNS, cnn_gemm_trace, total_macs

# Published ImageNet-224 MAC counts (within 10% — arch variants differ in
# counting of downsample/aux paths).
PUBLISHED_GMACS = {
    "mobilenet_v2": 0.30,
    "shufflenet_v2": 0.146,
    "resnet50": 4.1,
    "googlenet": 1.5,
}


class TestTableI:
    def test_all_15_cells_exact(self):
        table = scalability_table()
        for row, cells in PAPER_TABLE_I.items():
            for dr, expected in cells.items():
                assert table[row][dr] == expected, (row, dr)


class TestWorkloads:
    @pytest.mark.parametrize("name", list(CNNS))
    def test_mac_counts_near_published(self, name):
        got = total_macs(name) / 1e9
        pub = PUBLISHED_GMACS[name]
        assert 0.6 * pub <= got <= 1.25 * pub, f"{name}: {got:.3f} vs {pub}"

    @pytest.mark.parametrize("name", list(CNNS))
    def test_trace_wellformed(self, name):
        for g in cnn_gemm_trace(name):
            assert g.m > 0 and g.k > 0 and g.n > 0 and g.groups >= 1


class TestFig5:
    @pytest.fixture(scope="class")
    def comparison(self):
        return fig5_comparison()

    def test_headline_ratios_within_band(self, comparison):
        """Every paper ratio reproduced within +-35% (simulator internals
        of the paper are not public; see EXPERIMENTS.md for the exact
        residuals, most are within 15%)."""
        for key, vals in headline_ratios(comparison).items():
            lo, hi = 0.65 * vals["paper"], 1.35 * vals["paper"]
            assert lo <= vals["ours"] <= hi, f"{key}: {vals}"

    def test_spoga_beats_baselines_everywhere(self, comparison):
        """The paper's qualitative claim: SPOGA wins FPS and FPS/W at every
        data rate."""
        for dr in (1, 5, 10):
            s = comparison[f"SPOGA_{dr}"]["gmean"]
            for base in ("DEAPCNN", "HOLYLIGHT"):
                b = comparison[f"{base}_{dr}"]["gmean"]
                assert s["fps"] > b["fps"]
                assert s["fps_per_w"] > b["fps_per_w"]

    def test_conversion_count_structure(self):
        """Sec. III-B: SPOGA needs 1 ADC conversion per dot product; the
        bit-sliced baseline needs 4 per chunk plus SRAM round trips."""
        s = simulate(ACCELS["SPOGA_10"], "resnet50")
        d = simulate(ACCELS["DEAPCNN_10"], "resnet50")
        dots = sum(g.dots * g.groups * g.repeat for g in cnn_gemm_trace("resnet50"))
        assert s.adc_samples == dots
        assert d.adc_samples >= 4 * dots          # >= 4x: chunked + sliced
        assert d.sram_bytes > 8 * s.sram_bytes    # intermediate round trips
        assert d.deas_ops > 0 and s.deas_ops == 0

    def test_fps_monotone_in_datarate_for_spoga(self, comparison):
        fps = [comparison[f"SPOGA_{dr}"]["gmean"]["fps"] for dr in (1, 5, 10)]
        assert fps[0] < fps[1] < fps[2]


def test_gmean_sanity():
    assert math.isclose(
        math.exp(sum(map(math.log, [2.0, 8.0])) / 2), 4.0
    )
