"""Checkpointing: atomicity, bit-identical restore, GC, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (8, 16), jnp.float32),
        "b": jax.random.normal(k2, (16,), jnp.bfloat16),
        "step": jnp.asarray(3, jnp.int32),
        "nested": {"m": jnp.ones((4, 4), jnp.float32)},
    }


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_bit_identical(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, tree)
    step, restored, _ = restore_checkpoint(str(tmp_path), None, tree)
    assert step == 7
    _assert_trees_equal(tree, restored)


def test_latest_step_and_overwrite(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5


def test_structure_mismatch_rejected(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1, {"different": tree["w"]})


def test_no_partial_checkpoint_on_disk(tmp_path):
    """Atomic rename: only final step_* dirs are ever visible."""
    tree = _tree(jax.random.PRNGKey(3))
    save_checkpoint(str(tmp_path), 2, tree)
    entries = os.listdir(tmp_path)
    assert all(e.startswith("step_") for e in entries), entries


def test_manager_gc_keeps_last_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = _tree(jax.random.PRNGKey(4))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_async_save_visible_after_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=True)
    tree = _tree(jax.random.PRNGKey(5))
    mgr.save(11, tree)
    mgr.wait()
    step, restored, _ = mgr.restore_latest(tree)
    assert step == 11
    _assert_trees_equal(tree, restored)


def test_elastic_reshard_restore(tmp_path):
    """Restore with explicit (different) shardings — the elastic-restart
    path.  On one device this degenerates to replicated placement, but the
    device_put path and dtype round trip are exercised identically."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree(jax.random.PRNGKey(6))
    save_checkpoint(str(tmp_path), 9, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree
    )
    step, restored, _ = restore_checkpoint(str(tmp_path), 9, tree, shardings)
    _assert_trees_equal(tree, restored)
    for leaf in jax.tree_util.tree_leaves(restored):
        assert leaf.sharding.mesh.shape == {"data": 1}
