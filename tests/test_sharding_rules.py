"""Sharding-rule invariants (property-tested): every generated
PartitionSpec (a) never repeats a mesh axis, (b) only shards divisible
dims, (c) has rank <= leaf rank.  This family of bugs (ZeRO-1 stacking
"data" onto an FSDP-sharded dim) broke 8 dry-run cells once — see git
history of runtime/sharding.py."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as model_lib
from repro.optim.optimizers import adamw_init
from repro.runtime import sharding as shard_lib


def _check_specs(specs_tree, shapes_tree, mesh):
    flat_specs = jax.tree_util.tree_leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_shapes = jax.tree_util.tree_leaves(shapes_tree)
    assert len(flat_specs) == len(flat_shapes)
    for spec, leaf in zip(flat_specs, flat_shapes):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        seen = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                assert a not in seen, f"duplicate axis {a} in {spec}"
                seen.append(a)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, f"{spec} does not divide {leaf.shape}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_and_opt_specs_valid(arch):
    cfg = get_config(arch)
    mesh = make_host_mesh(1, 1)  # axis names matter, sizes=1 never divide-fail

    # use a *virtual* mesh shape by checking against the production sizes:
    # re-create specs against a fake mesh object with the production shape.
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    shapes = model_lib.param_shapes(cfg)
    p_specs = shard_lib.param_specs(shapes, FakeMesh, cfg, fsdp=True)
    _check_specs(p_specs, shapes, FakeMesh)

    opt_shapes = jax.eval_shape(adamw_init, shapes)
    o_specs = shard_lib.opt_state_specs(opt_shapes, p_specs, FakeMesh, zero1=True)
    _check_specs(
        o_specs["m"], opt_shapes["m"], FakeMesh)
    _check_specs(
        o_specs["master"], opt_shapes["master"], FakeMesh)


def test_cache_specs_valid():
    cfg = get_config("mistral-large-123b")

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    shapes = model_lib.cache_shapes(cfg, batch=128, cache_len=32768)
    specs = shard_lib.cache_specs(shapes, FakeMesh)
    _check_specs(specs, shapes, FakeMesh)


def test_collective_stats_parser():
    from repro.launch.dryrun import collective_stats

    hlo = """
  %ag = bf16[128,1024]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[256]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[64,32]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = u32[16]{0} collective-permute(%w)
  %not_a_collective = f32[9999]{0} add(%a, %b)
"""
    s = collective_stats(hlo)
    assert s["all-gather"] == {"count": 1, "bytes": 128 * 1024 * 2}
    assert s["all-reduce"] == {"count": 1, "bytes": 256 * 4}
    assert s["reduce-scatter"] == {"count": 1, "bytes": 64 * 32 * 4}
    assert s["collective-permute"] == {"count": 1, "bytes": 16 * 4}
    assert s["total_bytes"] == sum(
        v["bytes"] for k, v in s.items() if k != "total_bytes")
