"""The fleet-telemetry layer (PR 8): Prometheus exposition + live
``/metrics`` server, SLO deadline / goodput / per-request cost accounting,
the numerics watchdog, per-lane trace tracks, the streaming event sink,
and the bench_check fresh-trajectory behaviour.

The two engine-level invariants extend to the new layer: the watchdog
adds zero host syncs when off (no ``debug_callback`` in the jaxpr) and is
bitwise output-invisible when on; the metrics server only *polls*
registries, so a scrape mid-run perturbs nothing.
"""

import json
import os
import pathlib
import subprocess
import sys
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.api import (
    LLM,
    KVConfig,
    ObsConfig,
    RuntimeConfig,
    SchedulerConfig,
    SpecConfig,
)
from repro.api.config import QuantRuntime
from repro.backends.pipeline import quantized_linear
from repro.configs import get_config
from repro.obs import watchdog
from repro.obs.events import EventLog
from repro.obs.metrics import MetricsRegistry, labeled, split_labels
from repro.obs.server import (
    CONTENT_TYPE,
    MetricsServer,
    render_exposition,
    validate_exposition,
)
from repro.obs.trace import Tracer
from repro.serving.metrics import EngineMetrics
from repro.serving.request import Request, RequestCost
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Scheduler


# ---------------------------------------------------------------------------
# labeled registry keys
# ---------------------------------------------------------------------------

def test_labeled_keys_roundtrip_and_sort():
    key = labeled("watchdog_amax", mode="w4a4", layer="decode.00")
    # label keys are sorted so the same label set always yields one key
    assert key == 'watchdog_amax{layer="decode.00",mode="w4a4"}'
    base, labels = split_labels(key)
    assert base == "watchdog_amax"
    assert labels == {"layer": "decode.00", "mode": "w4a4"}
    assert labeled("plain") == "plain"
    assert split_labels("plain") == ("plain", {})


# ---------------------------------------------------------------------------
# Prometheus text exposition: renderer + validator
# ---------------------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry()
    reg.inc("steps", 3)
    reg.inc(labeled("watchdog_act_sat", layer="decode.00", mode="w4a4"), 7)
    reg.set("pages_total", 16)
    for v in (0.001, 0.01, 0.02, 0.5):
        reg.observe("ttft_s", v)
    reg.observe(labeled("watchdog_amax", layer="decode.00", mode="w4a4"), 2.5)
    return reg


def test_render_exposition_is_valid_and_complete():
    text = render_exposition([_populated_registry()],
                             {"tokens_per_second": 12.5})
    assert validate_exposition(text) == []
    assert "# TYPE repro_steps_total counter" in text
    assert "repro_steps_total 3" in text
    # labels survive rendering, attached to the family name
    assert ('repro_watchdog_act_sat_total{layer="decode.00",mode="w4a4"} 7'
            in text)
    assert "# TYPE repro_pages_total gauge" in text
    assert "repro_tokens_per_second 12.5" in text
    # histograms render as native cumulative buckets ending at +Inf
    assert "# TYPE repro_ttft_s histogram" in text
    assert 'repro_ttft_s_bucket{le="+Inf"} 4' in text
    assert "repro_ttft_s_count 4" in text
    assert "repro_ttft_s_sum" in text
    # the labeled histogram keeps its labels alongside le
    assert 'repro_watchdog_amax_bucket{layer="decode.00",le=' in text


def test_render_exposition_merges_registries_and_prefix():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("from_a")
    b.inc("from_b")
    text = render_exposition([a, b], prefix="x")
    assert "x_from_a_total 1" in text and "x_from_b_total 1" in text
    assert validate_exposition(text) == []


def test_validator_rejects_malformed_exposition():
    assert validate_exposition("name with spaces 1\n")
    assert validate_exposition("x_total 1\n")  # sample without TYPE
    assert validate_exposition("# TYPE c counter\nc_total -1\n")  # negative
    # le must increase and buckets must be cumulative, ending at +Inf == count
    bad_order = ('# TYPE h histogram\nh_bucket{le="1"} 5\n'
                 'h_bucket{le="0.5"} 6\nh_bucket{le="+Inf"} 6\n'
                 "h_sum 1\nh_count 6\n")
    assert any("le not increasing" in e for e in validate_exposition(bad_order))
    shrinking = ('# TYPE h histogram\nh_bucket{le="1"} 5\n'
                 'h_bucket{le="2"} 3\nh_bucket{le="+Inf"} 5\n'
                 "h_sum 1\nh_count 5\n")
    assert any("not cumulative" in e for e in validate_exposition(shrinking))
    no_inf = ('# TYPE h histogram\nh_bucket{le="1"} 5\n'
              "h_sum 1\nh_count 5\n")
    assert any("missing +Inf" in e for e in validate_exposition(no_inf))
    mismatch = ('# TYPE h histogram\nh_bucket{le="+Inf"} 4\n'
                "h_sum 1\nh_count 5\n")
    assert any("!= _count" in e for e in validate_exposition(mismatch))
    no_sum = ('# TYPE h histogram\nh_bucket{le="+Inf"} 5\nh_count 5\n')
    assert any("missing _sum" in e for e in validate_exposition(no_sum))


# ---------------------------------------------------------------------------
# the HTTP frontend
# ---------------------------------------------------------------------------

def test_metrics_server_endpoints():
    reg = _populated_registry()
    srv = MetricsServer(lambda: ([reg], {"up": 1.0}), port=0).start()
    try:
        assert srv.port and srv.url.endswith(str(srv.port))
        with urllib.request.urlopen(srv.url + "/metrics") as resp:
            assert resp.headers["Content-Type"] == CONTENT_TYPE
            body = resp.read().decode()
        assert validate_exposition(body) == []
        assert "repro_up 1" in body and "repro_steps_total 3" in body

        with urllib.request.urlopen(srv.url + "/healthz") as resp:
            assert resp.read() == b"ok\n"

        with urllib.request.urlopen(srv.url + "/snapshot") as resp:
            doc = json.loads(resp.read())
        assert doc["derived"] == {"up": 1.0}
        assert doc["registries"][0]["counters"]["steps"] == 3

        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/nope")
        assert ei.value.code == 404
    finally:
        srv.close()
    srv.close()  # idempotent


def test_metrics_server_collector_failure_is_500_not_crash():
    def broken():
        raise RuntimeError("collector exploded")

    srv = MetricsServer(broken, port=0).start()
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/metrics")
        assert ei.value.code == 500
        # the server survives a broken scrape
        with urllib.request.urlopen(srv.url + "/healthz") as resp:
            assert resp.read() == b"ok\n"
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# SLO deadline / goodput / cost accounting (host-side units)
# ---------------------------------------------------------------------------

def test_sampling_params_deadline_validation():
    assert SamplingParams(deadline_s=1.5).deadline_s == 1.5
    assert SamplingParams().deadline_s is None
    with pytest.raises(ValueError):
        SamplingParams(deadline_s=0.0)


def test_deadline_goodput_and_cost_accounting():
    m = EngineMetrics()
    m.begin()
    now = time.perf_counter()

    hit = Request(req_id=0, prompt=[1], max_new_tokens=2, deadline_s=1000.0)
    hit.submit_time = now
    hit.output_tokens = [5, 6]
    hit.cost = RequestCost(prefill_s=0.2, decode_s=0.1, dispatches=3,
                           page_steps=4)
    m.record_finished(hit)

    miss = Request(req_id=1, prompt=[1], max_new_tokens=1, deadline_s=1e-9)
    miss.submit_time = now - 1.0
    miss.late_at_admission = True
    miss.output_tokens = [7]
    m.record_finished(miss)

    free = Request(req_id=2, prompt=[1], max_new_tokens=3)  # no deadline
    free.submit_time = now
    free.output_tokens = [1, 2, 3]
    m.record_finished(free)

    assert hit.deadline_hit is True
    assert miss.deadline_hit is False
    assert free.deadline_hit is None
    assert m.deadline_hits == 1 and m.deadline_misses == 1
    assert m.deadline_late_admissions == 1
    # goodput: deadline-respecting tokens — the miss's token drops out,
    # the no-deadline request always counts
    assert m.goodput_tokens == 2 + 3
    rep = m.report()
    assert rep["deadline_hit_rate"] == 0.5
    assert rep["goodput_tokens"] == 5
    assert rep["goodput_tokens_per_s"] <= rep["tokens_per_s"]
    assert rep["cost_prefill_p99_s"] == pytest.approx(0.2)
    assert rep["cost_decode_p99_s"] == pytest.approx(0.1)

    # no deadlines at all -> hit rate is None, goodput == throughput
    m2 = EngineMetrics()
    m2.begin()
    free2 = Request(req_id=0, prompt=[1], max_new_tokens=1)
    free2.submit_time = time.perf_counter()
    free2.output_tokens = [9]
    m2.record_finished(free2)
    r2 = m2.report()
    assert r2["deadline_hit_rate"] is None
    assert r2["goodput_tokens"] == r2["generated_tokens"] == 1


def test_scheduler_stamps_late_at_admission():
    sched = Scheduler(n_slots=2)
    doomed = Request(req_id=0, prompt=[1], max_new_tokens=1, deadline_s=1e-6)
    doomed.submit_time = time.perf_counter() - 1.0
    fine = Request(req_id=1, prompt=[1], max_new_tokens=1, deadline_s=100.0)
    fine.submit_time = time.perf_counter()
    sched.submit(doomed)
    sched.submit(fine)
    admitted = sched.schedule(limit=2)
    assert len(admitted) == 2
    assert doomed.late_at_admission is True
    assert fine.late_at_admission is False


# ---------------------------------------------------------------------------
# numerics watchdog: direct pipeline surface
# ---------------------------------------------------------------------------

def _crafted_near_clamp():
    """Half the activation entries sit AT the dynamic-quant rail: with an
    absmax scale, every |x| == amax element maps exactly onto +-qmax."""
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((8, 32)) * 0.01).astype(np.float32)
    x[:, ::2] = np.where(np.arange(8)[:, None] % 2 == 0, 1.0, -1.0)
    w = rng.standard_normal((32, 16)).astype(np.float32) * 0.1
    return x, w


def test_watchdog_off_leaves_jaxpr_clean():
    x, w = _crafted_near_clamp()
    plain = str(jax.make_jaxpr(
        lambda a, b: quantized_linear(a, b, "w4a4"))(x, w))
    assert "debug_callback" not in plain
    watched = str(jax.make_jaxpr(
        lambda a, b: quantized_linear(a, b, "w4a4", watch=True))(x, w))
    assert "debug_callback" in watched


def test_watchdog_saturation_counter_fires_and_is_output_invisible():
    watchdog.reset()
    x, w = _crafted_near_clamp()
    f_plain = jax.jit(lambda a, b: quantized_linear(a, b, "w4a4"))
    f_watch = jax.jit(lambda a, b: quantized_linear(
        a, b, "w4a4", watch=True, layer="crafted"))
    y_plain = np.asarray(f_plain(x, w))
    y_watch = np.asarray(f_watch(x, w))
    jax.effects_barrier()
    # bitwise invisible: the callback observes, never feeds the output
    np.testing.assert_array_equal(y_plain, y_watch)

    reg = watchdog.peek_registry()
    assert reg is not None
    key = labeled("watchdog_act_sat", layer="crafted", mode="w4a4")
    n_key = labeled("watchdog_act_elems", layer="crafted", mode="w4a4")
    sat = reg.counters[key].value
    n = reg.counters[n_key].value
    assert n == x.size
    # half the entries were crafted onto the rail
    assert sat / n == pytest.approx(0.5, abs=0.1)
    assert watchdog.saturation_report()[
        'layer="crafted",mode="w4a4"'] == pytest.approx(sat / n)
    # amax / quant-error / accumulator-headroom histograms observed too
    amax_key = labeled("watchdog_amax", layer="crafted", mode="w4a4")
    assert reg.histograms[amax_key].total >= 1
    assert reg.histograms[amax_key].max == pytest.approx(1.0)
    acc_key = labeled("watchdog_acc_bits", layer="crafted", mode="w4a4")
    assert 0 < reg.histograms[acc_key].max <= 33
    watchdog.reset()
    assert watchdog.peek_registry() is None


def test_runtime_config_arms_model_watchdog_flag():
    base = get_config("llama3.2-1b")
    assert not RuntimeConfig().resolve_model(base).numerics_watchdog
    armed = RuntimeConfig(obs=ObsConfig(watchdog=True)).resolve_model(base)
    assert armed.numerics_watchdog
    # jit keying: the armed config must hash differently
    assert hash(armed) != hash(RuntimeConfig().resolve_model(base))


# ---------------------------------------------------------------------------
# live end-to-end: paged + prefix + spec run, scraped mid-flight
# ---------------------------------------------------------------------------

def _telemetry_runtime(watchdog_on: bool, port=None) -> RuntimeConfig:
    return RuntimeConfig(
        reduced=True,
        quant=QuantRuntime(mode="w4a4"),
        kv=KVConfig(mode="paged", page_size=8, prefix_cache=True),
        scheduler=SchedulerConfig(n_slots=2, prefill_chunk=8),
        spec=SpecConfig(enabled=True, k=2, drafter="ngram"),
        obs=ObsConfig(watchdog=watchdog_on, metrics_port=port),
    )


def _prompts(cfg):
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 16).tolist()
    return [shared + rng.integers(0, cfg.vocab_size, n).tolist()
            for n in (5, 9, 3)]


def test_live_scrape_watchdog_parity_and_cost():
    watchdog.reset()
    # reference run: watchdog OFF (the untraced, callback-free graph)
    llm_off = LLM(arch="llama3.2-1b", runtime=_telemetry_runtime(False))
    outs_off = llm_off.generate(_prompts(llm_off.config), max_new_tokens=6)
    assert llm_off.metrics_server is None
    assert watchdog.peek_registry() is None  # off records NOTHING

    # instrumented run: watchdog ON + live metrics server, driven step by
    # step so /metrics is scraped MID-run with requests still in flight
    llm = LLM(arch="llama3.2-1b", runtime=_telemetry_runtime(True, port=0))
    assert llm.config.numerics_watchdog
    engine = llm.build_engine(25, 6)
    sp = SamplingParams(deadline_s=120.0)
    reqs = [engine.add_request(p, 6, sampling=sp)
            for p in _prompts(llm.config)]
    assert all(r.deadline_s == 120.0 for r in reqs)

    url = llm.metrics_server.url
    mid = None
    while engine.has_work:
        engine.step()
        if mid is None:
            mid = urllib.request.urlopen(url + "/metrics").read().decode()
    assert mid is not None
    assert validate_exposition(mid) == []
    assert "repro_steps_total" in mid

    final = urllib.request.urlopen(url + "/metrics").read().decode()
    assert validate_exposition(final) == []
    # the ISSUE's named series: TTFT/per-token histograms, goodput,
    # per-layer saturation counters — all from one live run
    assert "# TYPE repro_ttft_s histogram" in final
    assert 'repro_ttft_s_bucket{le="+Inf"} 3' in final
    assert "# TYPE repro_per_token_s histogram" in final
    assert "repro_goodput_tokens_total" in final
    assert "repro_deadline_hits_total 3" in final
    assert "repro_watchdog_act_sat_total" in final
    assert 'mode="w4a4"' in final
    assert "repro_goodput_tokens_per_second" in final
    assert "repro_tokens_per_second" in final

    # bitwise parity: the watchdog's debug callbacks never change tokens
    assert ([r.output_tokens for r in reqs]
            == [o.token_ids for o in outs_off])

    sat = watchdog.saturation_report()
    assert sat and all(0.0 <= v <= 1.0 for v in sat.values())
    # scanned-layer labels carry the entry-point tag
    assert any(k.startswith('layer="prefill.') or k.startswith('layer="verify.')
               or k.startswith('layer="decode.') for k in sat)

    # per-request cost attribution reached the finished requests
    for r in reqs:
        assert r.deadline_hit is True
        assert r.cost.dispatches >= 1
        assert r.cost.prefill_s > 0
        assert r.cost.page_steps > 0  # paged run holds pages every step
    # goodput accounting: every request hit its generous deadline
    m = engine.metrics
    assert m.deadline_hits == 3 and m.deadline_misses == 0
    assert m.goodput_tokens == sum(len(r.output_tokens) for r in reqs)

    llm.close()
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(url + "/healthz", timeout=1)
    watchdog.reset()


def test_generate_outputs_carry_deadline_and_cost():
    runtime = RuntimeConfig(
        reduced=True,
        kv=KVConfig(mode="slot", cache_len=32),
        scheduler=SchedulerConfig(n_slots=2),
    )
    llm = LLM(arch="llama3.2-1b", runtime=runtime)
    sp = SamplingParams(deadline_s=300.0)
    outs = llm.generate([[1, 2, 3, 4]], sampling=sp, max_new_tokens=4)
    assert outs[0].deadline_hit is True
    assert outs[0].cost is not None
    assert outs[0].cost["dispatches"] >= 1
    assert outs[0].cost["prefill_s"] > 0
    # no deadline -> None outcome, cost still attributed
    outs2 = llm.generate([[1, 2, 3, 4]], max_new_tokens=4)
    assert outs2[0].deadline_hit is None
    assert outs2[0].cost["dispatches"] >= 1


# ---------------------------------------------------------------------------
# per-lane trace tracks
# ---------------------------------------------------------------------------

def test_tracer_mirrors_spans_onto_lane_tracks():
    tr = Tracer()
    with tr.span("decode", lanes=[0, 2], batch=2):
        pass
    with tr.span("prefill", lane=1):
        pass
    with tr.span("step"):  # lane-free spans stay engine-only
        pass
    engine_evs = [e for e in tr.events if e["tid"] == 1]
    lane_evs = [e for e in tr.events if e["tid"] != 1]
    assert [e["name"] for e in engine_evs] == ["decode", "prefill", "step"]
    # tid = slot + 2 (tid 1 is the engine stack)
    assert sorted((e["tid"], e["name"], e["args"]["lane"])
                  for e in lane_evs) == [
        (2, "decode", 0), (3, "prefill", 1), (4, "decode", 2)]
    assert all(e["cat"] == "lane" for e in lane_evs)
    # the mirror copies the span's own timing and args
    dec = engine_evs[0]
    for lane_ev in (e for e in lane_evs if e["name"] == "decode"):
        assert lane_ev["ts"] == dec["ts"] and lane_ev["dur"] == dec["dur"]
        assert lane_ev["args"]["batch"] == 2

    doc = tr.to_chrome()
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    names = {e["tid"]: e["args"]["name"] for e in metas
             if e["name"] == "thread_name"}
    assert names == {1: "engine", 2: "lane 0", 3: "lane 1", 4: "lane 2"}


# ---------------------------------------------------------------------------
# streaming event sink with rotation
# ---------------------------------------------------------------------------

def test_event_log_streams_and_rotates(tmp_path):
    path = tmp_path / "events.jsonl"
    log = EventLog(stream_path=str(path), max_bytes=2048, keep=16)
    for i in range(200):
        log.emit("tick", req_id=i, payload="x" * 32)
    assert log.rotations >= 1
    rotated = pathlib.Path(str(path) + ".1")
    assert rotated.exists()
    # in-memory window stays bounded; file lines stay valid JSONL
    assert len(log) == 16
    log.close()
    # disk stays bounded at ~2x max_bytes: only current + one rotation
    for p in (path, rotated):
        assert p.stat().st_size <= 2 * log.max_bytes
        for line in p.read_text().splitlines():
            ev = json.loads(line)
            assert ev["kind"] == "tick" and "seq" in ev
    # rotation renames whole files between line writes — the current file's
    # first line continues exactly where the rotated file ended
    current_lines = path.read_text().splitlines()
    if current_lines:
        last_rotated = json.loads(rotated.read_text().splitlines()[-1])["seq"]
        assert json.loads(current_lines[0])["seq"] == last_rotated + 1
    # timeline queries serve from the bounded window
    assert log.timeline(199)[0]["req_id"] == 199

    # to_jsonl on the stream path is a flush, not a rewrite
    log2 = EventLog(stream_path=str(tmp_path / "s.jsonl"))
    log2.emit("a")
    assert log2.to_jsonl(str(tmp_path / "s.jsonl")) == str(tmp_path / "s.jsonl")
    assert (tmp_path / "s.jsonl").read_text().count("\n") == 1
    log2.close()
    with pytest.raises(ValueError):
        EventLog(stream_path=str(tmp_path / "bad.jsonl"), max_bytes=0)


def test_obs_config_builds_streaming_sink(tmp_path):
    path = tmp_path / "ev.jsonl"
    obs = ObsConfig(events=str(path), events_max_mb=1.0).build()
    assert isinstance(obs.events, EventLog)
    assert obs.events.stream_path == str(path)
    assert obs.events.max_bytes == 2 ** 20
    obs.events.emit("hello", req_id=0)
    obs.close()  # closes the stream handle
    assert json.loads(path.read_text().splitlines()[0])["kind"] == "hello"
    with pytest.raises(ValueError):
        ObsConfig(events_max_mb=0)
    with pytest.raises(ValueError):
        ObsConfig(metrics_port=70000)


# ---------------------------------------------------------------------------
# bench_check: fresh trajectories exit cleanly, corruption still fails
# ---------------------------------------------------------------------------

_BENCH_CHECK = (pathlib.Path(__file__).parent.parent / "benchmarks"
                / "bench_check.py")


def _run_bench_check(*files):
    return subprocess.run(
        [sys.executable, str(_BENCH_CHECK), *map(str, files)],
        capture_output=True, text=True)


def test_bench_check_skips_missing_and_empty(tmp_path):
    missing = tmp_path / "BENCH_nope.json"
    r = _run_bench_check(missing)
    assert r.returncode == 0
    assert "fresh trajectory" in r.stdout

    empty = tmp_path / "BENCH_empty.json"
    empty.write_text("")
    r = _run_bench_check(empty)
    assert r.returncode == 0
    assert "fresh trajectory" in r.stdout


def test_bench_check_fails_on_corrupt_and_gates_goodput(tmp_path):
    corrupt = tmp_path / "BENCH_bad.json"
    corrupt.write_text("{not json")
    r = _run_bench_check(corrupt)
    assert r.returncode == 1
    assert "FAIL" in r.stdout

    # goodput_frac_overload gates like the other ratio headlines: a run
    # regressing >15% below the trailing median fails
    runs = [{"platform": "cpu", "goodput_frac_overload": v}
            for v in (0.8, 0.8, 0.8, 0.4)]
    traj = tmp_path / "BENCH_goodput.json"
    traj.write_text(json.dumps({"runs": runs}))
    r = _run_bench_check(traj)
    assert r.returncode == 1
    assert "goodput_frac_overload" in r.stdout
    runs[-1]["goodput_frac_overload"] = 0.79
    traj.write_text(json.dumps({"runs": runs}))
    assert _run_bench_check(traj).returncode == 0


def test_bench_check_writes_github_step_summary(tmp_path):
    runs = [{"platform": "cpu", "goodput_frac_overload": v}
            for v in (0.8, 0.8, 0.8, 0.4)]
    traj = tmp_path / "BENCH_goodput.json"
    traj.write_text(json.dumps({"runs": runs}))
    summary = tmp_path / "step_summary.md"
    env = dict(os.environ, GITHUB_STEP_SUMMARY=str(summary))
    r = subprocess.run(
        [sys.executable, str(_BENCH_CHECK), str(traj)],
        capture_output=True, text=True, env=env)
    assert r.returncode == 1
    table = summary.read_text()
    assert "## Benchmark regression gate" in table
    assert "| file | metric |" in table
    assert "`goodput_frac_overload`" in table and "FAIL" in table
    # appends (never truncates someone else's summary), and an unset env
    # var means no file side effects at all
    subprocess.run([sys.executable, str(_BENCH_CHECK), str(traj)],
                   capture_output=True, text=True, env=env)
    assert table * 2 == summary.read_text()
