"""Gradient-compression collective: int8 psum == fp32 psum within quant error."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime.collectives import compressed_psum_mean, psum_mean, shard_map

pytestmark = pytest.mark.skipif(
    jax.device_count() < 1, reason="needs at least one device")


def _run_shardmap(fn, n_dev, *args):
    mesh = jax.make_mesh((n_dev,), ("data",))
    sharded = shard_map(
        fn, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    return sharded(*args)


@pytest.mark.parametrize("shape", [(4, 8), (3, 5, 7)])
def test_compressed_matches_exact_within_quant_error(shape):
    n_dev = jax.device_count()
    key = jax.random.PRNGKey(0)
    # per-shard gradients with heterogeneous magnitude
    g = jax.random.normal(key, (n_dev,) + shape, jnp.float32) * 0.3

    exact = _run_shardmap(
        functools.partial(psum_mean, axis_name="data"), n_dev, g)
    comp = _run_shardmap(
        functools.partial(compressed_psum_mean, axis_name="data"), n_dev, g)

    # error bound: one int8 step of the agreed global scale per shard
    step = float(jnp.max(jnp.abs(g))) / 127.0
    np.testing.assert_allclose(
        np.asarray(comp), np.asarray(exact), atol=step + 1e-7)


def test_compression_is_deterministic():
    n_dev = jax.device_count()
    g = jax.random.normal(jax.random.PRNGKey(1), (n_dev, 16), jnp.float32)
    a = _run_shardmap(
        functools.partial(compressed_psum_mean, axis_name="data"), n_dev, g)
    b = _run_shardmap(
        functools.partial(compressed_psum_mean, axis_name="data"), n_dev, g)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero_gradients_stay_zero():
    n_dev = jax.device_count()
    g = jnp.zeros((n_dev, 8), jnp.float32)
    out = _run_shardmap(
        functools.partial(compressed_psum_mean, axis_name="data"), n_dev, g)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
