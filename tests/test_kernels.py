"""Per-kernel validation: Pallas kernels (interpret mode on CPU) vs ref.py
oracle, swept over shapes — exact integer equality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.deas_gemm import deas_gemm
from repro.kernels.ops import int8_gemm
from repro.kernels.ref import ref_int8_gemm, ref_spoga_gemm
from repro.kernels.spoga_gemm import spoga_gemm


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -128, 128, dtype=jnp.int8)


SHAPES = [
    (8, 16, 8),        # tiny
    (128, 128, 128),   # single tile
    (256, 512, 256),   # exact default tiles
    (130, 257, 100),   # ragged -> padding path
    (1, 249, 16),      # the paper's DPU shape: N=249 vector, M=16 dot products
    (512, 1024, 256),  # multi-tile K loop
]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_spoga_kernel_matches_oracle(m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
    x, w = _rand_int8(kx, (m, k)), _rand_int8(kw, (k, n))
    got = spoga_gemm(x, w, block_m=128, block_n=128, block_k=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_int8_gemm(x, w)))


@pytest.mark.parametrize("m,k,n", SHAPES[:4])
def test_deas_kernel_matches_oracle(m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + k * 3 + n))
    x, w = _rand_int8(kx, (m, k)), _rand_int8(kw, (k, n))
    got = deas_gemm(x, w, block_m=128, block_n=128, block_k=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_int8_gemm(x, w)))


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 256, 128), (256, 128, 512)])
def test_spoga_kernel_block_shape_sweep(bm, bn, bk):
    kx, kw = jax.random.split(jax.random.PRNGKey(bm + bn + bk))
    x, w = _rand_int8(kx, (192, 320)), _rand_int8(kw, (320, 160))
    got = spoga_gemm(x, w, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_int8_gemm(x, w)))


def test_ref_spoga_equals_ref_direct():
    kx, kw = jax.random.split(jax.random.PRNGKey(42))
    x, w = _rand_int8(kx, (64, 96)), _rand_int8(kw, (96, 32))
    np.testing.assert_array_equal(
        np.asarray(ref_spoga_gemm(x, w)), np.asarray(ref_int8_gemm(x, w))
    )


@pytest.mark.parametrize("mode", ["int8_spoga", "int8_deas", "int8_direct"])
def test_ops_dispatch(mode):
    kx, kw = jax.random.split(jax.random.PRNGKey(3))
    x, w = _rand_int8(kx, (32, 64)), _rand_int8(kw, (64, 16))
    got = int8_gemm(x, w, mode=mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_int8_gemm(x, w)))


def test_ops_dispatch_interpret_kernel():
    kx, kw = jax.random.split(jax.random.PRNGKey(4))
    x, w = _rand_int8(kx, (256, 256)), _rand_int8(kw, (256, 256))
    got = int8_gemm(x, w, mode="int8_spoga", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_int8_gemm(x, w)))


class TestSpogaGemmDequant:
    """Fused W8A8 + epilogue kernel vs the pure-jnp oracle."""

    @pytest.mark.parametrize("m,k,n", [(32, 64, 32), (48, 160, 96), (128, 512, 256)])
    def test_matches_oracle(self, m, k, n):
        from repro.kernels.ref import ref_spoga_gemm_dequant
        from repro.kernels.spoga_gemm_dequant import spoga_gemm_dequant

        rng = np.random.default_rng(m * k + n)
        x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        xs = jnp.asarray(rng.uniform(1e-3, 0.1, (m, 1)).astype(np.float32))
        ws = jnp.asarray(rng.uniform(1e-3, 0.1, (1, n)).astype(np.float32))
        got = spoga_gemm_dequant(x, w, xs, ws, block_m=32, block_n=32,
                                 block_k=64, interpret=True)
        want = ref_spoga_gemm_dequant(x, w, xs, ws)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_padding_path(self):
        from repro.kernels.ref import ref_spoga_gemm_dequant
        from repro.kernels.spoga_gemm_dequant import spoga_gemm_dequant

        rng = np.random.default_rng(7)
        m, k, n = 33, 70, 45  # none divide the block sizes
        x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
        w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
        xs = jnp.ones((m, 1), jnp.float32) * 0.02
        ws = jnp.ones((1, n), jnp.float32) * 0.05
        got = spoga_gemm_dequant(x, w, xs, ws, block_m=32, block_n=32,
                                 block_k=64, interpret=True)
        want = ref_spoga_gemm_dequant(x, w, xs, ws)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
