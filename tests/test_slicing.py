"""Property tests: nibble slicing is an exact identity over all of int8."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property suite is optional-dep gated
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.slicing import reconstruct, slice_sm, slice_tc


def test_tc_exhaustive():
    """Every int8 value round-trips exactly through two's-complement slices."""
    x = jnp.arange(-128, 128, dtype=jnp.int8)
    msn, lsn = slice_tc(x)
    np.testing.assert_array_equal(np.asarray(reconstruct(msn, lsn)), np.asarray(x))
    assert int(msn.min()) >= -8 and int(msn.max()) <= 7
    assert int(lsn.min()) >= 0 and int(lsn.max()) <= 15


def test_sm_exhaustive():
    """Every int8 value round-trips exactly through sign-magnitude slices."""
    x = jnp.arange(-128, 128, dtype=jnp.int8)
    msn, lsn = slice_sm(x)
    np.testing.assert_array_equal(np.asarray(reconstruct(msn, lsn)), np.asarray(x))
    assert int(msn.min()) >= -8 and int(msn.max()) <= 8
    assert int(lsn.min()) >= -15 and int(lsn.max()) <= 15


@given(st.lists(st.integers(-128, 127), min_size=1, max_size=256))
@settings(max_examples=50, deadline=None)
def test_slicing_roundtrip_property(vals):
    x = jnp.asarray(vals, jnp.int8)
    for fn in (slice_tc, slice_sm):
        m, l = fn(x)
        np.testing.assert_array_equal(np.asarray(reconstruct(m, l)), np.asarray(x))


@pytest.mark.parametrize("fn", [slice_tc, slice_sm])
def test_slicing_rejects_wrong_dtype(fn):
    with pytest.raises(TypeError):
        fn(jnp.zeros((4,), jnp.int32))
